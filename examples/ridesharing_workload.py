"""End-to-end streaming driver: the paper's Fig. 1 ridesharing workload over
a bursty generated stream, comparing HAMLET's dynamic sharing against the
static plans and the GRETA baseline.

    PYTHONPATH=src python examples/ridesharing_workload.py --minutes 2
"""

import argparse
import time

from repro.core.baselines.greta import greta_run
from repro.core.engine import HamletRuntime
from repro.core.optimizer import AlwaysShare, DynamicPolicy, NeverShare
from repro.launch.hamlet_service import ridesharing_workload
from repro.streams.generator import ridesharing_stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=int, default=2)
    ap.add_argument("--rate", type=int, default=400)
    ap.add_argument("--queries", type=int, default=6)
    args = ap.parse_args()

    wl = ridesharing_workload(args.queries)
    stream = ridesharing_stream(events_per_minute=args.rate,
                                minutes=args.minutes, n_groups=4)
    t_end = args.minutes * 60

    rows = []
    ref = None
    for name, runner in [
        ("hamlet-dynamic", lambda: HamletRuntime(wl, policy=DynamicPolicy())),
        ("static-share", lambda: HamletRuntime(wl, policy=AlwaysShare())),
        ("non-shared", lambda: HamletRuntime(wl, policy=NeverShare())),
    ]:
        rt = runner()
        t0 = time.time()
        res = rt.run(stream, t_end=t_end)
        dt = time.time() - t0
        if ref is None:
            ref = res
        else:
            assert set(res) == set(ref)
        s = rt.stats
        rows.append((name, dt, len(stream) / dt, s.snapshots_created,
                     s.shared_bursts, s.bursts))
    t0 = time.time()
    greta_res = greta_run(wl, stream, t_end)
    dt = time.time() - t0
    rows.append(("greta", dt, len(stream) / dt, 0, 0, 0))
    for k in list(ref)[:3]:
        assert abs(ref[k]["COUNT(*)"] - greta_res[k]["COUNT(*)"]) < 1e-6

    print(f"{'engine':16} {'wall_s':>8} {'events/s':>10} {'snapshots':>10} "
          f"{'shared':>7} {'bursts':>7}")
    for name, dt, thr, snaps, shared, bursts in rows:
        print(f"{name:16} {dt:8.3f} {thr:10.0f} {snaps:10d} {shared:7d} "
              f"{bursts:7d}")


if __name__ == "__main__":
    main()
