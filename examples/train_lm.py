"""End-to-end LM training driver: ~100M-parameter decoder-only model, a few
hundred steps on the synthetic corpus, with periodic async checkpoints and
crash-safe resume (re-run the command after killing it: it continues).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
from dataclasses import replace

from repro.configs import get_config
from repro.train.trainer import TrainLoopConfig, run_training


def lm_100m():
    base = get_config("h2o-danube-1.8b")     # llama-style block
    return replace(base, name="lm-100m", n_layers=10, d_model=768,
                   n_heads=12, n_kv_heads=4, head_dim=64, d_ff=3072,
                   vocab=32_000, window=1_024)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    cfg = lm_100m()
    import jax

    n_params = sum(x.size for x in jax.tree.leaves(jax.eval_shape(
        lambda: __import__("repro.models.lm", fromlist=["init_params"])
        .init_params(cfg, jax.random.PRNGKey(0)))))
    print(f"model: {cfg.name}  params={n_params / 1e6:.1f}M")

    loop = TrainLoopConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                           ckpt_dir=args.ckpt, ckpt_interval=50, lr=3e-4)
    params, losses, resumed = run_training(cfg, loop)
    print(f"resumed_from={resumed} steps_run={len(losses)}")
    for i in range(0, len(losses), max(1, len(losses) // 10)):
        print(f"  step {resumed + i:4d}  loss {losses[i]:.4f}")
    print(f"final loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
