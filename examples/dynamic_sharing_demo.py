"""Dynamic split/merge decisions on a constructed stream (paper Fig. 6).

Four queries share B+; mid-stream, a burst arrives whose events diverge
under the queries' predicates (event-level snapshots would be needed), so
the optimizer splits; when predicates align again it merges back.

    PYTHONPATH=src python examples/dynamic_sharing_demo.py
"""

import numpy as np

from repro.core.engine import HamletRuntime, PaneProcessor
from repro.core.events import EventBatch, StreamSchema
from repro.core.optimizer import AlwaysShare, DynamicPolicy
from repro.core.pattern import EventType, Kleene, Seq
from repro.core.query import Pred, Query, Workload

schema = StreamSchema(types=("A", "B"), attrs=("v",))
A, B = EventType("A"), EventType("B")

queries = [Query(f"q{i}", Seq(A, Kleene(B)),
                 preds={"B": [Pred("v", "<", 100.0 if i < 3 else 2.0)]},
                 within=60, slide=60)
           for i in range(4)]
wl = Workload(schema, queries)

rng = np.random.default_rng(0)
# burst 1: v < 2 for all events -> all queries agree -> share
# burst 2: v in [2, 100) -> q3 diverges on every event -> split decision
# burst 3: v < 2 again -> merge back into one shared graphlet
types, times, vals = [0], [0], [0.0]
t = 1
for lo, hi, n in [(0.0, 2.0, 12), (2.0, 99.0, 12), (0.0, 2.0, 12)]:
    types.append(0)                   # an A event separates the bursts
    times.append(t)
    vals.append(0.0)
    t += 1
    for _ in range(n):
        types.append(1)
        times.append(t)
        vals.append(float(rng.uniform(lo, hi)))
        t += 1

batch = EventBatch(schema, np.array(types), np.array(times),
                   np.array(vals)[:, None])

decisions = []
orig = PaneProcessor._process_group


def spy(self, g, el, type_id, attrs, b, *a, **k):
    if schema.types[type_id] == "B":
        decisions.append((len(g), b))
    return orig(self, g, el, type_id, attrs, b, *a, **k)


PaneProcessor._process_group = spy

for policy in (DynamicPolicy(), AlwaysShare()):
    decisions.clear()
    rt = HamletRuntime(wl, policy=policy)
    res = rt.run(batch, t_end=60)
    shared = [f"{k}q/b={b}" for k, b in decisions if k > 1]
    split = [f"{k}q/b={b}" for k, b in decisions if k == 1]
    print(f"{type(policy).__name__}: snapshots={rt.stats.snapshots_created} "
          f"shared groups={shared} singletons={len(split)}")
    print("  q0 count:", res[("q0", 0, 0)]["COUNT(*)"],
          " q3 count:", res[("q3", 0, 0)]["COUNT(*)"])

PaneProcessor._process_group = orig
print("\nDynamic shares bursts 1 & 3, splits the divergent burst 2 "
      "(fewer snapshots at equal results) — the Fig. 6 behaviour.")
