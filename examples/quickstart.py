"""Quickstart: shared online event trend aggregation in 30 lines.

Builds the paper's Example 3 workload (q1 = SEQ(A, B+), q2 = SEQ(C, B+),
B+ shareable), runs it over a small bursty stream, and prints per-window
trend counts plus the sharing decisions HAMLET made.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.engine import HamletRuntime
from repro.core.events import EventBatch, StreamSchema
from repro.core.pattern import EventType, Kleene, Seq
from repro.core.query import Query, Workload, count_star

schema = StreamSchema(types=("A", "B", "C"), attrs=("v",))
A, B, C = EventType("A"), EventType("B"), EventType("C")

workload = Workload(schema, [
    Query("q1", Seq(A, Kleene(B)), aggs=(count_star(),), within=10, slide=10),
    Query("q2", Seq(C, Kleene(B)), aggs=(count_star(),), within=10, slide=10),
])

# the paper's Fig. 4 stream: a1 a2 c1 | burst of four b's
types = np.array([0, 0, 2, 1, 1, 1, 1])
times = np.array([1, 2, 3, 4, 5, 6, 7])
stream = EventBatch(schema, types, times, None)

runtime = HamletRuntime(workload)          # dynamic sharing optimizer
results = runtime.run(stream, t_end=10)

for (query, group, window), vals in sorted(results.items()):
    print(f"{query} group={group} window=[{window},{window + 10}):", vals)

s = runtime.stats
print(f"\nbursts={s.bursts} shared_bursts={s.shared_bursts} "
      f"snapshots={s.snapshots_created} decisions={s.decisions}")
print("q1 counts 30 = 2 starts x 15 B-subsequences (Table 3: x, 2x, 4x, 8x)")
