"""Sharded-service weak-scaling study: ``BENCH_shard_scale.json``.

Scales the multi-tenant overload workload with the shard count (weak
scaling: ``tenants = shards x tenants_per_shard``, constant per-tenant
rate) through ``ShardedHamletService`` and records, per shard count:

* **aggregate throughput** — admitted events over the modeled makespan.
  Shards share no mutable state (each owns its runtime, plan cache, micro-
  batcher and PID loop), so a fleet of real workers would overlap their
  drive cycles perfectly; the single-process harness therefore models
  ``makespan = router_busy + max(shard_busy)`` — the serial router stage
  plus the slowest shard — which *charges* the router bottleneck instead
  of hiding it.  Per-shard busy seconds are measured around every worker
  call (offer/heartbeat/drive/results).
* **flash-crowd isolation** — a flash crowd aimed at one tenant (one
  shard) at the 4-shard point, against a no-flash baseline: the hot
  shard's p99 pane-processing latency degrades, the other shards' p99
  must stay within the SLO and within a small factor of their baseline.
* **aligned sealing under a slow shard** — one shard throttled to one
  pane per drive cycle: the aligned epoch must keep advancing ahead of
  the laggard's processed frontier (the aligned-epoch protocol's whole
  point; a global-min frontier would pin it to the slow shard).

Tenant groups are pinned round-robin onto shards through the placement
table's override path (the rebalance mechanism) so the scaling numbers
measure the dataplane, not consistent-hash balance luck; the differential
contract (N-shard == 1-shard results) is asserted inside the run at the
smoke scale and separately covered by ``tests/test_shardsvc.py``.

``--smoke`` is the CI fast-lane entry: a small 2-shard run asserting the
correctness invariants (differential match, alignment advance, SLO
isolation shape) without wall-clock floors.  ``--check`` validates the
committed JSON's scaling floors: >=1.6x aggregate throughput at 2 shards,
>=2.5x at 4, isolation and alignment flags true.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

import numpy as np

from repro.core.events import EventBatch
from repro.overload.config import OverloadConfig
from repro.shardsvc import ShardedHamletService, ShardServiceConfig
from repro.streams.generator import (RIDESHARING_SCHEMA, TenantStreamConfig,
                                     tenant_stream)

from .common import kleene_workload

BENCH_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_shard_scale.json")

SHARD_POINTS = (1, 2, 4)
SPEEDUP_FLOORS = {2: 1.6, 4: 2.5}
GROUPS_PER_TENANT = 2
TENANTS_PER_SHARD = 4
SLO_MS = 50.0
ISOLATION_RATIO_CEIL = 2.0     # non-flash p99 vs no-flash baseline p99


def _workload(quick: bool):
    # slide=5 -> pane=5: enough panes per run for p99 pane latency and for
    # the throttled-shard scenario to accumulate a real backlog.  Query
    # count is fixed across modes (full mode scales duration and replica
    # count, not per-pane weight) so the SLO means the same thing in both.
    del quick
    return kleene_workload(RIDESHARING_SCHEMA, 4,
                           kleene_type="Travel",
                           head_types=["Request", "Pickup", "Dropoff"],
                           within=30, slide=5)


def _base_stream(quick: bool, tps: int = TENANTS_PER_SHARD,
                 flash: bool = False):
    """One shard's worth of tenants (the replicated weak-scaling unit)."""
    minutes = 2 if quick else 6
    return tenant_stream(TenantStreamConfig(
        schema=RIDESHARING_SCHEMA, n_tenants=tps,
        groups_per_tenant=GROUPS_PER_TENANT,
        base_events_per_minute=3000,
        minutes=minutes, ramp_to=1.3,
        flash_tenant=0 if flash else None, flash=(minutes * 20, 30, 6.0),
        type_weights=(1, 1, 6, 1, 1, 1), seed=42))


def _replicated(base, n_replicas: int, tps: int = TENANTS_PER_SHARD,
                flash_base=None):
    """Clone the base tenant set onto ``n_replicas`` shards (group ids
    offset per replica).  Kleene cost is superlinear in burst size, so
    independently seeded tenants would give each shard a different amount
    of *work* for the same event count; replication makes per-shard work
    identical by construction and the scaling numbers measure
    orchestration, not seed luck.  ``flash_base`` (when given) replaces
    replica 0 — the flash crowd lands on exactly one shard."""
    span = tps * GROUPS_PER_TENANT
    parts = []
    for r in range(n_replicas):
        src = flash_base if (r == 0 and flash_base is not None) else base
        parts.append(EventBatch(schema=src.schema, type_id=src.type_id,
                                time=src.time, attrs=src.attrs,
                                group=src.group + r * span))
    return EventBatch.merge(parts)


def _service(wl, n_shards: int, tps: int = TENANTS_PER_SHARD, **cfg_kw):
    cfg = ShardServiceConfig(
        n_shards=n_shards, groups_per_tenant=GROUPS_PER_TENANT,
        admission="none", align_every_panes=1, max_lag_epochs=1,
        overload=OverloadConfig(shed_policy="none", micro_batch=8,
                                slo_ms=SLO_MS),
        **cfg_kw)
    svc = ShardedHamletService(wl, cfg)
    # pin each replica block onto its shard via the override path: the
    # scaling numbers then measure the dataplane, not hash balance luck
    for t in range(n_shards * tps):
        for g in range(t * GROUPS_PER_TENANT, (t + 1) * GROUPS_PER_TENANT):
            svc.placement.override(g, t // tps)
    return svc


def _drive(svc, stream) -> dict:
    t_hi = int(stream.time.max()) + 1
    w0 = time.perf_counter()
    for t0 in range(0, t_hi, svc.pane):
        svc.ingest(stream.time_slice(t0, t0 + svc.pane))
    svc.close()
    res = svc.results()
    wall = time.perf_counter() - w0
    busy = [w.busy_s for w in svc.workers]
    makespan = svc.router_busy_s + max(busy)
    events = sum(w.rt.metrics.summary()["admitted"] for w in svc.workers)
    return {
        "events": events,
        "windows": len(res),
        "wall_s": round(wall, 4),
        "router_busy_s": round(svc.router_busy_s, 4),
        "shard_busy_s": [round(b, 4) for b in busy],
        "makespan_s": round(makespan, 4),
        "events_per_s": round(events / makespan) if makespan > 0 else 0,
        "balance": round(max(busy) / (sum(busy) / len(busy)), 3)
        if sum(busy) > 0 else 1.0,
        "p99_proc_ms": [round(w.rt.metrics.percentile(99, "proc_ms"), 3)
                        for w in svc.workers],
        "results": res,
    }


def weak_scaling(quick: bool, reps: int = 3) -> dict:
    wl = _workload(quick)
    base = _base_stream(quick)
    out = {}
    for n in SHARD_POINTS:
        stream = _replicated(base, n)
        runs = [_drive(_service(wl, n), stream) for _ in range(reps)]
        for r in runs:
            r.pop("results")
        # per-shard work is deterministic (identical replicas), so the
        # element-wise min over reps is the cleanest estimate of each
        # shard's true cost — it filters scheduler/GC noise from
        # interleaving every shard in one process
        busy = [min(r["shard_busy_s"][s] for r in runs) for s in range(n)]
        router = min(r["router_busy_s"] for r in runs)
        makespan = router + max(busy)
        m = dict(min(runs, key=lambda r: r["makespan_s"]))
        m.update({
            "reps": reps,
            "router_busy_s": round(router, 4),
            "shard_busy_s": [round(b, 4) for b in busy],
            "makespan_s": round(makespan, 4),
            "events_per_s": round(m["events"] / makespan)
            if makespan > 0 else 0,
            "balance": round(max(busy) / (sum(busy) / len(busy)), 3)
            if sum(busy) > 0 else 1.0,
        })
        out[str(n)] = m
    base = out["1"]["events_per_s"]
    for n in SHARD_POINTS:
        out[str(n)]["speedup"] = round(
            out[str(n)]["events_per_s"] / base, 2) if base else 0.0
    return out


def _cpus() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:
        return os.cpu_count() or 1


def measured_scaling(quick: bool, reps: int = 3) -> dict:
    """*Measured* wall clock of the replicated problem: the serial drive
    vs the thread-pool drive (``ShardServiceConfig.parallel``).

    This deliberately sits next to the modeled ``weak_scaling`` numbers:
    the model (router_busy + max shard_busy) states what independent
    shards cost on sufficient cores; the measurement states what this
    host actually delivers.  Python threads only overlap the drive's
    GIL-released stretches, so measured speedup is bounded by
    ``min(shards, cpus)`` and by GIL residency — on a 1-core host it is
    ~1.0x by construction, which is why the artifact records ``cpus``
    and consumers gate on it."""
    wl = _workload(quick)
    base = _base_stream(quick)
    out = {"cpus": _cpus()}
    for n in SHARD_POINTS:
        if n == 1:
            continue
        stream = _replicated(base, n)
        walls = {}
        for parallel in (False, True):
            walls[parallel] = min(
                _drive(_service(wl, n, parallel=parallel), stream)["wall_s"]
                for _ in range(reps))
        out[str(n)] = {
            "serial_wall_s": round(walls[False], 4),
            "parallel_wall_s": round(walls[True], 4),
            "measured_speedup": round(walls[False] / walls[True], 3)
            if walls[True] else 0.0,
            "ideal_bound": min(n, _cpus()),
        }
    return out


def flash_isolation(quick: bool, tps: int = TENANTS_PER_SHARD) -> dict:
    """Flash crowd on replica 0's lead tenant (-> shard 0 under block
    pinning) at 4 shards; the other shards' p99 must hold against the
    no-flash baseline."""
    wl = _workload(quick)
    n = 4
    calm = _base_stream(quick, tps)
    hot = _base_stream(quick, tps, flash=True)
    base = _drive(_service(wl, n, tps), _replicated(calm, n, tps))
    flash = _drive(_service(wl, n, tps),
                   _replicated(calm, n, tps, flash_base=hot))
    base.pop("results")
    flash.pop("results")
    hot = 0
    cold = [s for s in range(n) if s != hot]
    cold_p99 = max(flash["p99_proc_ms"][s] for s in cold)
    cold_base = max(max(base["p99_proc_ms"][s] for s in cold), 1e-3)
    return {
        "hot_shard": hot,
        "slo_ms": SLO_MS,
        "baseline_p99_ms": base["p99_proc_ms"],
        "flash_p99_ms": flash["p99_proc_ms"],
        "hot_p99_ms": flash["p99_proc_ms"][hot],
        "cold_p99_ms": round(cold_p99, 3),
        "cold_p99_vs_baseline": round(cold_p99 / cold_base, 3),
        "cold_within_slo": bool(cold_p99 <= SLO_MS),
        "isolated": bool(cold_p99 <= SLO_MS
                         and cold_p99 / cold_base <= ISOLATION_RATIO_CEIL),
    }


def slow_shard_alignment(quick: bool, tps: int = TENANTS_PER_SHARD) -> dict:
    """Throttle shard 0 to one pane per drive; aligned sealing must keep
    advancing ahead of the laggard's processed frontier."""
    wl = _workload(quick)
    n = 4
    stream = _replicated(_base_stream(quick, tps), n, tps)
    svc = _service(wl, n, tps)
    svc.workers[0].throttle = 1
    t_hi = int(stream.time.max()) + 1
    max_lead = 0
    was_laggard = False

    def sample():
        nonlocal max_lead, was_laggard
        st = svc.aligner.status()
        max_lead = max(max_lead, st["aligned_time"] - svc.workers[0].t_now)
        was_laggard = was_laggard or 0 in st["laggards"]

    # multi-pane chunks: each ingest exposes several steppable panes, so
    # healthy shards step them all while the throttled shard steps one —
    # the backlog (and the aligned frontier's lead) grows per chunk
    chunk = 6 * svc.pane
    for t0 in range(0, t_hi, chunk):
        svc.ingest(stream.time_slice(t0, t0 + chunk))
        sample()
    # drain with the throttle still on, sampling each drive cycle
    for _ in range(1000):
        if svc.workers[0].t_now + svc.pane > t_hi:
            break
        svc._drive()
        sample()
    svc.close()
    final = svc.aligner.status()
    return {
        "throttled_shard": 0,
        "max_aligned_lead_ticks": int(max_lead),
        "laggard_excluded": bool(was_laggard),
        "aligned_advanced": bool(max_lead > 0),
        "final_epochs": final["epochs"],
        "final_laggards": final["laggards"],
    }


def smoke() -> int:
    """CI fast lane: correctness invariants at a small 2-shard scale."""
    wl = _workload(quick=True)
    tps = 2
    stream = _replicated(_base_stream(True, tps), 2, tps)
    m1 = _drive(_service(wl, 1, tps * 2), stream)
    m2 = _drive(_service(wl, 2, tps), stream)
    r1, r2 = m1.pop("results"), m2.pop("results")
    if set(r1) != set(r2) or any(r1[k] != r2[k] for k in r1):
        print("FAIL: 2-shard results differ from 1-shard run")
        return 1
    print(f"smoke: differential OK over {len(r1)} windows "
          f"(1-shard {m1['events_per_s']} ev/s, "
          f"2-shard {m2['events_per_s']} ev/s)")
    align = slow_shard_alignment(quick=True, tps=2)
    print(f"smoke: alignment {align}")
    if not (align["aligned_advanced"] and align["laggard_excluded"]):
        print("FAIL: aligned sealing did not advance past the slow shard")
        return 1
    iso = flash_isolation(quick=True, tps=2)
    print(f"smoke: isolation {iso}")
    if not iso["cold_within_slo"]:
        print("FAIL: flash crowd on one shard pushed other shards' p99 "
              "past the SLO")
        return 1
    print("OK")
    return 0


def check() -> int:
    """Validate the committed artifact's acceptance floors."""
    with open(BENCH_PATH) as f:
        payload = json.load(f)
    ws = payload["weak_scaling"]
    rc = 0
    for n, floor in SPEEDUP_FLOORS.items():
        got = ws[str(n)]["speedup"]
        print(f"shard_scale [{n} shards]: modeled speedup {got:.2f}x "
              f"(floor {floor:.2f}x)")
        if got < floor:
            print(f"FAIL: committed weak-scaling speedup at {n} shards "
                  f"below the {floor:.1f}x floor")
            rc = 1
    ms = payload.get("measured_scaling")
    if ms:
        cpus = ms["cpus"]
        for n in SHARD_POINTS:
            if str(n) not in ms:
                continue
            m = ms[str(n)]
            gated = cpus >= n
            print(f"shard_scale [{n} shards]: measured {m['measured_speedup']}x"
                  f" wall (cpus {cpus}"
                  f"{', ungated on this host' if not gated else ''})")
            if gated and m["measured_speedup"] < SPEEDUP_FLOORS.get(n, 0):
                print(f"FAIL: measured speedup at {n} shards below the "
                      f"modeled floor with {cpus} cpus available")
                rc = 1
    iso = payload["flash_isolation"]
    print(f"shard_scale [isolation]: hot p99 {iso['hot_p99_ms']:.1f} ms, "
          f"cold p99 {iso['cold_p99_ms']:.1f} ms (slo {iso['slo_ms']} ms)")
    if not iso["isolated"]:
        print("FAIL: committed artifact records a flash crowd leaking "
              "across shards")
        rc = 1
    al = payload["slow_shard"]
    print(f"shard_scale [alignment]: max aligned lead "
          f"{al['max_aligned_lead_ticks']} ticks, "
          f"laggard_excluded={al['laggard_excluded']}")
    if not (al["aligned_advanced"] and al["laggard_excluded"]):
        print("FAIL: committed artifact shows aligned sealing stalling on "
              "the slow shard")
        rc = 1
    if rc == 0:
        print("OK")
    return rc


def main(quick: bool = True) -> list[dict]:
    ws = weak_scaling(quick)
    ms = measured_scaling(quick)
    iso = flash_isolation(quick)
    al = slow_shard_alignment(quick)
    payload = {
        "meta": {
            "quick": quick,
            "cpus": _cpus(),
            "groups_per_tenant": GROUPS_PER_TENANT,
            "tenants_per_shard": TENANTS_PER_SHARD,
            "load_model": "replicated problem: same tenant block cloned "
                          "per shard (group ids offset)",
            "makespan_model": "weak_scaling speedups are MODELED: "
                              "router_busy + max(shard_busy) (shards share "
                              "no mutable state); measured_scaling records "
                              "actual serial-vs-parallel wall clock, "
                              "bounded by min(shards, cpus)",
            "python": platform.python_version(),
            "numpy": np.__version__,
        },
        "weak_scaling": ws,
        "measured_scaling": ms,
        "flash_isolation": iso,
        "slow_shard": al,
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    rows = []
    for n in SHARD_POINTS:
        m = ws[str(n)]
        rows.append({
            "shards": n,
            "speedup": m["speedup"],
            "events_per_s": m["events_per_s"],
            "events": m["events"],
            "makespan_s": m["makespan_s"],
            "balance": m["balance"],
        })
    rows.append({"shards": "isolation",
                 "hot_p99_ms": iso["hot_p99_ms"],
                 "cold_p99_ms": iso["cold_p99_ms"],
                 "isolated": iso["isolated"], "slo_ms": iso["slo_ms"]})
    rows.append({"shards": "slow_shard",
                 "max_aligned_lead_ticks": al["max_aligned_lead_ticks"],
                 "laggard_excluded": al["laggard_excluded"],
                 "aligned_advanced": al["aligned_advanced"],
                 "final_laggards": al["final_laggards"]})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast lane: 2-shard correctness invariants")
    ap.add_argument("--check", action="store_true",
                    help="validate the committed JSON's scaling floors")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke())
    if args.check:
        raise SystemExit(check())
    for row in main(quick=not args.full):
        print(row)
