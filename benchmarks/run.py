"""Benchmark orchestrator: one section per paper figure/table plus the
kernel microbench and the roofline summary.  Prints ``section,key,value``
CSV rows; pass --full for the paper-scale settings (slow on CPU)."""

from __future__ import annotations

import argparse


def _emit(section: str, rows: list[dict]) -> None:
    for row in rows:
        key = ",".join(f"{k}={row[k]}" for k in list(row)[:4])
        rest = {k: v for k, v in row.items() if k not in list(row)[:4]}
        print(f"{section},{key},{rest}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="fig9|fig11|fig12|overload|batched|disorder|"
                         "shard_scale|bench_e2e|kernel|roofline")
    args = ap.parse_args()
    quick = not args.full

    sections = []
    if args.only in (None, "kernel"):
        from . import kernel_bench

        sections.append(("kernel_bench", kernel_bench.main(quick=quick)))
    if args.only in (None, "fig9"):
        from . import fig9_vs_sota

        sections.append(("fig9_vs_sota", fig9_vs_sota.main(quick=quick)))
    if args.only in (None, "fig11"):
        from . import fig11_scale

        sections.append(("fig11_hamlet_vs_greta",
                         fig11_scale.main(quick=quick)))
    if args.only in (None, "fig12"):
        from . import fig12_dynamic_vs_static

        sections.append(("fig12_dynamic_vs_static",
                         fig12_dynamic_vs_static.main(quick=quick)))
    if args.only in (None, "overload"):
        from . import fig_overload

        sections.append(("fig_overload", fig_overload.main(quick=quick)))
    if args.only in (None, "batched"):
        from . import fig_batched

        sections.append(("fig_batched", fig_batched.main(quick=quick)))
    if args.only in (None, "disorder"):
        from . import fig_disorder

        sections.append(("fig_disorder", fig_disorder.main(quick=quick)))
    if args.only in (None, "shard_scale"):
        from . import fig_shard_scale

        sections.append(("fig_shard_scale",
                         fig_shard_scale.main(quick=quick)))
    if args.only in (None, "bench_e2e"):
        from . import bench_e2e

        sections.append(("bench_e2e", bench_e2e.main(quick=quick)))
    if args.only in (None, "roofline"):
        from . import roofline

        sections.append(("roofline", roofline.main(quick=quick)))

    for name, rows in sections:
        print(f"\n# {name}")
        _emit(name, rows)


if __name__ == "__main__":
    main()
