"""Shared benchmark utilities: timing, memory tracking, workload builders,
and the artifacts directory every figure shares."""

from __future__ import annotations

import os
import time
import tracemalloc

from repro.core.engine import HamletRuntime
from repro.core.pattern import EventType, Kleene, Seq
from repro.core.query import Pred, Query, Workload, count_star
from repro.streams.generator import (RIDESHARING_SCHEMA, SMARTHOME_SCHEMA,
                                     STOCK_SCHEMA, TAXI_SCHEMA)


ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def ensure_artifact_dir() -> str:
    """Create ``benchmarks/artifacts/`` if needed and return its path.

    Every figure that reads or writes artifacts goes through this helper, so
    creation is idempotent across figures and run orders (a fresh checkout
    can run any single figure first)."""
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    return ARTIFACT_DIR


def write_rows_csv(name: str, rows: list[dict]) -> str:
    """Persist benchmark rows as a CSV artifact; returns the file path."""
    import csv

    path = os.path.join(ensure_artifact_dir(), name)
    keys: list[str] = []
    for row in rows:
        for k in row:
            if k not in keys:
                keys.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(rows)
    return path


def timed(fn):
    """Run fn once; returns (wall_s, peak_python_bytes, result)."""
    tracemalloc.start()
    try:
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        _, peak = tracemalloc.get_traced_memory()
    finally:
        tracemalloc.stop()
    return dt, peak, out


def kleene_workload(schema, n_queries: int, *, kleene_type: str,
                    head_types: list[str], within: int = 60, slide: int = 30,
                    pred_attr: str | None = None) -> Workload:
    """Paper workload 1 shape: shared Kleene sub-pattern, same windows; the
    queries differ in their head type and (optionally) predicates."""
    T = EventType(kleene_type)
    qs = []
    for i in range(n_queries):
        head = EventType(head_types[i % len(head_types)])
        preds = None
        if pred_attr and i % 3 == 2:
            preds = {kleene_type: [Pred(pred_attr, "<", 4.0 + (i % 5))]}
        qs.append(Query(f"q{i}", Seq(head, Kleene(T)), aggs=(count_star(),),
                        preds=preds, within=within, slide=slide))
    return Workload(schema, qs)


def diverse_workload(schema, n_queries: int, *, kleene_type: str,
                     head_types: list[str], attr: str) -> Workload:
    """Paper workload 2 shape: Kleene patterns of length 1-3, window sizes
    5-20 ticks-of-60, varied aggregates and predicates."""
    from repro.core.query import agg_avg, agg_max, agg_sum, count_type

    T = EventType(kleene_type)
    aggs_pool = [
        (count_star(),),
        (count_star(), agg_sum(kleene_type, attr)),
        (count_star(), agg_avg(kleene_type, attr)),
        (count_star(), agg_max(kleene_type, attr)),
        (count_star(), count_type(kleene_type)),
    ]
    qs = []
    for i in range(n_queries):
        head = EventType(head_types[i % len(head_types)])
        tail = EventType(head_types[(i + 1) % len(head_types)])
        if i % 3 == 0:
            pat = Seq(head, Kleene(T))
        elif i % 3 == 1:
            pat = Seq(head, Kleene(T), tail)
        else:
            pat = Kleene(T)
        preds = None
        if i % 2:
            preds = {kleene_type: [Pred(attr, "<", 3.0 + (i % 6))]}
        qs.append(Query(f"q{i}", pat, aggs=aggs_pool[i % len(aggs_pool)],
                        preds=preds, within=(5 + 5 * (i % 4)) * 6,
                        slide=30, group_by=()))
    return Workload(schema, qs)
