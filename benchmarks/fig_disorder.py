"""Disorder figure (beyond-paper): emission latency and revision rate vs
disorder fraction, speculative revision vs buffer-everything.

All four named workload streams are disordered by the ``bounded_skew`` model
at a sweep of fractions (plus one stragglers / adversarial-tail row each on
ridesharing) and replayed through two event-time configurations:

* **speculate** — :class:`EventTimeRuntime` with a tight watermark: panes
  execute on arrival, windows emit as soon as the stream frontier passes
  them, stragglers re-plan their pane and amend.  Emission lag stays near
  zero regardless of the watermark's caution; the price is the revision
  rate (amendments per emitted window).
* **buffer** — the same runtime with ``speculative=False`` and a watermark
  skewed wide enough to lose nothing (the stream's measured max lateness):
  a window emits only after sealing, so the median emission lag grows with
  the disorder the watermark must cover.

``lag`` is in stream ticks: how far the arrival frontier had advanced past a
window's close when its value first appeared.  Both modes converge to the
same final aggregates (asserted against the time-sorted truth: ``exact`` is
the fraction of truth windows reproduced bit-for-bit post-revision, and must
be 1.0 whenever nothing expired).  The headline: at >= 10% disorder,
speculation beats buffering on median emission latency while revisions stay
a small fraction of emitted windows.
"""

from __future__ import annotations

from repro.core.engine import HamletRuntime, vals_equal
from repro.eventtime import EventTimeConfig, EventTimeRuntime
from repro.streams.generator import (NAMED_STREAMS, DisorderConfig,
                                     apply_disorder)

from .common import kleene_workload, write_rows_csv

WORKLOAD_SHAPE = {
    "ridesharing": dict(kleene_type="Travel",
                        head_types=["Request", "Pickup", "Dropoff"]),
    "stock": dict(kleene_type="Quote", head_types=["Buy", "Sell"]),
    "smarthome": dict(kleene_type="Measure", head_types=["Load", "Work"]),
    "taxi": dict(kleene_type="Travel", head_types=["Request", "Pickup"]),
}


def _exact(truth: dict, got: dict) -> float:
    if not truth:
        return 1.0
    hit = sum(1 for k, v in truth.items()
              if k in got and vals_equal(got[k], v))
    return hit / len(truth)


def _run_mode(wl, ds, t_end, *, speculative: bool, chunk: int,
              horizon) -> dict:
    skew = 2 if speculative else max(ds.max_lateness(), 1)
    cfg = EventTimeConfig(watermark="bounded_skew", skew=skew,
                          speculative=speculative,
                          lateness_horizon=None if speculative else horizon)
    et = EventTimeRuntime(wl, cfg)
    res = et.run_disordered(ds.base, ds.order, chunk=chunk, t_end=t_end)
    s = et.metrics.summary()
    s["res"] = res
    return s


def sweep(dataset: str, fractions, models, quick: bool) -> list[dict]:
    shape = WORKLOAD_SHAPE[dataset]
    schema = NAMED_STREAMS[dataset](minutes=1).schema
    wl = kleene_workload(schema, 3 if quick else 6, within=60, slide=15,
                        **shape)
    minutes = 2 if quick else 6
    base = NAMED_STREAMS[dataset](minutes=minutes,
                                  events_per_minute=300 if quick else 600)
    t_end = minutes * 60
    truth = HamletRuntime(wl).run(base, t_end=t_end)
    chunk = 32

    rows = []
    for model in models:
        for frac in fractions:
            ds = apply_disorder(base, DisorderConfig(
                model=model, fraction=frac, max_skew=12, seed=5))
            for mode, spec in (("speculate", True), ("buffer", False)):
                s = _run_mode(wl, ds, t_end, speculative=spec, chunk=chunk,
                              horizon=None)
                rows.append({
                    "dataset": dataset, "model": model,
                    "fraction": frac, "mode": mode,
                    "p50_lag": s["p50_emit_lag"],
                    "p99_lag": s["p99_emit_lag"],
                    "revision_rate": round(s["revision_rate"], 4),
                    "amendments": s["amendments"],
                    "windows": s["windows_emitted"],
                    "expired": s["expired"],
                    "exact": round(_exact(truth, s["res"]), 4),
                })
    return rows


def main(quick=True):
    fractions = [0.0, 0.1, 0.3] if quick else [0.0, 0.05, 0.1, 0.2, 0.4]
    datasets = ["ridesharing"] if quick else list(WORKLOAD_SHAPE)
    rows = []
    for ds in datasets:
        rows += sweep(ds, fractions, ["bounded_skew"], quick)
    # the clumped and heavy-tailed regimes, one fraction each
    rows += sweep("ridesharing", [0.2], ["stragglers", "adversarial_tail"],
                  quick)
    write_rows_csv("fig_disorder.csv", rows)
    return rows


if __name__ == "__main__":
    for row in main(quick=False):
        print(row)
