"""Overload figure (beyond-paper): latency and recall vs offered load under
pane-granular load shedding.

Two experiments on ridesharing overload scenarios (rate ramp + flash crowds):

* **SLO control** (dense stream) — offer the stream at a multiple of the
  engine's calibrated capacity and let the admission cap + PID controller
  hold the pane-latency SLO.  The headline claim: at 2x capacity,
  ``benefit_weighted`` shedding keeps p99 pane-processing latency within 2x
  the SLO while ``none`` (process everything) runs hot and its end-to-end
  latency diverges with the backlog.  The admission cap is sized from
  *worst-case* (fully fragmented, burstiness-0) throughput: shedding breaks
  bursts apart, so per-pane cost is governed by burst count, not event count.
* **Equal shed ratio** (sparse stream, many groups) — fix the shed ratio
  (controller bypassed) and compare *detection recall* across policies:
  pattern-aware shedding keeps pattern-completing heads and a per-burst
  Kleene witness, so it loses far fewer windows than uniform-random shedding
  at the same drop rate.

Metrics: ``recall`` is detection recall (fraction of truth windows with a
nonzero trend count whose shedded run still emits a nonzero count) — the
utility metric of the CEP load-shedding literature; ``fidelity`` is the mean
clipped count ratio ``min(emitted / true, 1)`` (harsh under shedding: trend
counts scale like 2^kept).
"""

from __future__ import annotations

import time

from repro.core.engine import HamletRuntime
from repro.core.events import pane_size_for
from repro.overload import OverloadConfig, OverloadRuntime
from repro.streams.generator import (RIDESHARING_SCHEMA, OverloadStreamConfig,
                                     StreamConfig, bursty_stream,
                                     overload_stream)

from .common import kleene_workload

POLICIES = ("none", "drop_tail", "random", "benefit_weighted")


def detection_recall(truth: dict, got: dict) -> float:
    num = den = 0.0
    for k, v in truth.items():
        if v.get("COUNT(*)", 0.0) <= 0:
            continue
        den += 1
        num += got.get(k, {}).get("COUNT(*)", 0.0) > 0
    return num / max(den, 1.0)


def count_fidelity(truth: dict, got: dict) -> float:
    num = den = 0.0
    for k, v in truth.items():
        c = v.get("COUNT(*)", 0.0)
        if c <= 0:
            continue
        num += min(got.get(k, {}).get("COUNT(*)", 0.0) / c, 1.0)
        den += 1
    return num / max(den, 1.0)


def _workload(n_queries: int):
    return kleene_workload(RIDESHARING_SCHEMA, n_queries,
                           kleene_type="Travel",
                           head_types=["Request", "Pickup", "Dropoff"],
                           within=60, slide=15)


def _timed_run(wl, stream, t_end):
    rt = HamletRuntime(wl)
    t0 = time.perf_counter()
    res = rt.run(stream, t_end=t_end)
    return res, len(stream) / (time.perf_counter() - t0)


def slo_control(quick: bool, offered_xs) -> list[dict]:
    minutes = 4 if quick else 8
    t_end = minutes * 60
    wl = _workload(4 if quick else 8)
    stream = overload_stream(OverloadStreamConfig(
        schema=RIDESHARING_SCHEMA, base_events_per_minute=1500,
        minutes=minutes, ramp_to=1.5,
        flash_crowds=((t_end // 3, 10, 3.0), (2 * t_end // 3, 10, 4.0)),
        n_groups=4, burstiness=0.9, type_weights=(1, 1, 6, 1, 1, 1), seed=7))
    truth, capacity = _timed_run(wl, stream, t_end)
    # worst-case throughput: same rate but fully fragmented bursts
    frag = bursty_stream(StreamConfig(
        schema=RIDESHARING_SCHEMA, events_per_minute=1500, minutes=1,
        n_groups=4, burstiness=0.0, type_weights=(1, 1, 6, 1, 1, 1), seed=11))
    _, cap_frag = _timed_run(wl, frag, 60)

    pane = pane_size_for(wl.windows)
    rows = []
    for offered_x in offered_xs:
        tick_seconds = (len(stream) / t_end) / (offered_x * capacity)
        slo_ms = pane * tick_seconds * 1e3   # SLO = keep up with real time
        budget = max(1, int(cap_frag * slo_ms / 1e3))
        for policy in POLICIES:
            cfg = OverloadConfig(slo_ms=slo_ms, shed_policy=policy,
                                 tick_seconds=tick_seconds,
                                 pane_budget_events=budget,
                                 min_burst_keep=0.1)
            ort = OverloadRuntime(wl, cfg)
            res = ort.run(stream, t_end)
            s = ort.metrics.summary()
            rows.append({
                "experiment": "slo_control", "policy": policy,
                "offered_x": offered_x,
                "slo_ms": round(slo_ms, 3),
                "p50_proc_ms": round(s["p50_proc_ms"], 3),
                "p99_proc_ms": round(s["p99_proc_ms"], 3),
                "p99_x_slo": round(s["p99_proc_ms"] / slo_ms, 3),
                "p99_e2e_ms": round(s["p99_lat_ms"], 3),
                "shed_frac": round(s["shed_frac"], 3),
                "recall": round(detection_recall(truth, res), 4),
                "fidelity": round(count_fidelity(truth, res), 4),
            })
    return rows


def equal_shed(quick: bool, ratios) -> list[dict]:
    minutes = 4 if quick else 8
    t_end = minutes * 60
    wl = _workload(4)
    stream = overload_stream(OverloadStreamConfig(
        schema=RIDESHARING_SCHEMA, base_events_per_minute=300,
        minutes=minutes, ramp_to=1.5,
        flash_crowds=((t_end // 3, 10, 3.0),),
        n_groups=16, burstiness=0.9, type_weights=(1, 1, 6, 1, 1, 1), seed=7))
    truth = HamletRuntime(wl).run(stream, t_end=t_end)
    rows = []
    for ratio in ratios:
        for policy in ("drop_tail", "random", "benefit_weighted"):
            cfg = OverloadConfig(shed_policy=policy, fixed_shed=ratio,
                                 min_burst_keep=0.1)
            ort = OverloadRuntime(wl, cfg)
            res = ort.run(stream, t_end)
            rows.append({
                "experiment": "equal_shed", "policy": policy,
                "shed_ratio": ratio,
                "shed_frac": round(ort.metrics.summary()["shed_frac"], 3),
                "recall": round(detection_recall(truth, res), 4),
                "fidelity": round(count_fidelity(truth, res), 4),
            })
    return rows


def main(quick=True):
    rows = slo_control(quick, [2.0] if quick else [1.0, 2.0, 4.0])
    rows += equal_shed(quick, [0.5] if quick else [0.3, 0.5, 0.7])
    return rows


if __name__ == "__main__":
    for row in main(quick=False):
        print(row)
