"""Figure 9/10: HAMLET vs GRETA vs SHARON vs MCEP — latency, throughput and
memory while varying event rate and workload size (ridesharing stream).

Scaled to CPU: the paper uses 10K-20K events/min and 5-25 queries; the
shapes of the curves (orders-of-magnitude separation between the two-step /
flattened baselines and the online shared engine) reproduce at the default
reduced rates.  Pass --paper-scale for the full setting (slow)."""

from __future__ import annotations

from repro.core.baselines.greta import greta_run
from repro.core.baselines.mcep import mcep_run
from repro.core.baselines.sharon import sharon_run
from repro.core.engine import HamletRuntime
from repro.core.optimizer import DynamicPolicy
from repro.streams.generator import RIDESHARING_SCHEMA, ridesharing_stream

from .common import kleene_workload, timed

HEADS = ["Request", "Accept", "Pickup", "Dropoff", "Cancel"]


def run(events_per_minute=120, minutes=2, n_queries=5, seed=0,
        include_two_step=True):
    wl = kleene_workload(RIDESHARING_SCHEMA, n_queries, kleene_type="Travel",
                         head_types=HEADS, within=60, slide=30,
                         pred_attr="speed")
    stream = ridesharing_stream(events_per_minute=events_per_minute,
                                minutes=minutes, n_groups=4, seed=seed,
                                burstiness=0.95)
    t_end = minutes * 60
    n = len(stream)
    rows = []

    def add(name, fn):
        dt, peak, res = timed(fn)
        rows.append({"approach": name, "events_per_min": events_per_minute,
                     "queries": n_queries, "events": n,
                     "latency_s": round(dt, 4),
                     "throughput_ev_s": round(n / dt, 1),
                     "peak_mem_mb": round(peak / 1e6, 2)})
        return res

    import math

    ref = add("hamlet", lambda: HamletRuntime(
        wl, policy=DynamicPolicy()).run(stream, t_end))
    got = add("greta", lambda: greta_run(wl, stream, t_end))
    for k in list(ref)[:5]:
        a, b = ref[k]["COUNT(*)"], got[k]["COUNT(*)"]
        if math.isfinite(a) and math.isfinite(b):     # counts saturate at 2^1024
            assert abs(a - b) <= 1e-6 * (1 + abs(b)), k
    add("sharon", lambda: sharon_run(wl, stream, t_end))
    if include_two_step:
        try:
            add("mcep", lambda: mcep_run(wl, stream, t_end))
        except RuntimeError as e:      # trend explosion: the paper's point
            rows.append({"approach": "mcep",
                         "events_per_min": events_per_minute,
                         "queries": n_queries, "events": n,
                         "latency_s": float("inf"),
                         "throughput_ev_s": 0.0,
                         "peak_mem_mb": float("nan"),
                         "note": f"exploded: {e}"})
    return rows


def main(quick=True):
    rows = []
    # MCEP's shared *construction* is still exponential in matched events per
    # window (the paper's core point) — it only terminates at toy rates.
    # The high-rate rows show the HAMLET/GRETA crossover (k*n^2 per window
    # vs shared pane-transfer propagation).
    rates = [30, 240, 2400] if quick else [30, 120, 240, 960, 2400, 9600]
    sizes = [3, 5] if quick else [5, 10, 15, 20, 25]
    for r in rates:
        rows += run(events_per_minute=r, n_queries=5,
                    include_two_step=(r <= 30))
    for k in sizes:
        rows += run(events_per_minute=120, n_queries=k,
                    include_two_step=False)
    return rows


if __name__ == "__main__":
    for row in main(quick=False):
        print(row)
