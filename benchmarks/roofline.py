"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:

    compute    = HLO_FLOPs / (chips * 197 TFLOP/s)      (global, trip-exact)
    memory     = HBM bytes per device / 819 GB/s        (trip-aware estimate)
    collective = collective bytes per device / 50 GB/s  (trip-aware, per-kind)

plus MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N_active*B (decode)
and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs (catching remat and
dispatch waste).  Hardware: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

from .common import ensure_artifact_dir


def model_flops(arch: str, cell: str) -> float:
    import jax

    from repro.configs import get_config
    from repro.configs.base import SHAPE_CELLS
    from repro.models.lm import init_params

    cfg = get_config(arch)
    seq, batch, step = SHAPE_CELLS[cell]
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    n_total = 0.0
    n_active = 0.0
    for path, leaf in flat:
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        n = float(leaf.size)
        n_total += n
        if "moe/w_" in p and "shared" not in p:
            n_active += n * cfg.top_k / max(1, cfg.n_experts)
        else:
            n_active += n
    if step == "train":
        return 6.0 * n_active * batch * seq
    if step == "prefill":
        return 2.0 * n_active * batch * seq
    return 2.0 * n_active * batch  # decode: one token per sequence


def analyze(records: list[dict]) -> list[dict]:
    rows = []
    for r in records:
        if r.get("status") != "ok" or r["arch"].startswith("hamlet"):
            if r.get("status") == "skipped":
                rows.append({"arch": r["arch"], "cell": r["cell"],
                             "mesh": r["mesh"], "status": "skipped",
                             "reason": r.get("reason", "")[:60]})
            continue
        chips = 1
        for part in r["mesh"].split("x"):
            chips *= int(part.split("=")[1])
        flops = r.get("flops_exact") or r.get("flops", 0.0)
        t_c = flops / (chips * PEAK_FLOPS)
        t_m = r.get("traffic_bytes_per_device", 0.0) / HBM_BW
        coll = r.get("collectives", {})
        t_x = coll.get("total", 0.0) / ICI_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        dom = max(terms, key=terms.get)
        mf = model_flops(r["arch"], r["cell"])
        bound = max(terms.values())
        mfu_bound = (mf / (chips * PEAK_FLOPS)) / bound if bound else 0.0
        rows.append({
            "arch": r["arch"], "cell": r["cell"], "mesh": r["mesh"],
            "status": "ok",
            "t_compute_s": f"{t_c:.3e}", "t_memory_s": f"{t_m:.3e}",
            "t_collective_s": f"{t_x:.3e}", "bottleneck": dom,
            "model_flops": f"{mf:.3e}", "hlo_flops": f"{flops:.3e}",
            "useful_ratio": round(mf / flops, 3) if flops else 0.0,
            "roofline_fraction": round(min(1.0, mfu_bound), 3),
            "mem_gb_per_chip": round(
                (r.get("temp_size_in_bytes", 0) +
                 r.get("argument_size_in_bytes", 0)) / 2**30, 2),
        })
    return rows


def load(mesh: str = "single") -> list[dict]:
    path = os.path.join(ensure_artifact_dir(), f"dryrun_{mesh}.json")
    with open(path) as f:
        return json.load(f)


def main(quick: bool = True):
    rows = []
    for mesh in ("single", "multi"):
        try:
            rows += analyze(load(mesh))
        except FileNotFoundError:
            rows.append({"mesh": mesh, "status": "missing artifacts — run "
                         "python -m repro.launch.dryrun first"})
    return rows


if __name__ == "__main__":
    for row in main(quick=False):
        print(row)
