"""Figure 11: HAMLET vs GRETA on the NYC-taxi-like and smart-home-like
streams, varying event rate and workload size."""

from __future__ import annotations

from repro.core.baselines.greta import greta_run
from repro.core.engine import HamletRuntime
from repro.core.optimizer import DynamicPolicy
from repro.streams.generator import (SMARTHOME_SCHEMA, TAXI_SCHEMA,
                                     nyc_taxi_stream, smarthome_stream)

from .common import kleene_workload, timed


def run(dataset: str, events_per_minute: int, n_queries: int, minutes=2):
    if dataset == "taxi":
        wl = kleene_workload(TAXI_SCHEMA, n_queries, kleene_type="Travel",
                             head_types=["Request", "Pickup", "Dropoff"],
                             within=60, slide=30, pred_attr="speed")
        stream = nyc_taxi_stream(events_per_minute=events_per_minute,
                                 minutes=minutes)
    else:
        wl = kleene_workload(SMARTHOME_SCHEMA, n_queries,
                             kleene_type="Measure",
                             head_types=["Load", "Work", "Idle"],
                             within=60, slide=30, pred_attr="value")
        stream = smarthome_stream(events_per_minute=events_per_minute,
                                  minutes=minutes)
    t_end = minutes * 60
    rows = []
    for name, fn in [
        ("hamlet", lambda: HamletRuntime(wl, policy=DynamicPolicy()).run(
            stream, t_end)),
        ("greta", lambda: greta_run(wl, stream, t_end)),
    ]:
        dt, peak, _ = timed(fn)
        rows.append({"dataset": dataset, "approach": name,
                     "events_per_min": events_per_minute,
                     "queries": n_queries,
                     "latency_s": round(dt, 4),
                     "throughput_ev_s": round(len(stream) / dt, 1),
                     "peak_mem_mb": round(peak / 1e6, 2)})
    return rows


def main(quick=True):
    rows = []
    for ds in ("taxi", "smarthome"):
        rates = [120] if quick else [120, 240, 480]
        ks = [5] if quick else [5, 15, 25]
        for r in rates:
            rows += run(ds, r, 5)
        for k in ks:
            if not quick or k != 5:
                rows += run(ds, 120, k)
    return rows


if __name__ == "__main__":
    for row in main(quick=False):
        print(row)
