"""Figures 12/13: dynamic vs static sharing decisions on the stock-like
stream (diverse workload 2: Kleene lengths 1-3, mixed windows, aggregates,
predicates).  Reports latency/throughput/memory and the snapshot counts whose
divergence drives the paper's 21-52% gains."""

from __future__ import annotations

from repro.core.engine import HamletRuntime
from repro.core.optimizer import AlwaysShare, DynamicPolicy, FlopPolicy, NeverShare
from repro.streams.generator import STOCK_SCHEMA, stock_stream

from .common import diverse_workload, timed


def run(events_per_minute=240, n_queries=20, minutes=2, seed=1,
        burstiness=0.93):
    """The paper's stock bursts average ~120 events (Sec. 6.2); the dynamic
    optimizer's gains need that bursty regime, hence the burstiness default."""
    from repro.streams.generator import StreamConfig, bursty_stream

    wl = diverse_workload(STOCK_SCHEMA, n_queries, kleene_type="Quote",
                          head_types=["Buy", "Sell", "Trade"], attr="price")
    stream = bursty_stream(StreamConfig(
        schema=STOCK_SCHEMA, events_per_minute=events_per_minute,
        minutes=minutes, n_groups=8, burstiness=burstiness,
        type_weights=(2, 2, 4, 3), seed=seed))
    t_end = minutes * 60
    rows = []
    ref = None
    for name, policy in [("dynamic", DynamicPolicy()),
                         ("static-share", AlwaysShare()),
                         ("non-shared", NeverShare()),
                         ("flop-model", FlopPolicy())]:
        rt = HamletRuntime(wl, policy=policy)
        dt, peak, res = timed(lambda rt=rt: rt.run(stream, t_end))
        if ref is None:
            ref = res
        s = rt.stats
        rows.append({"policy": name, "events_per_min": events_per_minute,
                     "queries": n_queries,
                     "latency_s": round(dt, 4),
                     "throughput_ev_s": round(len(stream) / dt, 1),
                     "peak_mem_mb": round(peak / 1e6, 2),
                     "snapshots": s.snapshots_created,
                     "snapshots_propagated": s.snapshots_propagated,
                     "shared_bursts": s.shared_bursts,
                     "bursts": s.bursts,
                     "decision_ms": 0.0})
    return rows


def main(quick=True):
    rows = []
    rates = [600] if quick else [600, 1200, 2400, 4500]
    ks = [10] if quick else [20, 40, 60, 80, 100]
    for r in rates:
        rows += run(events_per_minute=r, n_queries=10 if quick else 20)
    for k in ks:
        if not quick or k != 10:
            rows += run(events_per_minute=600, n_queries=k)
    return rows


if __name__ == "__main__":
    for row in main(quick=False):
        print(row)
