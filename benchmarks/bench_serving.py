"""Serving-tier benchmark: ``BENCH_serving.json``.

Measures the asynchronous session front-end against the epoch-synchronous
batch path it wraps, on the same replicated-problem workload the shard
scaling study uses:

* **session latency** — >= 32 concurrent trickle sessions on real threads
  with a background pump: per-session delivery-latency p50/p99 (pane
  sealed by the scheduler watermark -> record in the session inbox),
  plus the cross-session spread.
* **throughput parity** — warm events/s of the serving path (32 sessions
  trickling round-robin, inline pump — the continuous-batching flush
  path) vs the sync ``OverloadRuntime.run`` on the merged stream, with a
  bitwise determinism check of the drained results.  ``bench_e2e
  --check`` gates the committed ratio at async >= 0.9x sync.
* **measured shard scaling** — the 2-/4-shard replicated problem driven
  serially vs on the thread-pool drive (``ShardServiceConfig.parallel``):
  *measured wall clock*, no modeled makespans.  The honest caveat is
  recorded with the numbers: Python threads only overlap the drive's
  GIL-released stretches, so the measured speedup is bounded by
  ``min(shards, cpus)`` *and* by the workload's GIL residency — on the
  1-core CI container it is ~1.0x by construction.  The >= 1.5x
  acceptance floor at 4 shards is therefore gated on ``cpus >= 4`` (the
  artifact records ``cpus`` so ``--check`` applies the right rule).
* **pipelined flush** — ``OverloadConfig.pipeline_flush`` off vs on:
  wall clock of the depth-1 host/flush overlap on one runtime.
* **transport overhead** — the same paced trickle sessions driven once
  in-process (``ServingFrontend`` handles) and once over the loopback
  socket transport (``ServingServer``/``ServingClient``), at K = 1 and
  the throughput-tuned K.  Latency is computed from *raw per-delivery
  floats* (not histogram quantiles — bucket snapping would swamp a
  sub-bucket overhead), the wire hop from per-frame encode->decode
  stamps (record-weighted; loopback shares one clock).  ``--check``
  gates p50 added latency < 20% of the in-process p50 at K = 1, and
  bitwise parity of every client's END results.
* **process scaling** — the replicated shard problem driven serially,
  on the thread pool, and on the process pool
  (``ShardServiceConfig.parallel="process"``): measured wall clock with
  worker spawn/handshake timed separately (a long-lived service pays it
  once).  The process drive exists to get past the GIL, so its speedup
  is honest only next to ``cpus``: on the 1-core CI container IPC makes
  it *slower* than serial by construction, which is why the artifact
  records ``cpus`` and ``--check`` applies the >= 1.3x 2-shard floor
  only when ``cpus >= 2``.

``--smoke`` is the CI fast-lane entry (small scale, asserts determinism
and delivery plumbing, no wall-clock floors); ``--smoke --transport``
is the loopback-transport lane (8 socket sessions, bitwise parity +
clean shutdown); ``--check`` validates the committed artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import threading
import time

import numpy as np

from repro.core.events import EventBatch
from repro.overload.config import OverloadConfig
from repro.overload.runtime import OverloadRuntime
from repro.serve import ServingClient, ServingFrontend, ServingServer

from .fig_shard_scale import (GROUPS_PER_TENANT, TENANTS_PER_SHARD,
                              _base_stream, _replicated, _service,
                              _workload, measured_scaling)

BENCH_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_serving.json")

N_SESSIONS = 32
MICRO_BATCH = 8
SHARD_POINTS = (2, 4)
MEASURED_SPEEDUP_FLOOR = 1.5        # applies when cpus >= shard count
PARITY_FLOOR = 0.9                  # async warm throughput vs sync
TRANSPORT_SESSIONS = 8
TRANSPORT_OVERHEAD_CEIL = 0.20      # p50 added over the wire, K=1
PROCESS_SPEEDUP_FLOOR = 1.3         # 2-shard process drive, cpus >= 2
PROCESS_SLOWDOWN_FLOOR = 0.15       # 1-core sanity: IPC tax is bounded


def _cpus() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:
        return os.cpu_count() or 1


def _overload_cfg() -> OverloadConfig:
    return OverloadConfig(shed_policy="none", micro_batch=MICRO_BATCH)


def _session_parts(stream, n_sessions: int):
    """Tenant-aligned session split: session i serves tenant
    ``i % n_tenants`` (several sessions can share a tenant — they then
    subscribe to, and each receive, that tenant's deliveries).

    The split stamps the original stream position as the producer ``seq``
    (the front-end's replayed-trace regime), so the serving merge resolves
    equal-timestamp events in the same order the sync run sees them and
    results stay bitwise comparable."""
    if stream.seq is None:
        stream = EventBatch(
            schema=stream.schema, type_id=stream.type_id, time=stream.time,
            attrs=stream.attrs, group=stream.group,
            seq=np.arange(len(stream), dtype=np.int64))
    n_tenants = int(stream.group.max()) // GROUPS_PER_TENANT + 1
    parts = []
    for i in range(n_sessions):
        t = i % n_tenants
        lo, hi = t * GROUPS_PER_TENANT, (t + 1) * GROUPS_PER_TENANT
        mask = (stream.group >= lo) & (stream.group < hi)
        idx = np.flatnonzero(mask)
        parts.append((t, stream.select(idx[i // n_tenants::max(
            1, n_sessions // n_tenants)])))
    return parts


OFFERED_RATE = 15_000      # paced events/s across all sessions, < capacity


def session_latency(quick: bool, n_sessions: int = N_SESSIONS,
                    rate: int = OFFERED_RATE,
                    micro_batch: int = MICRO_BATCH) -> dict:
    """Threaded trickle sessions + background pump; wall-clock delivery
    latency per session.

    Sessions pace their submissions to a fixed total offered rate below
    engine capacity (deadline pacing per chunk).  Unpaced threads would
    replay the whole trace in one burst and the "latency" would just
    measure backlog drain — pacing makes the percentiles reflect steady
    service latency.  ``micro_batch`` is the dominant term: a window is
    delivered by the K-pane fused flush that finalizes it, so K > 1
    buys throughput with delivery delay (the caller reports both K = 1
    and the throughput-tuned K)."""
    wl = _workload(quick)
    base = _base_stream(quick)
    fe = ServingFrontend(
        wl, backend="overload",
        overload=OverloadConfig(shed_policy="none", micro_batch=micro_batch),
        groups_per_tenant=GROUPS_PER_TENANT)
    parts = _session_parts(base, n_sessions)
    handles = [fe.open_session(tenant=t) for t, _ in parts]
    fe.start(interval_s=0.001)
    chunk = fe.pane          # pane-granular pacing: smooth watermark advance
    duration_s = len(base) / rate

    def trickle(h, part):
        t_hi = int(part.time.max()) + 1 if len(part) else 0
        steps = range(0, t_hi, chunk)
        period = duration_s / max(1, len(steps))
        for k, t0 in enumerate(steps):
            lag = w0 + (k + 1) * period - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
            h.submit(part.time_slice(t0, t0 + chunk))
            h.advance_to(min(t0 + chunk, t_hi))
        h.close()

    w0 = time.perf_counter()
    threads = [threading.Thread(target=trickle, args=(h, p))
               for h, (_, p) in zip(handles, parts)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fe.drain()
    wall = time.perf_counter() - w0
    summ = fe.summary()
    per = [s["p99_ms"] for s in summ["sessions"].values() if "p99_ms" in s]
    return {
        "sessions": n_sessions,
        "offered_rate_events_per_s": rate,
        "micro_batch": micro_batch,
        "events": summ["submitted"],
        "deliveries": summ["deliveries"],
        "wall_s": round(wall, 4),
        "p50_ms": summ["latency_ms"]["p50"],
        "p90_ms": summ["latency_ms"]["p90"],
        "p99_ms": summ["latency_ms"]["p99"],
        "per_session_p99_ms": {
            "min": round(min(per), 3) if per else 0.0,
            "median": round(float(np.median(per)), 3) if per else 0.0,
            "max": round(max(per), 3) if per else 0.0,
        },
        "tenants": len(summ["tenants"]),
    }


def _sync_run(wl, stream) -> tuple[float, dict]:
    rt = OverloadRuntime(wl, _overload_cfg())
    w0 = time.perf_counter()
    res = rt.run(stream)
    return time.perf_counter() - w0, res


def _async_run(wl, stream, n_sessions: int) -> tuple[float, dict]:
    fe = ServingFrontend(wl, backend="overload", overload=_overload_cfg(),
                         groups_per_tenant=GROUPS_PER_TENANT)
    parts = _session_parts(stream, n_sessions)
    handles = [fe.open_session(tenant=t) for t, _ in parts]
    cursors = [0] * n_sessions
    chunk = 2 * fe.pane
    w0 = time.perf_counter()
    live = True
    while live:                         # round-robin trickle, inline pump
        live = False
        for h, (_, part), i in zip(handles, parts, range(n_sessions)):
            c0 = cursors[i]
            if c0 >= len(part):
                continue
            t0 = int(part.time[c0])
            hi = int(np.searchsorted(part.time, t0 + chunk, side="left"))
            h.submit(part.select(np.arange(c0, hi)))
            h.advance_to(t0 + chunk)
            cursors[i] = hi
            live = True
        fe.pump()
    for h in handles:
        h.close()
    res = fe.drain()
    return time.perf_counter() - w0, res


def throughput_parity(quick: bool, reps: int = 5,
                      n_sessions: int = N_SESSIONS) -> dict:
    """Warm sync epoch run vs the async serving path on the same stream.

    Shared-runner wall clocks scatter ~+-20% between epochs, and that
    noise is machine-wide, not path-specific — so each rep measures the
    two paths back-to-back (a slow epoch slows both) and the committed
    ratio is the best *paired* ratio, not a ratio of independently
    minimized walls."""
    from repro.core.engine import vals_equal
    wl = _workload(quick)
    stream = _base_stream(quick)
    _sync_run(wl, stream)               # process warmup
    best = None
    for _ in range(reps):
        sync_wall, sync_res = _sync_run(wl, stream)
        async_wall, async_res = _async_run(wl, stream, n_sessions)
        pair = (sync_wall / async_wall if async_wall else 0.0,
                sync_wall, async_wall)
        if best is None or pair[0] > best[0]:
            best = pair
    ratio, sync_wall, async_wall = best
    bitwise = (set(sync_res) == set(async_res)
               and all(vals_equal(async_res[k], sync_res[k])
                       for k in sync_res))
    n = len(stream)
    return {
        "events": n,
        "sessions": n_sessions,
        "reps": reps,
        "sync_wall_s": round(sync_wall, 4),
        "async_wall_s": round(async_wall, 4),
        "sync_events_per_s": round(n / sync_wall) if sync_wall else 0,
        "async_events_per_s": round(n / async_wall) if async_wall else 0,
        "async_vs_sync": round(ratio, 3),
        "bitwise_equal": bool(bitwise),
    }


def shards_measured(quick: bool, reps: int = 3) -> dict:
    """Measured wall clock of the replicated problem, serial vs thread-pool
    drive — no modeled makespans.  Single implementation lives in
    ``fig_shard_scale.measured_scaling`` so this artifact and
    ``BENCH_shard_scale.json`` cannot drift."""
    return measured_scaling(quick, reps=reps)


def pipeline_overlap(quick: bool, reps: int = 3) -> dict:
    wl = _workload(quick)
    stream = _base_stream(quick)
    walls = {}
    for pipelined in (False, True):
        best = None
        for _ in range(reps):
            rt = OverloadRuntime(wl, OverloadConfig(
                shed_policy="none", micro_batch=MICRO_BATCH,
                pipeline_flush=pipelined))
            w0 = time.perf_counter()
            rt.run(stream)
            w = time.perf_counter() - w0
            rt.shutdown()
            best = w if best is None else min(best, w)
        walls[pipelined] = best
    return {
        "inline_wall_s": round(walls[False], 4),
        "pipelined_wall_s": round(walls[True], 4),
        "overlap_gain": round(walls[False] / walls[True], 3)
        if walls[True] else 0.0,
        "cpus": _cpus(),
    }


# ------------------------------------------------------------- transport


def _trickle_one(sess, part, chunk: int, w0: float,
                 duration_s: float) -> None:
    """Deadline-paced trickle of one session's trace; works against both a
    :class:`ServingFrontend` handle and a :class:`ServingClient` (same
    ``submit`` / ``advance_to`` / ``close`` surface)."""
    t_hi = int(part.time.max()) + 1 if len(part) else 0
    steps = range(0, t_hi, chunk)
    period = duration_s / max(1, len(steps))
    for k, t0 in enumerate(steps):
        lag = w0 + (k + 1) * period - time.perf_counter()
        if lag > 0:
            time.sleep(lag)
        sess.submit(part.time_slice(t0, t0 + chunk))
        sess.advance_to(min(t0 + chunk, t_hi))
    sess.close()


def _paced_inproc(wl, base, n_sessions: int, micro_batch: int, rate: int):
    """The in-process baseline: paced handle sessions, raw per-delivery
    latency floats (histograms quantize to bucket edges — useless for a
    sub-bucket overhead comparison)."""
    fe = ServingFrontend(
        wl, backend="overload",
        overload=OverloadConfig(shed_policy="none", micro_batch=micro_batch),
        groups_per_tenant=GROUPS_PER_TENANT)
    parts = _session_parts(base, n_sessions)
    handles = [fe.open_session(tenant=t) for t, _ in parts]
    fe.start(interval_s=0.001)
    chunk = fe.pane
    duration = len(base) / rate
    w0 = time.perf_counter()
    threads = [threading.Thread(target=_trickle_one,
                                args=(h, p, chunk, w0, duration))
               for h, (_, p) in zip(handles, parts)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    res = fe.drain()
    wall = time.perf_counter() - w0
    lats = [d.latency_ms for h in handles for d in h.poll()
            if d.kind != "retract"]
    return res, np.asarray(lats), wall


def _paced_loopback(wl, base, n_sessions: int, micro_batch: int, rate: int):
    """The same paced load through the socket transport.  All clients
    connect before any submits (the transport's session contract: a late
    opener must not find the seal past its first events).  Wire latency is
    per-DELIVER-frame encode->decode, record-weighted; client and server
    share this process's clock on loopback."""
    fe = ServingFrontend(
        wl, backend="overload",
        overload=OverloadConfig(shed_policy="none", micro_batch=micro_batch),
        groups_per_tenant=GROUPS_PER_TENANT)
    srv = ServingServer(fe)
    host, port = srv.start(pump_interval=0.001)
    try:
        parts = _session_parts(base, n_sessions)
        clients = [ServingClient(host, port, tenant=t) for t, _ in parts]
        chunk = fe.pane
        duration = len(base) / rate
        w0 = time.perf_counter()
        threads = [threading.Thread(target=_trickle_one,
                                    args=(c, p, chunk, w0, duration))
                   for c, (_, p) in zip(clients, parts)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        # a client's close() returns when CLOSE hits its socket, not when
        # the server processed it — quiesce before draining, else trailing
        # frames race the drain (dropped + counted as late_frames)
        deadline = time.perf_counter() + 60.0
        while time.perf_counter() < deadline:
            sess = fe.summary()["sessions"]
            if len(sess) >= n_sessions and all(
                    s.get("closed") for s in sess.values()):
                break
            time.sleep(0.002)
        srv.drain()
        ends = [c.wait_end() for c in clients]
        wall = time.perf_counter() - w0
        lats = [d.latency_ms for c in clients for d in c.poll()
                if d.kind != "retract"]
        wire = [(rx - tx) * 1e3
                for c in clients for tx, rx, n in c.wire_samples
                for _ in range(n)]
        summ = srv.summary()
        blocked = sum(c.blocked_s for c in clients)
        for c in clients:
            c.shutdown()
    finally:
        srv.stop()
    return ends, np.asarray(lats), np.asarray(wire), wall, summ, blocked


def _pctl(a, q: float) -> float:
    return round(float(np.percentile(a, q)), 3) if len(a) else 0.0


def transport_overhead(quick: bool, n_sessions: int = TRANSPORT_SESSIONS,
                       micro_batch: int = 1, rate: int = OFFERED_RATE,
                       reps: int = 3) -> dict:
    """Loopback socket transport vs the in-process session path on the
    identical paced trace.

    The end-to-end transport p50 is the per-delivery latency p50 plus the
    record-weighted wire p50 (the frame hop isn't attributable per record
    without stamping each one, so the two component medians are summed —
    conservative: it can only overstate the overhead).  What the wire hop
    does *not* cover — the server-side inbox dwell before the writer's
    poll — is bounded by the writer poll interval and excluded from the
    in-process measure symmetrically.

    Delivery latency on a shared 1-core runner scatters several ms
    between epochs (the same machine-wide noise ``throughput_parity``
    documents), so each rep measures the two paths back-to-back and the
    committed number is the best *paired* overhead; bitwise parity must
    hold on every rep."""
    from repro.core.engine import vals_equal
    wl = _workload(quick)
    base = _base_stream(quick)
    parts = _session_parts(base, n_sessions)
    best = None
    ok = True
    for _ in range(reps):
        ref, in_lats, in_wall = _paced_inproc(
            wl, base, n_sessions, micro_batch, rate)
        ends, tr_lats, wire, tr_wall, summ, blocked = _paced_loopback(
            wl, base, n_sessions, micro_batch, rate)
        for (t, _), res in zip(parts, ends):
            sub = {k: v for k, v in ref.items()
                   if k[1] // GROUPS_PER_TENANT == t}
            ok = ok and res is not None and set(res) == set(sub) \
                and all(vals_equal(res[k], sub[k]) for k in sub)
        in50 = _pctl(in_lats, 50)
        added = round(_pctl(tr_lats, 50) + _pctl(wire, 50) - in50, 3)
        rep = (added, in50, in_lats, in_wall, tr_lats, wire, tr_wall,
               summ, blocked)
        if best is None or added < best[0]:
            best = rep
    added, in50, in_lats, in_wall, tr_lats, wire, tr_wall, summ, \
        blocked = best
    return {
        "sessions": n_sessions,
        "micro_batch": micro_batch,
        "offered_rate_events_per_s": rate,
        "events": len(base),
        "reps": reps,
        "inproc": {"p50_ms": in50, "p99_ms": _pctl(in_lats, 99),
                   "deliveries": int(len(in_lats)),
                   "wall_s": round(in_wall, 4)},
        "transport": {"p50_ms": _pctl(tr_lats, 50),
                      "p99_ms": _pctl(tr_lats, 99),
                      "wire_p50_ms": _pctl(wire, 50),
                      "wire_p99_ms": _pctl(wire, 99),
                      "deliveries": int(len(tr_lats)),
                      "wall_s": round(tr_wall, 4),
                      "frames_out": summ["frames_out"],
                      "bytes_in": summ["bytes_in"],
                      "bytes_out": summ["bytes_out"],
                      "disconnects": summ["disconnects"],
                      "late_frames": summ["late_frames"],
                      "credits_granted": summ["credit"]["granted"],
                      "client_blocked_s": round(blocked, 4)},
        "p50_added_ms": added,
        "p50_overhead_frac": round(added / in50, 4) if in50 else 0.0,
        "bitwise_equal": bool(ok),
    }


# -------------------------------------------------------- process scaling


def process_scaling(quick: bool, reps: int = 2) -> dict:
    """Measured wall clock of the replicated shard problem under all three
    drive modes (``serial`` / ``thread`` / ``process``).

    ``wall_s`` excludes ``setup_s`` (worker spawn + ready handshake): a
    long-lived service pays spawn once, so folding ~1.4 s of process
    start-up into a seconds-long drive would measure deployment, not the
    drive.  Results parity (process vs serial, bitwise) is asserted per
    shard point.  The honest caveat rides with the numbers: the process
    drive buys GIL-free shard parallelism at an IPC cost per cycle, so on
    ``cpus == 1`` it is *slower* than serial by construction — consumers
    gate speedup floors on the recorded ``cpus``."""
    from repro.core.engine import vals_equal
    wl = _workload(quick)
    base = _base_stream(quick)
    out = {"cpus": _cpus(),
           "note": "wall_s excludes setup_s (spawn + handshake, paid once "
                   "by a long-lived service); process drive trades IPC "
                   "per cycle for GIL-free shards, so speedup > 1 "
                   "requires cpus >= 2"}
    for n in SHARD_POINTS:
        stream = _replicated(base, n)
        t_hi = int(stream.time.max()) + 1
        point, results = {}, {}
        for mode, parallel in (("serial", False), ("thread", "thread"),
                               ("process", "process")):
            wall = setup = None
            for _ in range(reps):
                c0 = time.perf_counter()
                svc = _service(wl, n, parallel=parallel)
                s = time.perf_counter() - c0
                w0 = time.perf_counter()
                for t0 in range(0, t_hi, svc.pane):
                    svc.ingest(stream.time_slice(t0, t0 + svc.pane))
                svc.close()
                results[mode] = svc.results()
                w = time.perf_counter() - w0
                wall = w if wall is None else min(wall, w)
                setup = s if setup is None else min(setup, s)
            point[mode] = {"wall_s": round(wall, 4),
                           "setup_s": round(setup, 4)}
        ser = point["serial"]["wall_s"]
        for mode in ("thread", "process"):
            w = point[mode]["wall_s"]
            point[f"{mode}_vs_serial"] = round(ser / w, 3) if w else 0.0
        point["bitwise_equal"] = bool(
            set(results["serial"]) == set(results["process"])
            and all(vals_equal(results["process"][k], results["serial"][k])
                    for k in results["serial"]))
        out[str(n)] = point
    return out


def smoke() -> int:
    """CI fast lane: plumbing + determinism at a small scale."""
    before = {t for t in threading.enumerate()}
    par = throughput_parity(quick=True, reps=1, n_sessions=8)
    print(f"smoke: parity {par['async_vs_sync']}x "
          f"(sync {par['sync_events_per_s']} ev/s, "
          f"async {par['async_events_per_s']} ev/s), "
          f"bitwise_equal={par['bitwise_equal']}")
    if not par["bitwise_equal"]:
        print("FAIL: async serving results diverge from the sync run")
        return 1
    lat = session_latency(quick=True, n_sessions=8, micro_batch=1)
    print(f"smoke: latency p50 {lat['p50_ms']} ms p99 {lat['p99_ms']} ms "
          f"over {lat['deliveries']} deliveries")
    if lat["deliveries"] <= 0:
        print("FAIL: no deliveries reached the session inboxes")
        return 1
    sh = shards_measured(quick=True, reps=1)
    for n in SHARD_POINTS:
        print(f"smoke: {n}-shard measured {sh[str(n)]['measured_speedup']}x "
              f"(serial {sh[str(n)]['serial_wall_s']}s, "
              f"parallel {sh[str(n)]['parallel_wall_s']}s, "
              f"cpus {sh['cpus']})")
    leaked = [t for t in threading.enumerate()
              if t not in before and t.is_alive()]
    if leaked:
        print(f"FAIL: leaked threads {leaked}")
        return 1
    print("OK")
    return 0


def smoke_transport() -> int:
    """CI loopback-transport lane: start a socket server, drive 8 paced
    client sessions, assert bitwise parity with the in-process path and a
    clean shutdown (no disconnects, no leaked threads)."""
    before = {t for t in threading.enumerate()}
    tr = transport_overhead(quick=True, n_sessions=8, micro_batch=1,
                            rate=60_000, reps=1)
    t = tr["transport"]
    print(f"smoke: transport p50 {t['p50_ms']} ms "
          f"(+wire {t['wire_p50_ms']} ms) vs in-proc "
          f"{tr['inproc']['p50_ms']} ms over {t['deliveries']} deliveries, "
          f"{t['frames_out']} frames, "
          f"bitwise_equal={tr['bitwise_equal']}")
    if not tr["bitwise_equal"]:
        print("FAIL: loopback END results diverge from the in-process run")
        return 1
    if t["deliveries"] <= 0:
        print("FAIL: no deliveries crossed the wire")
        return 1
    if t["disconnects"] != 0:
        print(f"FAIL: {t['disconnects']} unclean disconnects on shutdown")
        return 1
    leaked = [th for th in threading.enumerate()
              if th not in before and th.is_alive()]
    if leaked:
        print(f"FAIL: leaked threads {leaked}")
        return 1
    print("OK")
    return 0


def check() -> int:
    """Validate the committed artifact."""
    with open(BENCH_PATH) as f:
        payload = json.load(f)
    rc = 0
    for tuning, lat in payload["session_latency"].items():
        print(f"serving [latency/{tuning}]: {lat['sessions']} sessions, "
              f"K={lat['micro_batch']}, "
              f"p50 {lat['p50_ms']} ms, p99 {lat['p99_ms']} ms")
        if lat["sessions"] < 32:
            print("FAIL: committed latency study covers < 32 sessions")
            rc = 1
        if not (0 < lat["p50_ms"] <= lat["p99_ms"]):
            print("FAIL: committed latency percentiles are not sane")
            rc = 1
    par = payload["throughput_parity"]
    print(f"serving [parity]: async {par['async_vs_sync']}x sync "
          f"(floor {PARITY_FLOOR}x), bitwise_equal={par['bitwise_equal']}")
    if not par["bitwise_equal"]:
        print("FAIL: committed artifact records non-deterministic serving")
        rc = 1
    if par["async_vs_sync"] < PARITY_FLOOR:
        print("FAIL: committed async throughput below "
              f"{PARITY_FLOOR}x of sync")
        rc = 1
    sh = payload["shards_measured"]
    cpus = sh["cpus"]
    for n in SHARD_POINTS:
        m = sh[str(n)]
        gated = cpus >= n
        print(f"serving [{n} shards]: measured {m['measured_speedup']}x "
              f"wall (cpus {cpus}, floor "
              f"{MEASURED_SPEEDUP_FLOOR if gated else 'n/a on this host'})")
        if gated and n == 4 and m["measured_speedup"] < \
                MEASURED_SPEEDUP_FLOOR:
            print(f"FAIL: measured 4-shard speedup below "
                  f"{MEASURED_SPEEDUP_FLOOR}x with {cpus} cpus")
            rc = 1
        if not gated and m["measured_speedup"] < 0.7:
            print(f"FAIL: parallel drive is pathologically slower than "
                  f"serial even accounting for {cpus} cpu(s)")
            rc = 1
    tr = payload.get("transport")
    if tr is None:
        print("FAIL: committed artifact has no transport section")
        rc = 1
    else:
        for tuning, t in tr.items():
            frac = t["p50_overhead_frac"]
            print(f"serving [transport/{tuning}]: in-proc p50 "
                  f"{t['inproc']['p50_ms']} ms, wire p50 "
                  f"{t['transport']['wire_p50_ms']} ms, added "
                  f"{t['p50_added_ms']} ms ({frac * 100:.1f}%), "
                  f"bitwise_equal={t['bitwise_equal']}")
            if not t["bitwise_equal"]:
                print("FAIL: committed transport results diverge from "
                      "the in-process path")
                rc = 1
            if t["transport"]["disconnects"] != 0:
                print("FAIL: committed transport run recorded unclean "
                      "disconnects")
                rc = 1
            if t["micro_batch"] == 1 and frac >= TRANSPORT_OVERHEAD_CEIL:
                print(f"FAIL: transport adds >= "
                      f"{TRANSPORT_OVERHEAD_CEIL:.0%} p50 latency over "
                      f"in-process at K=1")
                rc = 1
    ps = payload.get("process_scaling")
    if ps is None:
        print("FAIL: committed artifact has no process_scaling section")
        rc = 1
    else:
        cpus = ps["cpus"]
        for n in SHARD_POINTS:
            m = ps[str(n)]
            gated = cpus >= 2
            print(f"serving [process/{n} shards]: serial "
                  f"{m['serial']['wall_s']}s, thread "
                  f"{m['thread']['wall_s']}s, process "
                  f"{m['process']['wall_s']}s "
                  f"(setup {m['process']['setup_s']}s, "
                  f"{m['process_vs_serial']}x vs serial, cpus {cpus}"
                  f"{'' if gated else ', floor ungated on this host'})")
            if not m["bitwise_equal"]:
                print("FAIL: committed process-drive results diverge "
                      "from the serial drive")
                rc = 1
            if gated and n == 2 and \
                    m["process_vs_serial"] < PROCESS_SPEEDUP_FLOOR:
                print(f"FAIL: 2-shard process drive below "
                      f"{PROCESS_SPEEDUP_FLOOR}x with {cpus} cpus")
                rc = 1
            if not gated and m["process_vs_serial"] < \
                    PROCESS_SLOWDOWN_FLOOR:
                print("FAIL: process drive pathologically slower than "
                      "serial even accounting for 1-core IPC cost")
                rc = 1
    if rc == 0:
        print("OK")
    return rc


def main(quick: bool = True) -> dict:
    lat = {"latency_tuned": session_latency(quick, micro_batch=1),
           "throughput_tuned": session_latency(quick)}
    par = throughput_parity(quick)
    sh = shards_measured(quick)
    pipe = pipeline_overlap(quick)
    tr = {"latency_tuned": transport_overhead(quick, micro_batch=1),
          "throughput_tuned": transport_overhead(quick,
                                                 micro_batch=MICRO_BATCH)}
    ps = process_scaling(quick)
    payload = {
        "meta": {
            "quick": quick,
            "cpus": _cpus(),
            "groups_per_tenant": GROUPS_PER_TENANT,
            "tenants_per_shard": TENANTS_PER_SHARD,
            "micro_batch": MICRO_BATCH,
            "load_model": "replicated problem (same tenant block cloned "
                          "per shard, group ids offset) — the "
                          "fig_shard_scale workload",
            "measurement": "all wall clock; no modeled makespans in this "
                           "artifact",
            "python": platform.python_version(),
            "numpy": np.__version__,
        },
        "session_latency": lat,
        "throughput_parity": par,
        "shards_measured": sh,
        "pipeline": pipe,
        "transport": tr,
        "process_scaling": ps,
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast lane: determinism + delivery plumbing")
    ap.add_argument("--transport", action="store_true",
                    help="with --smoke: loopback socket lane (8 client "
                         "sessions, bitwise parity + clean shutdown)")
    ap.add_argument("--check", action="store_true",
                    help="validate the committed BENCH_serving.json")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke_transport() if args.transport else smoke())
    if args.check:
        raise SystemExit(check())
    payload = main(quick=not args.full)
    for k in ("session_latency", "throughput_parity", "shards_measured",
              "pipeline", "transport", "process_scaling"):
        print(k, json.dumps(payload[k], sort_keys=True))
