"""Serving-tier benchmark: ``BENCH_serving.json``.

Measures the asynchronous session front-end against the epoch-synchronous
batch path it wraps, on the same replicated-problem workload the shard
scaling study uses:

* **session latency** — >= 32 concurrent trickle sessions on real threads
  with a background pump: per-session delivery-latency p50/p99 (pane
  sealed by the scheduler watermark -> record in the session inbox),
  plus the cross-session spread.
* **throughput parity** — warm events/s of the serving path (32 sessions
  trickling round-robin, inline pump — the continuous-batching flush
  path) vs the sync ``OverloadRuntime.run`` on the merged stream, with a
  bitwise determinism check of the drained results.  ``bench_e2e
  --check`` gates the committed ratio at async >= 0.9x sync.
* **measured shard scaling** — the 2-/4-shard replicated problem driven
  serially vs on the thread-pool drive (``ShardServiceConfig.parallel``):
  *measured wall clock*, no modeled makespans.  The honest caveat is
  recorded with the numbers: Python threads only overlap the drive's
  GIL-released stretches, so the measured speedup is bounded by
  ``min(shards, cpus)`` *and* by the workload's GIL residency — on the
  1-core CI container it is ~1.0x by construction.  The >= 1.5x
  acceptance floor at 4 shards is therefore gated on ``cpus >= 4`` (the
  artifact records ``cpus`` so ``--check`` applies the right rule).
* **pipelined flush** — ``OverloadConfig.pipeline_flush`` off vs on:
  wall clock of the depth-1 host/flush overlap on one runtime.

``--smoke`` is the CI fast-lane entry (small scale, asserts determinism
and delivery plumbing, no wall-clock floors); ``--check`` validates the
committed artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import threading
import time

import numpy as np

from repro.core.events import EventBatch
from repro.overload.config import OverloadConfig
from repro.overload.runtime import OverloadRuntime
from repro.serve import ServingFrontend

from .fig_shard_scale import (GROUPS_PER_TENANT, TENANTS_PER_SHARD,
                              _base_stream, _workload, measured_scaling)

BENCH_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_serving.json")

N_SESSIONS = 32
MICRO_BATCH = 8
SHARD_POINTS = (2, 4)
MEASURED_SPEEDUP_FLOOR = 1.5        # applies when cpus >= shard count
PARITY_FLOOR = 0.9                  # async warm throughput vs sync


def _cpus() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:
        return os.cpu_count() or 1


def _overload_cfg() -> OverloadConfig:
    return OverloadConfig(shed_policy="none", micro_batch=MICRO_BATCH)


def _session_parts(stream, n_sessions: int):
    """Tenant-aligned session split: session i serves tenant
    ``i % n_tenants`` (several sessions can share a tenant — they then
    subscribe to, and each receive, that tenant's deliveries).

    The split stamps the original stream position as the producer ``seq``
    (the front-end's replayed-trace regime), so the serving merge resolves
    equal-timestamp events in the same order the sync run sees them and
    results stay bitwise comparable."""
    if stream.seq is None:
        stream = EventBatch(
            schema=stream.schema, type_id=stream.type_id, time=stream.time,
            attrs=stream.attrs, group=stream.group,
            seq=np.arange(len(stream), dtype=np.int64))
    n_tenants = int(stream.group.max()) // GROUPS_PER_TENANT + 1
    parts = []
    for i in range(n_sessions):
        t = i % n_tenants
        lo, hi = t * GROUPS_PER_TENANT, (t + 1) * GROUPS_PER_TENANT
        mask = (stream.group >= lo) & (stream.group < hi)
        idx = np.flatnonzero(mask)
        parts.append((t, stream.select(idx[i // n_tenants::max(
            1, n_sessions // n_tenants)])))
    return parts


OFFERED_RATE = 15_000      # paced events/s across all sessions, < capacity


def session_latency(quick: bool, n_sessions: int = N_SESSIONS,
                    rate: int = OFFERED_RATE,
                    micro_batch: int = MICRO_BATCH) -> dict:
    """Threaded trickle sessions + background pump; wall-clock delivery
    latency per session.

    Sessions pace their submissions to a fixed total offered rate below
    engine capacity (deadline pacing per chunk).  Unpaced threads would
    replay the whole trace in one burst and the "latency" would just
    measure backlog drain — pacing makes the percentiles reflect steady
    service latency.  ``micro_batch`` is the dominant term: a window is
    delivered by the K-pane fused flush that finalizes it, so K > 1
    buys throughput with delivery delay (the caller reports both K = 1
    and the throughput-tuned K)."""
    wl = _workload(quick)
    base = _base_stream(quick)
    fe = ServingFrontend(
        wl, backend="overload",
        overload=OverloadConfig(shed_policy="none", micro_batch=micro_batch),
        groups_per_tenant=GROUPS_PER_TENANT)
    parts = _session_parts(base, n_sessions)
    handles = [fe.open_session(tenant=t) for t, _ in parts]
    fe.start(interval_s=0.001)
    chunk = fe.pane          # pane-granular pacing: smooth watermark advance
    duration_s = len(base) / rate

    def trickle(h, part):
        t_hi = int(part.time.max()) + 1 if len(part) else 0
        steps = range(0, t_hi, chunk)
        period = duration_s / max(1, len(steps))
        for k, t0 in enumerate(steps):
            lag = w0 + (k + 1) * period - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
            h.submit(part.time_slice(t0, t0 + chunk))
            h.advance_to(min(t0 + chunk, t_hi))
        h.close()

    w0 = time.perf_counter()
    threads = [threading.Thread(target=trickle, args=(h, p))
               for h, (_, p) in zip(handles, parts)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fe.drain()
    wall = time.perf_counter() - w0
    summ = fe.summary()
    per = [s["p99_ms"] for s in summ["sessions"].values() if "p99_ms" in s]
    return {
        "sessions": n_sessions,
        "offered_rate_events_per_s": rate,
        "micro_batch": micro_batch,
        "events": summ["submitted"],
        "deliveries": summ["deliveries"],
        "wall_s": round(wall, 4),
        "p50_ms": summ["latency_ms"]["p50"],
        "p90_ms": summ["latency_ms"]["p90"],
        "p99_ms": summ["latency_ms"]["p99"],
        "per_session_p99_ms": {
            "min": round(min(per), 3) if per else 0.0,
            "median": round(float(np.median(per)), 3) if per else 0.0,
            "max": round(max(per), 3) if per else 0.0,
        },
        "tenants": len(summ["tenants"]),
    }


def _sync_run(wl, stream) -> tuple[float, dict]:
    rt = OverloadRuntime(wl, _overload_cfg())
    w0 = time.perf_counter()
    res = rt.run(stream)
    return time.perf_counter() - w0, res


def _async_run(wl, stream, n_sessions: int) -> tuple[float, dict]:
    fe = ServingFrontend(wl, backend="overload", overload=_overload_cfg(),
                         groups_per_tenant=GROUPS_PER_TENANT)
    parts = _session_parts(stream, n_sessions)
    handles = [fe.open_session(tenant=t) for t, _ in parts]
    cursors = [0] * n_sessions
    chunk = 2 * fe.pane
    w0 = time.perf_counter()
    live = True
    while live:                         # round-robin trickle, inline pump
        live = False
        for h, (_, part), i in zip(handles, parts, range(n_sessions)):
            c0 = cursors[i]
            if c0 >= len(part):
                continue
            t0 = int(part.time[c0])
            hi = int(np.searchsorted(part.time, t0 + chunk, side="left"))
            h.submit(part.select(np.arange(c0, hi)))
            h.advance_to(t0 + chunk)
            cursors[i] = hi
            live = True
        fe.pump()
    for h in handles:
        h.close()
    res = fe.drain()
    return time.perf_counter() - w0, res


def throughput_parity(quick: bool, reps: int = 5,
                      n_sessions: int = N_SESSIONS) -> dict:
    """Warm sync epoch run vs the async serving path on the same stream.

    Shared-runner wall clocks scatter ~+-20% between epochs, and that
    noise is machine-wide, not path-specific — so each rep measures the
    two paths back-to-back (a slow epoch slows both) and the committed
    ratio is the best *paired* ratio, not a ratio of independently
    minimized walls."""
    from repro.core.engine import vals_equal
    wl = _workload(quick)
    stream = _base_stream(quick)
    _sync_run(wl, stream)               # process warmup
    best = None
    for _ in range(reps):
        sync_wall, sync_res = _sync_run(wl, stream)
        async_wall, async_res = _async_run(wl, stream, n_sessions)
        pair = (sync_wall / async_wall if async_wall else 0.0,
                sync_wall, async_wall)
        if best is None or pair[0] > best[0]:
            best = pair
    ratio, sync_wall, async_wall = best
    bitwise = (set(sync_res) == set(async_res)
               and all(vals_equal(async_res[k], sync_res[k])
                       for k in sync_res))
    n = len(stream)
    return {
        "events": n,
        "sessions": n_sessions,
        "reps": reps,
        "sync_wall_s": round(sync_wall, 4),
        "async_wall_s": round(async_wall, 4),
        "sync_events_per_s": round(n / sync_wall) if sync_wall else 0,
        "async_events_per_s": round(n / async_wall) if async_wall else 0,
        "async_vs_sync": round(ratio, 3),
        "bitwise_equal": bool(bitwise),
    }


def shards_measured(quick: bool, reps: int = 3) -> dict:
    """Measured wall clock of the replicated problem, serial vs thread-pool
    drive — no modeled makespans.  Single implementation lives in
    ``fig_shard_scale.measured_scaling`` so this artifact and
    ``BENCH_shard_scale.json`` cannot drift."""
    return measured_scaling(quick, reps=reps)


def pipeline_overlap(quick: bool, reps: int = 3) -> dict:
    wl = _workload(quick)
    stream = _base_stream(quick)
    walls = {}
    for pipelined in (False, True):
        best = None
        for _ in range(reps):
            rt = OverloadRuntime(wl, OverloadConfig(
                shed_policy="none", micro_batch=MICRO_BATCH,
                pipeline_flush=pipelined))
            w0 = time.perf_counter()
            rt.run(stream)
            w = time.perf_counter() - w0
            rt.shutdown()
            best = w if best is None else min(best, w)
        walls[pipelined] = best
    return {
        "inline_wall_s": round(walls[False], 4),
        "pipelined_wall_s": round(walls[True], 4),
        "overlap_gain": round(walls[False] / walls[True], 3)
        if walls[True] else 0.0,
        "cpus": _cpus(),
    }


def smoke() -> int:
    """CI fast lane: plumbing + determinism at a small scale."""
    before = {t for t in threading.enumerate()}
    par = throughput_parity(quick=True, reps=1, n_sessions=8)
    print(f"smoke: parity {par['async_vs_sync']}x "
          f"(sync {par['sync_events_per_s']} ev/s, "
          f"async {par['async_events_per_s']} ev/s), "
          f"bitwise_equal={par['bitwise_equal']}")
    if not par["bitwise_equal"]:
        print("FAIL: async serving results diverge from the sync run")
        return 1
    lat = session_latency(quick=True, n_sessions=8, micro_batch=1)
    print(f"smoke: latency p50 {lat['p50_ms']} ms p99 {lat['p99_ms']} ms "
          f"over {lat['deliveries']} deliveries")
    if lat["deliveries"] <= 0:
        print("FAIL: no deliveries reached the session inboxes")
        return 1
    sh = shards_measured(quick=True, reps=1)
    for n in SHARD_POINTS:
        print(f"smoke: {n}-shard measured {sh[str(n)]['measured_speedup']}x "
              f"(serial {sh[str(n)]['serial_wall_s']}s, "
              f"parallel {sh[str(n)]['parallel_wall_s']}s, "
              f"cpus {sh['cpus']})")
    leaked = [t for t in threading.enumerate()
              if t not in before and t.is_alive()]
    if leaked:
        print(f"FAIL: leaked threads {leaked}")
        return 1
    print("OK")
    return 0


def check() -> int:
    """Validate the committed artifact."""
    with open(BENCH_PATH) as f:
        payload = json.load(f)
    rc = 0
    for tuning, lat in payload["session_latency"].items():
        print(f"serving [latency/{tuning}]: {lat['sessions']} sessions, "
              f"K={lat['micro_batch']}, "
              f"p50 {lat['p50_ms']} ms, p99 {lat['p99_ms']} ms")
        if lat["sessions"] < 32:
            print("FAIL: committed latency study covers < 32 sessions")
            rc = 1
        if not (0 < lat["p50_ms"] <= lat["p99_ms"]):
            print("FAIL: committed latency percentiles are not sane")
            rc = 1
    par = payload["throughput_parity"]
    print(f"serving [parity]: async {par['async_vs_sync']}x sync "
          f"(floor {PARITY_FLOOR}x), bitwise_equal={par['bitwise_equal']}")
    if not par["bitwise_equal"]:
        print("FAIL: committed artifact records non-deterministic serving")
        rc = 1
    if par["async_vs_sync"] < PARITY_FLOOR:
        print("FAIL: committed async throughput below "
              f"{PARITY_FLOOR}x of sync")
        rc = 1
    sh = payload["shards_measured"]
    cpus = sh["cpus"]
    for n in SHARD_POINTS:
        m = sh[str(n)]
        gated = cpus >= n
        print(f"serving [{n} shards]: measured {m['measured_speedup']}x "
              f"wall (cpus {cpus}, floor "
              f"{MEASURED_SPEEDUP_FLOOR if gated else 'n/a on this host'})")
        if gated and n == 4 and m["measured_speedup"] < \
                MEASURED_SPEEDUP_FLOOR:
            print(f"FAIL: measured 4-shard speedup below "
                  f"{MEASURED_SPEEDUP_FLOOR}x with {cpus} cpus")
            rc = 1
        if not gated and m["measured_speedup"] < 0.7:
            print(f"FAIL: parallel drive is pathologically slower than "
                  f"serial even accounting for {cpus} cpu(s)")
            rc = 1
    if rc == 0:
        print("OK")
    return rc


def main(quick: bool = True) -> dict:
    lat = {"latency_tuned": session_latency(quick, micro_batch=1),
           "throughput_tuned": session_latency(quick)}
    par = throughput_parity(quick)
    sh = shards_measured(quick)
    pipe = pipeline_overlap(quick)
    payload = {
        "meta": {
            "quick": quick,
            "cpus": _cpus(),
            "groups_per_tenant": GROUPS_PER_TENANT,
            "tenants_per_shard": TENANTS_PER_SHARD,
            "micro_batch": MICRO_BATCH,
            "load_model": "replicated problem (same tenant block cloned "
                          "per shard, group ids offset) — the "
                          "fig_shard_scale workload",
            "measurement": "all wall clock; no modeled makespans in this "
                           "artifact",
            "python": platform.python_version(),
            "numpy": np.__version__,
        },
        "session_latency": lat,
        "throughput_parity": par,
        "shards_measured": sh,
        "pipeline": pipe,
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast lane: determinism + delivery plumbing")
    ap.add_argument("--check", action="store_true",
                    help="validate the committed BENCH_serving.json")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke())
    if args.check:
        raise SystemExit(check())
    payload = main(quick=not args.full)
    for k in ("session_latency", "throughput_parity", "shards_measured",
              "pipeline"):
        print(k, json.dumps(payload[k], sort_keys=True))
