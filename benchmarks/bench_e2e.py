"""End-to-end pane-throughput trajectory: ``BENCH_e2e.json``.

This is the perf-trajectory artifact future PRs diff against.  For each of
the four named workload streams (ridesharing, stock, smarthome, taxi) plus
the high-burst overload workload (rate ramp + flash crowd, panes with >= 64
bursts — the regime the batched executor and the plan cache target), the
full pane pipeline (plan -> execute -> finalize -> fold) runs in two engine
configurations:

* ``baseline``  — bucketed batched launches only (plan cache off,
  ``micro_batch=1``, sequential per-graphlet finalize): the pre-plan-cache
  engine;
* ``optimized`` — plan cache on + cross-pane fused execution + the stacked
  ``FoldExecutor`` (``micro_batch=16``), measured **warm** (second run over
  the stream, so repeated pane shapes hit the plan cache and the fold
  executor's flush-plan cache) with the cold run reported alongside.

Per configuration the JSON records pane/event throughput, the engine's own
phase split (``RunStats`` wall-clock timers), the plan-cache hit rate, and
launches per pane.  Both configurations produce bitwise-identical results
(pinned by ``tests/test_microbatch.py``), so the ratio is pure speed.

``--check`` re-runs the small smoke workload and fails when the measured
warm speedup degrades by more than ``--rtol`` (default 25%) versus the
committed JSON.  The check compares *speedup ratios* (optimized vs baseline
measured in the same process) rather than absolute events/s, so it is
meaningful across machines of different speeds — a >25% drop in the ratio
means the optimization itself regressed, not the hardware.  It additionally
gates the warm *phase split*: the finalize share must not regress past the
execute share (within ``--rtol``) on the overload workload — the
FoldExecutor's acceptance headline (finalize was ~80% of warm pane time
before it; the fold must never again dominate execution).
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

import numpy as np

from repro.core.engine import HamletRuntime, RunStats
from repro.core.events import split_panes
from repro.core.optimizer import AlwaysShare, DynamicPolicy
from repro.streams.generator import (NAMED_STREAMS, RIDESHARING_SCHEMA,
                                     OverloadStreamConfig, overload_stream)

from .common import kleene_workload

BENCH_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_e2e.json")
SERVING_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                            "BENCH_serving.json")
SERVING_PARITY_FLOOR = 0.9     # async warm throughput vs sync epoch run
TRANSPORT_OVERHEAD_CEIL = 0.20  # p50 added over the wire at K=1

WORKLOAD_SHAPE = {
    "ridesharing": dict(kleene_type="Travel",
                        head_types=["Request", "Pickup", "Dropoff"]),
    "stock": dict(kleene_type="Quote", head_types=["Buy", "Sell"]),
    "smarthome": dict(kleene_type="Measure", head_types=["Load", "Work"]),
    "taxi": dict(kleene_type="Travel", head_types=["Request", "Pickup"]),
}

MICRO_BATCH = 16
SMOKE = "overload_64plus"          # the workload the CI perf-smoke checks


def _schema_for(name: str):
    from repro.streams import generator as G

    return {"ridesharing": G.RIDESHARING_SCHEMA, "stock": G.STOCK_SCHEMA,
            "smarthome": G.SMARTHOME_SCHEMA, "taxi": G.TAXI_SCHEMA}[name]


def _cases(quick: bool, only_smoke: bool = False) -> dict:
    """name -> (workload, stream batch, t_end, policy)."""
    cases = {}
    if not only_smoke:
        epm = {"ridesharing": 400, "stock": 600, "smarthome": 1200,
               "taxi": 400}
        for name, shape in WORKLOAD_SHAPE.items():
            schema = _schema_for(name)
            wl = kleene_workload(schema, 4 if quick else 8, **shape,
                                 within=60, slide=30)
            stream = NAMED_STREAMS[name](
                events_per_minute=epm[name] if quick else epm[name] * 2,
                minutes=2 if quick else 4, seed=11)
            cases[name] = (wl, stream, DynamicPolicy())
    # the >= 64-burst overload pane regime (acceptance headline); AlwaysShare
    # like fig_batched so the measurement isolates engine throughput.  Four
    # minutes in quick mode yields ~16 qualifying panes — enough depth for
    # the micro-batcher to fuse a full K=16 flush, which is what amortizes
    # the fold executor's per-round launches across panes
    minutes = 4 if quick else 6
    wl = kleene_workload(RIDESHARING_SCHEMA, 4 if quick else 8,
                         kleene_type="Travel",
                         head_types=["Request", "Pickup", "Dropoff"],
                         within=60, slide=15)
    stream = overload_stream(OverloadStreamConfig(
        schema=RIDESHARING_SCHEMA,
        base_events_per_minute=12000 if quick else 20000,
        minutes=minutes, ramp_to=1.5,
        flash_crowds=((minutes * 30, 10, 4.0),),
        n_groups=1, burstiness=0.9,
        type_weights=(1, 1, 6, 1, 1, 1), seed=7))
    cases[SMOKE] = (wl, stream, AlwaysShare())
    return cases


def _min_bursts_filter(wl, stream, min_bursts: int):
    """Keep only panes with >= min_bursts engine bursts (the 64+ regime)."""
    rt = HamletRuntime(wl, policy=AlwaysShare(), plan_cache=False)
    proc = rt.make_processor(0)
    t_end = ((int(stream.time.max()) + rt.pane) // rt.pane) * rt.pane
    kept = []
    for _, ev in split_panes(stream, rt.pane, 0, t_end):
        s = RunStats()
        proc.plan(ev, s)
        if s.bursts >= min_bursts:
            kept.append(ev)
    return kept


def _run_once(wl, panes, policy, *, plan_cache: bool, micro_batch: int,
              fold_exec: bool = True, warm_rt: HamletRuntime | None = None,
              obs=None):
    """One timed sweep of the pane pipeline over ``panes``; returns
    (metrics dict, runtime) — pass the runtime back in to measure warm.
    ``obs`` attaches a ``repro.obs.Observability`` facade to a freshly
    built runtime (the obs-overhead gate measures with a disabled one)."""
    from repro.core.engine import PaneMicroBatcher

    rt = warm_rt if warm_rt is not None else HamletRuntime(
        wl, policy=policy, plan_cache=plan_cache, micro_batch=micro_batch,
        fold_exec=fold_exec, obs=obs)
    rt.stats = RunStats()
    launches0 = rt.executor.launches
    cs0 = rt.plan_cache_stats()
    fe = rt.fold_exec
    fp0 = ((fe.plan_hits, fe.plan_misses, fe.plan_evictions)
           if fe is not None else (0, 0, 0))
    procs = [rt.make_processor(ci) for ci in range(len(rt.ctxs))]
    t0 = time.perf_counter()
    mb = PaneMicroBatcher(rt.executor, k=micro_batch, fold_exec=rt.fold_exec,
                          obs=rt.obs)
    backlog = []
    for ev in panes:
        for proc in procs:
            backlog.append(mb.submit(proc, ev, rt.stats))
        if len(backlog) >= micro_batch * len(procs):
            mb.drain()
            for pend in backlog:
                pend.finalize()
            backlog.clear()
    mb.drain()
    for pend in backlog:
        pend.finalize()
    wall = time.perf_counter() - t0
    s = rt.stats
    n_panes = max(1, s.panes)
    cs1 = rt.plan_cache_stats()
    d_hits = cs1["hits"] - cs0["hits"]
    d_total = d_hits + cs1["misses"] - cs0["misses"]
    return {
        "panes": s.panes,
        "events": s.events,
        "bursts": s.bursts,
        "wall_s": round(wall, 4),
        "panes_per_s": round(s.panes / wall, 1),
        "events_per_s": round(s.events / wall),
        "phase_split": {k: round(v, 4) for k, v in s.phase_split().items()},
        "plan_cache_hit_rate": round(d_hits / d_total, 4) if d_total else 0.0,
        "launches_per_pane": round(
            (rt.executor.launches - launches0) / n_panes, 2),
        "fold_plan": ({"hits": fe.plan_hits - fp0[0],
                       "misses": fe.plan_misses - fp0[1],
                       "evictions": fe.plan_evictions - fp0[2]}
                      if fe is not None else
                      {"hits": 0, "misses": 0, "evictions": 0}),
    }, rt


def run_case(wl, stream, policy, quick: bool, min_bursts: int = 0) -> dict:
    if min_bursts:
        panes = _min_bursts_filter(wl, stream, min_bursts)
    else:
        rt = HamletRuntime(wl, plan_cache=False)
        t_end = ((int(stream.time.max()) + rt.pane) // rt.pane) * rt.pane
        panes = [ev for _, ev in split_panes(stream, rt.pane, 0, t_end)]
    reps = 2 if quick else 3

    def best(**kw):
        out, rt = _run_once(wl, panes, policy, **kw)
        for _ in range(reps - 1):
            nxt, rt = _run_once(wl, panes, policy, **kw)
            if nxt["wall_s"] < out["wall_s"]:
                out = nxt
        return out, rt

    # the baseline keeps the PR2-era sequential finalize: the speedup (and
    # the phase-share gate) then measure plan cache + fusion + FoldExecutor
    baseline, _ = best(plan_cache=False, micro_batch=1, fold_exec=False)
    cold, opt_rt = _run_once(wl, panes, policy, plan_cache=True,
                             micro_batch=MICRO_BATCH)
    warm, _ = best(plan_cache=True, micro_batch=MICRO_BATCH, warm_rt=opt_rt)
    speedup = (baseline["wall_s"] / warm["wall_s"]
               if warm["wall_s"] > 0 else float("inf"))
    return {
        "baseline": baseline,
        "optimized_cold": cold,
        "optimized": warm,
        "speedup_warm": round(speedup, 2),
        "plan_below_execute": (warm["phase_split"]["plan"]
                               < warm["phase_split"]["execute"]),
        "finalize_below_execute": (warm["phase_split"]["finalize"]
                                   < warm["phase_split"]["execute"]),
    }


def main(quick: bool = True, only_smoke: bool = False) -> list[dict]:
    results = {}
    for name, (wl, stream, policy) in _cases(quick, only_smoke).items():
        results[name] = run_case(wl, stream, policy, quick,
                                 min_bursts=64 if name == SMOKE else 0)
    payload = {
        "meta": {
            "quick": quick,
            "micro_batch": MICRO_BATCH,
            "python": platform.python_version(),
            "numpy": np.__version__,
        },
        "workloads": results,
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    rows = []
    for name, r in results.items():
        fp = r["optimized"]["fold_plan"]
        rows.append({
            "workload": name,
            "speedup_warm": r["speedup_warm"],
            "baseline_evps": r["baseline"]["events_per_s"],
            "optimized_evps": r["optimized"]["events_per_s"],
            "hit_rate": r["optimized"]["plan_cache_hit_rate"],
            "launches_per_pane": r["optimized"]["launches_per_pane"],
            "plan_share": r["optimized"]["phase_split"]["plan"],
            "execute_share": r["optimized"]["phase_split"]["execute"],
            "fold_plan_hits": fp["hits"],
            "fold_plan_misses": fp["misses"],
        })
    return rows


def _obs_overhead(wl, panes, policy, reps: int = 15) -> tuple[float, float]:
    """Warm wall-time ratio of a *disabled* ``Observability`` facade vs no
    facade.  Each rep times the two arms back to back (order alternating,
    GC paused) and contributes one paired ratio; the estimate is the
    *median* paired ratio — per-sample noise on a shared box dwarfs the
    true overhead, and medians of adjacent-in-time pairs are robust to
    both drift and spikes where per-arm minima are not.  Returns
    (obs_wall_s, plain_wall_s) scaled so obs/plain is that median."""
    import gc
    import statistics

    from repro.obs import Observability

    def warmed(obs):
        _, rt = _run_once(wl, panes, policy, plan_cache=True,
                          micro_batch=MICRO_BATCH, obs=obs)
        return rt                              # cold pass doubles as warmup

    plain, obsd = warmed(None), warmed(Observability.disabled())

    def timed(rt):
        wall = 0.0
        for _ in range(2):                     # longer samples beat timer noise
            m, _ = _run_once(wl, panes, policy, plan_cache=True,
                             micro_batch=MICRO_BATCH, warm_rt=rt)
            wall += m["wall_s"]
        return wall

    ratios, plain_walls = [], []
    gc_was_on = gc.isenabled()
    gc.disable()
    try:
        for rep in range(reps):
            if rep % 2 == 0:                   # alternate order: drift cancels
                pw, ow = timed(plain), timed(obsd)
            else:
                ow, pw = timed(obsd), timed(plain)
            ratios.append(ow / pw)
            plain_walls.append(pw)
            gc.collect()                       # between reps, not inside them
    finally:
        if gc_was_on:
            gc.enable()
    plain_w = statistics.median(plain_walls)
    return plain_w * statistics.median(ratios), plain_w


# warm plan phase-share ceiling for every workload in the committed
# trajectory: at plan-cache hit rate 1.0 the batched stacked prologue keeps
# fixed per-pane plan work under a fifth of the pane budget
PLAN_SHARE_CEIL = 0.20


def _fold_depth_launches(n_bursts: int) -> tuple[int, int]:
    """Warm FoldExecutor launches for one K=4 flush whose panes carry
    ``n_bursts`` single-event bursts (fold-chain depth grows with the
    burst count), on the jax backend — the scanned-flush path.  Returns
    ``(launches, rounds)`` where ``rounds`` is the deepest cached flush
    plan, so the caller can assert the depths really differ while the
    launch count does not."""
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.core.engine import HamletRuntime, PaneMicroBatcher, RunStats
    from repro.core.events import EventBatch, StreamSchema
    from repro.core.pattern import EventType, Kleene, Seq
    from repro.core.query import Query, Workload

    schema = StreamSchema(types=("A", "B"), attrs=("v",))
    a, b = EventType("A"), EventType("B")
    wl = Workload(schema, [
        Query("q1", Seq(a, Kleene(b)), within=40, slide=20),
        Query("q2", Kleene(b), within=40, slide=20),
    ])
    evs = [0] + [1, 0] * n_bursts
    batch = EventBatch(schema, np.array(evs, dtype=np.int32),
                       np.arange(1, len(evs) + 1),
                       np.ones((len(evs), 1)))
    rt = HamletRuntime(wl, backend="jax", micro_batch=4, plan_cache=True,
                       fold_exec=True)
    proc = rt.make_processor(0)
    stats = RunStats()

    def flush():
        mb = PaneMicroBatcher(rt.executor, k=4, fold_exec=rt.fold_exec)
        pends = [mb.submit(proc, batch, stats) for _ in range(4)]
        mb.drain()
        for p in pends:
            p.finalize()

    flush()                       # cold: builds + compiles the flush plan
    l0 = rt.fold_exec.launches
    flush()                       # warm: the cached plan's one scan launch
    rounds = max(len(fp.rounds) for fp in rt.fold_exec._plans.values())
    return rt.fold_exec.launches - l0, rounds


def _shard_cache_hit_rates() -> tuple[float, float]:
    """Warm per-shard plan-cache hit rates: 1-shard vs min over 2 shards.

    Feeds the same multi-tenant stream twice through a
    ``ShardedHamletService`` (second pass time-shifted, so pane *shapes*
    repeat while the pane clock advances) and measures the second-pass hit
    rate per shard.  Deterministic — no timing involved.  Splitting the
    tenants over two shards must keep each shard's cache warm: unstable
    routing (groups bouncing between shards) or a cache cleared across
    chunks would zero the warm rate.  The single-shard runtime sees every
    group through one LRU, so its warm rate can legitimately sit *below*
    the per-shard ones (working set beyond capacity thrashes); the gate
    therefore holds 2-shard warmth to an absolute floor as well as to the
    1-shard baseline."""
    from repro.core.events import EventBatch
    from repro.streams.generator import TenantStreamConfig, tenant_stream

    from .fig_shard_scale import _service, _workload

    wl = _workload(True)
    stream = tenant_stream(TenantStreamConfig(
        schema=RIDESHARING_SCHEMA, n_tenants=4, groups_per_tenant=2,
        base_events_per_minute=1500, minutes=2, seed=42))
    t_hi = int(stream.time.max()) + 1
    t_hi = -(-t_hi // 5) * 5
    shifted = EventBatch(schema=stream.schema, type_id=stream.type_id,
                         time=stream.time + t_hi, attrs=stream.attrs,
                         group=stream.group)
    warm = {}
    for n, tps in ((1, 4), (2, 2)):
        svc = _service(wl, n, tps)
        for t0 in range(0, t_hi, svc.pane):
            svc.ingest(stream.time_slice(t0, t0 + svc.pane))
        pre = [w.summary()["plan_cache"] for w in svc.workers]
        for t0 in range(t_hi, 2 * t_hi, svc.pane):
            svc.ingest(shifted.time_slice(t0, t0 + svc.pane))
        svc.close()
        rates = []
        for w, p in zip(svc.workers, pre):
            s = w.summary()["plan_cache"]
            dh = s["hits"] - p["hits"]
            dn = dh + s["misses"] - p["misses"]
            rates.append(dh / dn if dn else 0.0)
        warm[n] = min(rates)
    return warm[1], warm[2]


# a 2-shard split must keep each shard's plan cache warm on replayed pane
# shapes: the floor catches warmth destruction (unstable routing, cleared
# caches) even when single-shard thrash makes the baseline comparison easy
SHARD_WARM_FLOOR = 0.5


def check(rtol: float = 0.25, obs_tol: float = 0.03) -> int:
    """CI perf-smoke: re-measure the smoke workload, compare the warm
    speedup ratio against the committed ``BENCH_e2e.json``, and gate the
    overhead of an attached-but-disabled observability facade."""
    with open(BENCH_PATH) as f:
        payload = json.load(f)
    if not payload["meta"].get("quick", False):
        # the check re-measures the *quick* workload; a full-mode artifact
        # covers a different stream and would make the ratio comparison
        # meaningless — commit a quick-mode run (the default) instead
        print("FAIL: committed BENCH_e2e.json was generated with --full; "
              "regenerate it in quick mode before relying on perf-smoke")
        return 1
    committed = payload["workloads"][SMOKE]
    # the committed artifact itself must match what the docs claim: the
    # stacked fold carries finalize below execute on the smoke workload
    # (a recorded ``false`` used to slip through because only the rtol
    # ratio was gated), and every workload's warm plan share sits under
    # the stacked-prologue ceiling
    if not committed.get("finalize_below_execute", False):
        print(f"FAIL: committed BENCH_e2e.json records "
              f"finalize_below_execute=false on {SMOKE} — the trajectory "
              f"contradicts the docs; re-run and re-commit the bench")
        return 1
    for name, rec in payload["workloads"].items():
        share = rec["optimized"]["phase_split"]["plan"]
        if share >= PLAN_SHARE_CEIL:
            print(f"FAIL: committed warm plan share {share:.3f} on {name} "
                  f"is at/above the {PLAN_SHARE_CEIL:.2f} ceiling")
            return 1
    wl, stream, policy = _cases(quick=True, only_smoke=True)[SMOKE]
    current = run_case(wl, stream, policy, quick=True, min_bursts=64)
    want = committed["speedup_warm"]
    got = current["speedup_warm"]
    floor = want * (1.0 - rtol)
    print(f"perf-smoke [{SMOKE}]: committed speedup {want:.2f}x, "
          f"measured {got:.2f}x (floor {floor:.2f}x)")
    if got < floor:
        print("FAIL: pane-throughput speedup regressed by more than "
              f"{rtol:.0%} vs the committed trajectory")
        return 1
    # phase-share gate: warm finalize must stay at/below the execute share
    # (the FoldExecutor's acceptance headline), with the same tolerance to
    # absorb share jitter between the two phases
    ps = current["optimized"]["phase_split"]
    fin, exe = ps["finalize"], ps["execute"]
    print(f"perf-smoke [{SMOKE}]: warm phase shares finalize {fin:.3f} "
          f"vs execute {exe:.3f} (ceiling {exe * (1.0 + rtol):.3f})")
    if fin > exe * (1.0 + rtol):
        print("FAIL: warm finalize phase share regressed past the execute "
              "share — the stacked fold path is no longer carrying the "
              "finalize phase")
        return 1
    # plan-share gate: the re-measured warm plan share must stay under the
    # stacked-prologue ceiling (with the same rtol slack as the other
    # re-measured ratios — the committed values are gated exactly above)
    plan_share = ps["plan"]
    print(f"perf-smoke [{SMOKE}]: warm plan share {plan_share:.3f} "
          f"(ceiling {PLAN_SHARE_CEIL * (1.0 + rtol):.3f})")
    if plan_share > PLAN_SHARE_CEIL * (1.0 + rtol):
        print("FAIL: warm plan phase share regressed past the "
              f"{PLAN_SHARE_CEIL:.2f} stacked-prologue ceiling")
        return 1
    # launch-constancy gate: a warm scanned flush is one device program, so
    # the per-flush launch count must not grow with fold-chain depth
    (l_shallow, r_shallow), (l_deep, r_deep) = (
        _fold_depth_launches(8), _fold_depth_launches(24))
    print(f"perf-smoke [fold-depth]: warm flush launches {l_shallow} at "
          f"{r_shallow} rounds vs {l_deep} at {r_deep} rounds")
    if r_deep <= r_shallow:
        print("FAIL: fold-depth probe did not produce a deeper flush plan "
              "— the launch-constancy gate is vacuous")
        return 1
    if l_deep != l_shallow:
        print("FAIL: warm fold launches per flush grew with fold-chain "
              "depth — the flush is no longer one scanned device program")
        return 1
    # obs-overhead gate: a disabled Observability facade (tracing + audit
    # off, registry attached) must stay within ``obs_tol`` of the plain
    # runtime's warm wall time — the no-op span path is the contract
    panes = _min_bursts_filter(wl, stream, 64)
    ratio = None
    # a shared runner's noise floor is ~+-2.5% at this workload size (A/A
    # plain-vs-plain medians scatter that much), so take the min of up to
    # three independent median estimates: noise spares one of them, a real
    # regression inflates all three
    for attempt in range(3):
        obs_w, plain_w = _obs_overhead(wl, panes, policy)
        r = obs_w / plain_w if plain_w > 0 else 1.0
        ratio = r if ratio is None else min(ratio, r)
        print(f"perf-smoke [{SMOKE}]: obs-disabled overhead {r:.3f}x "
              f"(ceiling {1.0 + obs_tol:.3f}x; "
              f"obs {obs_w * 1e3:.1f} ms vs plain {plain_w * 1e3:.1f} ms)")
        if ratio <= 1.0 + obs_tol:
            break
    if ratio > 1.0 + obs_tol:
        print("FAIL: a disabled observability facade costs more than "
              f"{obs_tol:.0%} warm pane throughput")
        return 1
    # shard-cache gate: splitting tenants across shards must not lose plan-
    # cache warmth — each shard's warm hit rate on replayed pane shapes
    # holds an absolute floor and never regresses below the 1-shard rate
    one, two = _shard_cache_hit_rates()
    print(f"perf-smoke [shard-cache]: warm hit rate 1-shard {one:.3f}, "
          f"2-shard min {two:.3f} (floor {max(SHARD_WARM_FLOOR, one):.3f})")
    if two < SHARD_WARM_FLOOR or two < one:
        print("FAIL: per-shard plan-cache warm hit rate regressed vs the "
              "single-shard runtime — sharding is losing plan-cache warmth")
        return 1
    # serving-parity gate: the committed serving artifact must show the
    # async session front-end holding warm throughput within 10% of the
    # sync epoch run on the same merged stream, with bitwise-equal results
    # (the continuous-batching flush path is a wrapper, not a second engine)
    with open(SERVING_PATH) as f:
        serving_all = json.load(f)
    serving = serving_all["throughput_parity"]
    ratio = serving["async_vs_sync"]
    print(f"perf-smoke [serving]: async warm throughput {ratio:.3f}x sync "
          f"(floor {SERVING_PARITY_FLOOR:.2f}x), "
          f"bitwise_equal={serving['bitwise_equal']}")
    if not serving["bitwise_equal"]:
        print("FAIL: committed BENCH_serving.json records async results "
              "diverging from the sync run")
        return 1
    if ratio < SERVING_PARITY_FLOOR:
        print("FAIL: committed async serving throughput is more than 10% "
              "below the sync epoch run")
        return 1
    # transport gate: the wire must be a transparent wrapper too — bitwise
    # parity with the in-process session path and a bounded p50 latency
    # tax at the latency-tuned point (K=1)
    tr = serving_all.get("transport")
    if tr is None:
        print("FAIL: committed BENCH_serving.json has no transport section")
        return 1
    for tuning, t in tr.items():
        print(f"perf-smoke [transport/{tuning}]: added p50 "
              f"{t['p50_added_ms']} ms ({t['p50_overhead_frac']:+.1%}), "
              f"bitwise_equal={t['bitwise_equal']}")
        if not t["bitwise_equal"]:
            print("FAIL: committed transport results diverge from the "
                  "in-process session path")
            return 1
        if t["micro_batch"] == 1 and t["p50_overhead_frac"] >= \
                TRANSPORT_OVERHEAD_CEIL:
            print("FAIL: committed transport adds >= "
                  f"{TRANSPORT_OVERHEAD_CEIL:.0%} p50 delivery latency "
                  "over in-process at K=1")
            return 1
    print("OK")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="perf-smoke: compare against committed JSON")
    ap.add_argument("--rtol", type=float, default=0.25)
    ap.add_argument("--obs-tol", type=float, default=0.03,
                    help="obs-disabled overhead ceiling for --check")
    args = ap.parse_args()
    if args.check:
        raise SystemExit(check(rtol=args.rtol, obs_tol=args.obs_tol))
    for row in main(quick=not args.full):
        print(row)
