"""Batched pane execution figure (beyond-paper): per-burst vs batched
propagation launches on high-burst-count panes.

The plan-then-execute engine turns a pane's propagation work into a job set
and executes it with one bucketed launch per size class instead of one
launch per burst.  This benchmark replays overload-scenario panes (rate
ramp + flash crowd, Markov-bursty types — the regime Sec. 6's GRETA
comparison loses in) and measures, per burst-count bin:

* **launch throughput** — events/s through the propagation-execution phase
  alone, identical prebuilt jobs, per-burst launches vs bucketed batched
  launches.  This isolates the per-launch overhead the tentpole removes;
  the headline: >= 3x on panes with >= 64 bursts.
* **end-to-end throughput** — full ``PaneProcessor.process`` (plan +
  execute + finalize) in both modes, same panes.  Planning and snapshot
  folds are mode-independent Python, so this ratio is smaller; it is
  reported so the launch win is not mistaken for the whole story.

Batched and per-burst execution are bitwise-identical by construction
(tests/test_differential.py pins this), so the comparison is pure speed.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.batch_exec import PaneBatchExecutor
from repro.core.engine import (HamletRuntime, PaneProcessor, RunStats,
                               _GroupPlan)
from repro.core.events import split_panes
from repro.core.optimizer import AlwaysShare
from repro.kernels import ops
from repro.streams.generator import (RIDESHARING_SCHEMA,
                                     OverloadStreamConfig, overload_stream)

from .common import kleene_workload

BINS = ((1, 16), (16, 64), (64, 1 << 30))


def _bin_label(lo, hi):
    return f"{lo}+" if hi >= 1 << 30 else f"{lo}-{hi}"


def _build_panes(quick: bool):
    minutes = 2 if quick else 4
    wl = kleene_workload(RIDESHARING_SCHEMA, 4 if quick else 8,
                         kleene_type="Travel",
                         head_types=["Request", "Pickup", "Dropoff"],
                         within=60, slide=15)
    stream = overload_stream(OverloadStreamConfig(
        schema=RIDESHARING_SCHEMA,
        base_events_per_minute=12000 if quick else 20000,
        minutes=minutes, ramp_to=1.5,
        flash_crowds=((minutes * 30, 10, 4.0),),
        n_groups=1, burstiness=0.9,
        type_weights=(1, 1, 6, 1, 1, 1), seed=7))
    rt = HamletRuntime(wl, policy=AlwaysShare())
    ctx = rt.ctxs[0]
    t_end = ((int(stream.time.max()) + rt.pane) // rt.pane) * rt.pane
    panes = [ev for _, ev in split_panes(stream, rt.pane, 0, t_end)]
    return rt, ctx, panes


def _plan_jobs(proc: PaneProcessor, pane_ev):
    """Plan one pane and return (n_bursts, n_events, jobs) with prebuilt
    count-round injection rows — the identical inputs both launch modes see."""
    stats = RunStats()
    steps = proc._plan_pane(pane_ev, stats)
    jobs = [(proc._count_base(p), None if p.dense else p.em)
            for p in steps if isinstance(p, _GroupPlan)]
    return stats.bursts, stats.events, jobs


def _launch_per_burst(jobs) -> float:
    t0 = time.perf_counter()
    for base, mask in jobs:
        if mask is None:
            ops.propagate_dense(base, backend="np")
        else:
            ops.propagate(base, mask, backend="np")
    return time.perf_counter() - t0


def _launch_batched(jobs) -> float:
    ex = PaneBatchExecutor(backend="np", batched=True)
    t0 = time.perf_counter()
    for base, mask in jobs:
        ex.submit(base, mask)
    ex.flush()
    return time.perf_counter() - t0


def _end_to_end(ctx, policy, panes, batched: bool) -> float:
    ex = PaneBatchExecutor(backend="np", batched=batched)
    proc = PaneProcessor(ctx, policy, executor=ex)
    stats = RunStats()
    t0 = time.perf_counter()
    for ev in panes:
        proc.process(ev, stats)
    return time.perf_counter() - t0


def main(quick: bool = True) -> list[dict]:
    rt, ctx, panes = _build_panes(quick)
    proc = PaneProcessor(ctx, rt.policy,
                         executor=PaneBatchExecutor(batched=True))
    planned = [_plan_jobs(proc, ev) for ev in panes]

    reps = 3 if quick else 5
    rows: list[dict] = []
    for lo, hi in BINS:
        sel = [(n_b, n_ev, jobs) for n_b, n_ev, jobs in planned
               if lo <= n_b < hi]
        if not sel:
            continue
        events = sum(n_ev for _, n_ev, _ in sel)
        bursts = sum(n_b for n_b, _, _ in sel)
        all_jobs = [j for _, _, jobs in sel for j in jobs]
        _launch_per_burst(all_jobs), _launch_batched(all_jobs)   # warm
        t_pb = min(_launch_per_burst(all_jobs) for _ in range(reps))
        t_ba = min(_launch_batched(all_jobs) for _ in range(reps))
        rows.append({
            "bursts_per_pane": _bin_label(lo, hi),
            "panes": len(sel),
            "mean_bursts": round(bursts / len(sel), 1),
            "jobs": len(all_jobs),
            "per_burst_launch_evps": round(events / t_pb),
            "batched_launch_evps": round(events / t_ba),
            "launch_speedup": round(t_pb / t_ba, 2),
        })

    # end-to-end pane processing, same panes, both modes
    _end_to_end(ctx, rt.policy, panes, True)
    _end_to_end(ctx, rt.policy, panes, False)                    # warm
    e_ba = min(_end_to_end(ctx, rt.policy, panes, True)
               for _ in range(reps))
    e_pb = min(_end_to_end(ctx, rt.policy, panes, False)
               for _ in range(reps))
    events = sum(n_ev for _, n_ev, _ in planned)
    rows.append({
        "bursts_per_pane": "all(e2e)",
        "panes": len(panes),
        "mean_bursts": round(sum(n_b for n_b, _, _ in planned) / len(panes), 1),
        "jobs": sum(len(j) for _, _, j in planned),
        "per_burst_e2e_evps": round(events / e_pb),
        "batched_e2e_evps": round(events / e_ba),
        "e2e_speedup": round(e_pb / e_ba, 2),
    })
    return rows


if __name__ == "__main__":
    for row in main(quick=True):
        print(row)
