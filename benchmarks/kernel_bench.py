"""Propagation-kernel microbenchmark: numpy oracle vs jnp scan vs blocked
Neumann (the Pallas algorithm in jnp) vs Pallas interpret, across burst
sizes and basis widths.  On CPU the interpret-mode Pallas timing is not
meaningful for TPU perf; the benchmark's role here is correctness-at-scale
plus FLOP accounting for the roofline."""

from __future__ import annotations

import time

import numpy as np

from repro.kernels import ops


def run(b: int, d: int, reps: int = 3, backends=("np", "jax", "jax_blocked",
                                                 "pallas")):
    rng = np.random.default_rng(b)
    # weighted mask keeps magnitudes bounded (0/1 counts double per event and
    # saturate f32 past b ~ 120; the engine's f64 host path is the exact one)
    mask = np.tril((rng.random((b, b)) < 0.5), k=-1).astype(np.float32)
    mask *= rng.uniform(0, 2.0 / b, (b, b)).astype(np.float32)
    base = rng.standard_normal((b, d)).astype(np.float32) * 0.01
    rows = []
    ref = None
    for backend in backends:
        out = np.asarray(ops.propagate(base, mask, backend=backend))
        t0 = time.perf_counter()
        for _ in range(reps):
            out = ops.propagate(base, mask, backend=backend)
        np.asarray(out)
        dt = (time.perf_counter() - t0) / reps
        if ref is None:
            ref = np.asarray(out)
        rows.append({"backend": backend, "b": b, "d": d,
                     "us_per_call": round(dt * 1e6, 1),
                     "max_err": float(np.max(np.abs(np.asarray(out) - ref)))})
    return rows


def main(quick=True):
    rows = []
    shapes = [(128, 8), (256, 16)] if quick else [(128, 8), (256, 16),
                                                  (512, 32), (1024, 8)]
    for b, d in shapes:
        rows += run(b, d)
    return rows


if __name__ == "__main__":
    for row in main(quick=False):
        print(row)
