"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

Pods replicate parameters (DP across pods), so per-step gradient sync crosses
the slow inter-pod links once per parameter.  ``compressed_psum`` quantizes
each gradient leaf to int8 with a per-leaf scale, all-reduces the int8 payload
(as int32 accumulation), dequantizes, and keeps the quantization residual as
*error feedback* added to the next step's gradient — the standard EF-SGD
construction (1-bit Adam / EF21 lineage) that preserves convergence.

Payload crossing the pod links: 1 byte/param instead of 4 — a 4x cut of the
collective term on the pod axis.  Used inside a ``shard_map`` over the
``pod`` axis (see launch/dryrun.py's compressed multi-pod variant).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ef_compress_tree",
           "compressed_psum_tree"]


def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, errors):
    """Quantize (grad + carried error) per leaf; returns (q, scales, new_err).

    new_err = (g + e) - dequant(q)   — the residual fed back next step."""

    def leaf(g, e):
        x = g.astype(jnp.float32) + e
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        return q, s, x - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    qs = tdef.unflatten([o[0] for o in out])
    scales = tdef.unflatten([o[1] for o in out])
    new_err = tdef.unflatten([o[2] for o in out])
    return qs, scales, new_err


def dp_compressed_step_fn(cfg, optimizer, mesh, n_pods: int,
                          pod_axis: str = "pod"):
    """Build a jit-able multi-pod train step whose *cross-pod* gradient sync
    is error-feedback int8 compressed.

    Pods replicate parameters (DP across pods); inside the ``shard_map`` over
    ``pod`` the data/model axes remain auto-partitioned, so in-pod FSDP/TP is
    unchanged — only the inter-pod wire format changes (4x fewer bytes on the
    slow links).  State: carries the per-leaf error-feedback residuals.

    Returns (step, init_errors) with
    ``step(params, opt_state, errors, batch) -> (params, opt_state, errors,
    loss)``.
    """
    import jax.numpy as _jnp
    from jax.sharding import PartitionSpec as P

    from ..models import lm

    def local_step(params, opt_state, errors, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, batch))(params)
        grads, errors = compressed_psum_tree(grads, errors, pod_axis, n_pods)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, errors, loss

    def init_errors(params):
        return jax.tree.map(lambda p: _jnp.zeros(p.shape, _jnp.float32),
                            params)

    def specs_for(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def make(params_like, opt_like, batch_like):
        rep = P()
        return jax.jit(jax.shard_map(
            local_step, mesh=mesh,
            in_specs=(specs_for(params_like, rep), specs_for(opt_like, rep),
                      specs_for(params_like, rep),
                      specs_for(batch_like, P(pod_axis))),
            out_specs=(specs_for(params_like, rep), specs_for(opt_like, rep),
                       specs_for(params_like, rep), P()),
            check_vma=False, axis_names=frozenset({pod_axis})))

    return make, init_errors


def compressed_psum_tree(grads, errors, axis_name: str, n_pods: int):
    """Error-feedback compressed mean over ``axis_name``.

    Returns (synced_grads, new_errors).  int8 payloads are summed in int32
    across pods; scales (one f32 per leaf) are gathered alongside.  Each pod
    applies its own scale before the sum would be exact; summing q*s_local
    requires per-pod scales, so we all-gather the scalar scales (negligible)
    and sum dequantized shards — the *wire* payload is still the int8 tensor.
    """
    qs, scales, new_err = ef_compress_tree(grads, errors)

    def sync(q, s):
        # all-gather per-pod scales (scalars), psum int8 payload per scale
        # bucket: implemented as psum of (q * onehot) per pod in int32 then
        # scale-weighted sum.  For equal scales this is exactly psum(q)*s/n.
        s_all = jax.lax.all_gather(s, axis_name)              # [n_pods]
        idx = jax.lax.axis_index(axis_name)
        acc = jnp.zeros(q.shape, jnp.float32)
        q32 = q.astype(jnp.int32)
        for p in range(n_pods):
            contrib = jnp.where(idx == p, q32, 0)
            summed = jax.lax.psum(contrib, axis_name)         # int32 wire
            acc = acc + summed.astype(jnp.float32) * s_all[p]
        return acc / n_pods

    synced = jax.tree.map(sync, qs, scales)
    return synced, new_err
