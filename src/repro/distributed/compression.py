"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

Pods replicate parameters (DP across pods), so per-step gradient sync crosses
the slow inter-pod links once per parameter.  ``compressed_psum`` quantizes
each gradient leaf to int8 with a per-leaf scale, all-reduces the int8 payload
(as int32 accumulation), dequantizes, and keeps the quantization residual as
*error feedback* added to the next step's gradient — the standard EF-SGD
construction (1-bit Adam / EF21 lineage) that preserves convergence.

Payload crossing the pod links: 1 byte/param instead of 4 — a 4x cut of the
collective term on the pod axis.  Used inside a ``shard_map`` over the
``pod`` axis (see launch/dryrun.py's compressed multi-pod variant).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ef_compress_tree",
           "compressed_psum_tree"]


def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, errors):
    """Quantize (grad + carried error) per leaf; returns (q, scales, new_err).

    new_err = (g + e) - dequant(q)   — the residual fed back next step."""

    def leaf(g, e):
        x = g.astype(jnp.float32) + e
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        return q, s, x - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    qs = tdef.unflatten([o[0] for o in out])
    scales = tdef.unflatten([o[1] for o in out])
    new_err = tdef.unflatten([o[2] for o in out])
    return qs, scales, new_err


def dp_compressed_step_fn(cfg, optimizer, mesh, n_pods: int,
                          pod_axis: str = "pod"):
    """Build a jit-able multi-pod train step whose *cross-pod* gradient sync
    is error-feedback int8 compressed.

    Pods replicate parameters (DP across pods).  The pod axis is expressed
    as a stacked leading dimension — the global batch reshapes to
    ``[n_pods, B/n_pods, ...]`` and a ``vmap`` computes per-pod gradients —
    so the whole step lowers under plain GSPMD (in-pod FSDP/TP via the
    data/model axes is untouched; manual-subgroup shard_map around a full
    transformer does not partition on the pinned toolchain).  With the
    stacked axis sharded over ``pod``, the only collective crossing the
    slow inter-pod links is the int32 reduce-sum of the quantized stack:
    1 byte/param on the wire instead of 4.  State: per-pod error-feedback
    residuals (stacked leaves ``[n_pods, ...]``).

    Returns (step, init_errors) with jitted
    ``step(params, opt_state, errors, batch) -> (params, opt_state, errors,
    loss)``.
    """
    import jax.numpy as _jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..models import lm

    def _pod_spec(x):
        return NamedSharding(mesh, P(pod_axis, *([None] * (x.ndim - 1))))

    def _on_pods(tree):
        """Pin each leaf's stacked [n_pods, ...] axis to the pod mesh axis,
        making the cross-pod wire format below real, not just notation."""
        return jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(x, _pod_spec(x)), tree)

    def step(params, opt_state, errors, batch):
        mbs = _on_pods(jax.tree.map(
            lambda x: x.reshape((n_pods, x.shape[0] // n_pods) + x.shape[1:]),
            batch))

        def pod_grads(mb):
            return jax.value_and_grad(
                lambda p: lm.loss_fn(p, cfg, mb))(params)

        losses, pgrads = jax.vmap(pod_grads)(mbs)   # leaves [n_pods, ...]

        def sync(gstack, estack):
            x = gstack.astype(_jnp.float32) + estack
            s = _jnp.max(_jnp.abs(x)) / 127.0 + 1e-12   # pod-shared scale
            q = _jnp.clip(_jnp.round(x / s), -127, 127).astype(_jnp.int8)
            q = jax.lax.with_sharding_constraint(q, _pod_spec(q))
            new_e = x - q.astype(_jnp.float32) * s
            summed = _jnp.sum(q.astype(_jnp.int32), 0)  # int32 cross-pod wire
            return summed.astype(_jnp.float32) * s / n_pods, new_e

        flat_g, tdef = jax.tree_util.tree_flatten(pgrads)
        flat_e = tdef.flatten_up_to(errors)
        out = [sync(g, e) for g, e in zip(flat_g, flat_e)]
        grads = tdef.unflatten([o[0] for o in out])
        errors = tdef.unflatten([o[1] for o in out])
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, errors, losses.mean()

    def init_errors(params):
        return jax.tree.map(
            lambda p: _jnp.zeros((n_pods,) + p.shape, _jnp.float32), params)

    return jax.jit(step), init_errors


def compressed_psum_tree(grads, errors, axis_name: str, n_pods: int):
    """Error-feedback compressed mean over ``axis_name``.

    Returns (synced_grads, new_errors).  Each leaf quantizes against a
    *pod-shared* scale (``pmax`` of the local scales — one scalar AllReduce),
    so the int8 payloads sum exactly: one int32-accumulated ``psum`` per leaf
    is the whole sync, and the wire payload is 1 byte/param plus a scalar.
    Only AllReduce-shaped collectives appear — ``axis_index``/``all_gather``
    lower to PartitionId / manual-subgroup reshards that partial-auto
    shard_map (in-pod axes left to GSPMD) cannot partition.  The residual
    against the shared-scale dequantization is carried as error feedback.
    """

    def sync(g, e):
        x = g.astype(jnp.float32) + e
        s_local = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
        s = jax.lax.pmax(s_local, axis_name)                  # shared scale
        q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
        new_e = x - q.astype(jnp.float32) * s
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)  # int32 wire
        return summed.astype(jnp.float32) * s / n_pods, new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [sync(g, e) for g, e in zip(flat_g, flat_e)]
    synced = tdef.unflatten([o[0] for o in out])
    new_err = tdef.unflatten([o[1] for o in out])
    return synced, new_err
