"""Sharding rules: parameter/activation PartitionSpecs for the production
meshes.

Logical placement:
  * TP ("model"): attention heads / head_dim, FFN hidden, vocab, experts.
  * FSDP ("data"): the other matrix dimension of every large parameter.
  * DP: batch over ("pod", "data") — pods replicate parameters, so the
    gradient all-reduce crossing the (slow) pod links touches each parameter
    once, and is the hook for gradient compression.
  * SP/CP: when the per-cell batch is smaller than the data axis (long_500k,
    batch=1), activations and KV caches shard their *sequence* axis over
    "data" instead; GSPMD inserts the split-K softmax collectives.

Every rule is divisibility-checked against the actual dimension; a
non-divisible axis falls back to replication for that dim (reported by
``explain``), so lowering never fails on an odd head count.
"""

from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_pspecs", "batch_pspecs", "cache_pspecs", "shardings_for",
           "explain", "pane_bucket_shards", "pane_batch_pspecs",
           "shard_pane_bucket"]

# (path regex, spec template) — templates name logical axes per dim;
# first match wins.  "tp" -> model, "fsdp" -> data, None -> replicate.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("tp", "fsdp")),
    (r"lm_head$", ("fsdp", "tp")),
    (r"(final_norm|ln\w*|.*norm|post_ln\d)$", (None,)),
    (r"attn/w[qkv]$", ("fsdp", "tp")),
    (r"attn/wo$", ("tp", "fsdp")),
    (r"(attn|cross)/[qk]_norm$", (None,)),
    (r"cross/w[qkv]$", ("fsdp", "tp")),
    (r"cross/wo$", ("tp", "fsdp")),
    (r"mlp/w_(gate|up)$", ("fsdp", "tp")),
    (r"mlp/w_down$", ("tp", "fsdp")),
    (r"moe/router$", ("fsdp", None)),
    (r"moe/w_(gate|up)$", ("tp", "fsdp", None)),   # experts over model (EP)
    (r"moe/w_down$", ("tp", None, "fsdp")),
    (r"moe/shared/w_(gate|up)$", ("fsdp", "tp")),
    (r"moe/shared/w_down$", ("tp", "fsdp")),
    (r"mamba/in_proj$", ("fsdp", "tp")),
    (r"mamba/out_proj$", ("tp", "fsdp")),
    (r"mamba/conv_w$", (None, "tp")),
    (r"mamba/(A_log|D|dt_bias)$", (None,)),
    (r"rwkv/w[rkvgo]$", ("fsdp", "tp")),
    (r"rwkv/w0$", (None,)),
    (r"rwkv/w1$", ("fsdp", None)),
    (r"rwkv/w2$", (None, "fsdp")),
    (r"rwkv/u$", (None, None)),
    (r"rwkv/mu$", (None, None)),
    (r"rwkv/cmu$", (None, None)),
    (r"rwkv/ck$", ("fsdp", "tp")),
    (r"rwkv/cv$", ("tp", "fsdp")),
    (r"rwkv/cr$", ("fsdp", "tp")),
    (r".*", (None,)),
]


def _axis_name(logical: str | None, mesh: Mesh) -> str | None:
    if logical is None:
        return None
    if logical == "tp":
        return "model" if "model" in mesh.axis_names else None
    if logical == "fsdp":
        return "data" if "data" in mesh.axis_names else None
    raise ValueError(logical)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _spec_for(path: str, shape: tuple[int, ...], mesh: Mesh,
              notes: list | None = None) -> P:
    # scan-stacked params have a leading group axis: detect via rule arity
    for pat, template in _PARAM_RULES:
        if re.search(pat, path):
            extra = len(shape) - len(template)
            dims: list[str | None] = [None] * max(0, extra) + list(template)
            dims = dims[: len(shape)]
            out = []
            for dim, logical in zip(shape, dims):
                ax = _axis_name(logical, mesh)
                if ax is not None and dim % mesh.shape[ax] != 0:
                    if notes is not None:
                        notes.append((path, shape, logical,
                                      f"{dim} % {mesh.shape[ax]} != 0"))
                    ax = None
                out.append(ax)
            return P(*out)
    return P()


def param_pspecs(params_tree, mesh: Mesh, notes: list | None = None):
    """PartitionSpec pytree for a parameter (or optimizer-state) pytree.
    Works on pytrees of arrays or ShapeDtypeStructs."""

    def f(path, leaf):
        return _spec_for(_path_str(path), leaf.shape, mesh, notes)

    return jax.tree_util.tree_map_with_path(f, params_tree)


def batch_pspecs(batch_tree, mesh: Mesh, *, global_batch: int):
    """Input-batch specs: batch over (pod, data) when divisible, otherwise
    sequence over data (context parallelism for long_500k)."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = int(np.prod([mesh.shape[a] for a in dp_axes]))

    def f(path, leaf):
        p = _path_str(path)
        shape = leaf.shape
        if p.endswith("positions"):          # [3, B, S]
            if global_batch % dp == 0:
                return P(None, dp_axes, None)
            return P(None, None, "data")
        if p.endswith("pos"):                # [B]
            if global_batch % dp == 0:
                return P(dp_axes)
            return P(None)
        if len(shape) >= 2 and shape[0] == global_batch and global_batch % dp == 0:
            return P(dp_axes, *([None] * (len(shape) - 1)))
        if len(shape) >= 2 and shape[1] % mesh.shape.get("data", 1) == 0:
            # batch too small: shard the sequence axis (CP)
            return P(None, "data", *([None] * (len(shape) - 2)))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(f, batch_tree)


def cache_pspecs(cache_tree, mesh: Mesh, *, batch: int):
    """Decode-state specs.  K/V caches [.., B, S, KV, hd]: batch over
    (pod,data) when divisible, else sequence over data; head_dim over model
    (always divisible for the assigned pool).  Recurrent states shard their
    head axis."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = int(np.prod([mesh.shape[a] for a in dp_axes]))
    data = mesh.shape.get("data", 1)
    model = mesh.shape.get("model", 1)

    def f(path, leaf):
        p = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        if p.endswith("pos"):                # ring positions [.., B, span]
            lead = [None] * (nd - 2)
            if batch % dp == 0:
                return P(*lead, dp_axes, None)
            return P(*lead, None, "data" if shape[-1] % data == 0 else None)
        if re.search(r"(^|/)(x?[kv])$", p) and nd >= 4:
            # split-K decode layout: the cache *sequence* axis shards over
            # "model" (and over "data" too when the batch cannot), so
            # attention reduces over local KV slices and combines partial
            # softmax statistics with tiny all-reduces — the KV cache is
            # never gathered.
            lead = [None] * (nd - 4)
            b, s, kv, hd = shape[-4:]
            if batch % dp == 0:
                s_ax = "model" if s % model == 0 else None
                return P(*lead, dp_axes, s_ax, None, None)
            if s % (data * model) == 0:
                return P(*lead, None, ("data", "model"), None, None)
            s_ax = "data" if s % data == 0 else None
            return P(*lead, None, s_ax, None, None)
        if "mamba_state" in p or "rwkv_state" in p:
            lead: list = [None] * nd
            # find the batch axis: first dim equal to batch
            for i, d in enumerate(shape):
                if d == batch and batch % dp == 0:
                    lead[i] = dp_axes
                    break
            else:
                # shard the head axis over data instead (B too small)
                for i, d in enumerate(shape):
                    if i >= nd - 3 and d % data == 0 and d != batch:
                        lead[i] = "data"
                        break
            return P(*lead)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(f, cache_tree)


def shardings_for(tree_of_pspecs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def explain(params_tree, mesh: Mesh) -> list:
    """Return the list of (path, shape, logical_axis, reason) fallbacks."""
    notes: list = []
    param_pspecs(params_tree, mesh, notes)
    return notes


# --------------------------------------------------------------------------
# pane-batch sharding hooks (engine's bucketed propagation launches)
# --------------------------------------------------------------------------


def pane_bucket_shards(nb: int, n_shards: int) -> list[slice]:
    """Balanced contiguous slices splitting a pane bucket's batch axis.

    The engine's :class:`~repro.core.batch_exec.PaneBatchExecutor` takes
    this (partially applied over ``n_shards``) as its ``shard_slices`` hook:
    each returned slice becomes its own launch, so one size bucket of burst
    jobs can spread across devices or hosts.  Empty shards are elided —
    ``nb < n_shards`` yields ``nb`` singleton slices.
    """
    if nb <= 0:
        return []
    n_shards = max(1, min(int(n_shards), nb))
    cuts = np.linspace(0, nb, n_shards + 1).round().astype(int)
    return [slice(int(a), int(b)) for a, b in zip(cuts[:-1], cuts[1:])
            if b > a]


def pane_batch_pspecs(mesh: Mesh, ndim: int = 3) -> P:
    """PartitionSpec for a stacked pane bucket ``[nb, b, d]`` (or mask
    ``[nb, b, b]``): the batch-of-bursts axis shards over the data-parallel
    mesh axes; burst rows and basis columns stay local to the device."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    lead = dp_axes if dp_axes else None
    return P(lead, *([None] * (ndim - 1)))


def shard_pane_bucket(arr, mesh: Mesh):
    """device_put a stacked pane bucket with its batch axis split across the
    mesh (pad the leading axis to a multiple of the dp size upstream)."""
    return jax.device_put(
        arr, NamedSharding(mesh, pane_batch_pspecs(mesh, np.ndim(arr))))
