"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

The production meshes for this assignment are (data, model) / (pod, data,
model); PP is provided as an optional axis for deployments that prefer
pipeline over pure FSDPxTP (e.g. cross-pod stages).  Implementation:
``shard_map`` over ``stage`` — each stage holds a slice of the layer stack
(params sharded with P("stage") on the stacked-layer axis), microbatches
stream through stages with ``jax.lax.ppermute`` boundary transfers in a
classic GPipe schedule of ``n_micro + n_stages - 1`` ticks.

Numerically equivalent to running the full stack sequentially (tested on a
forced multi-device host in tests/test_distributed.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["pipelined_apply", "sequential_apply"]


def sequential_apply(layer_fn, stacked_params, x):
    """Reference: apply all L stacked layers in order.  x [B, ...]."""

    def body(h, p):
        return layer_fn(p, h), None

    h, _ = jax.lax.scan(body, x, stacked_params)
    return h


def pipelined_apply(layer_fn, stacked_params, x, *, mesh: Mesh,
                    n_micro: int, stage_axis: str = "stage",
                    layers_per_stage: int | None = None):
    """GPipe forward over the ``stage`` axis of ``mesh``.

    stacked_params: pytree with leading layer axis L = n_stages * per_stage.
    x: [B, ...] with B % n_micro == 0.
    """
    n_stages = mesh.shape[stage_axis]
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    assert L % n_stages == 0, (L, n_stages)
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro

    def stage_fn(params_stage, x_all):
        # params_stage: this stage's [L/n_stages, ...] slice (via shard_map)
        sid = jax.lax.axis_index(stage_axis)
        n_ticks = n_micro + n_stages - 1
        out = jnp.zeros_like(x_all)
        carry = jnp.zeros((mb,) + x_all.shape[1:], x_all.dtype)

        def tick(t, state):
            out, carry = state
            # stage 0 ingests microbatch t (if within range)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            fresh = jax.lax.dynamic_slice_in_dim(x_all, mb_idx * mb, mb, 0)
            h = jnp.where(sid == 0, fresh, carry)

            def body(hh, p):
                return layer_fn(p, hh), None

            h, _ = jax.lax.scan(body, h, params_stage)
            # last stage emits microbatch (t - n_stages + 1)
            emit_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = (sid == n_stages - 1) & (t >= n_stages - 1)
            cur = jax.lax.dynamic_slice_in_dim(out, emit_idx * mb, mb, 0)
            upd = jnp.where(emit, h, cur)
            out = jax.lax.dynamic_update_slice_in_dim(out, upd, emit_idx * mb, 0)
            # shift activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            carry = jax.lax.ppermute(h, stage_axis, perm)
            return out, carry

        out, _ = jax.lax.fori_loop(0, n_ticks, tick, (out, carry))
        # only the last stage holds results; others contribute zeros
        return jax.lax.psum(out, stage_axis)

    pspec_params = jax.tree.map(lambda _: P(stage_axis), stacked_params)
    from .compat import shard_map

    f = shard_map(stage_fn, mesh=mesh,
                  in_specs=(pspec_params, P()),
                  out_specs=P(), check_vma=False)
    return f(stacked_params, x)
