"""Sharded, mesh-elastic checkpointing (no orbax/tensorstore dependency).

Layout: one directory per step containing ``leaf_<i>.npy`` files plus
``index.json`` (tree structure, dtypes, shapes, step metadata) and a final
``COMMITTED`` marker — a crash mid-write never yields a readable-but-corrupt
checkpoint.  Restore takes the *live* mesh + shardings and ``device_put``s
each leaf, so a checkpoint written on a 512-chip mesh restores onto 256 chips
(or one CPU) unchanged: this is the elastic-rescale path after losing a pod.

Writes can be asynchronous (background thread) so the train loop overlaps
checkpoint I/O with compute; ``wait()`` joins before the next save or exit.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def save_checkpoint(directory: str, step: int, tree, *, blocking=True,
                    on_commit=None):
    path = os.path.join(directory, f"step_{step:010d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    host = [np.asarray(l) for l in leaves]

    def write():
        meta = {"step": step, "n_leaves": len(host),
                "treedef": str(treedef),
                "dtypes": [str(h.dtype) for h in host],
                "shapes": [list(h.shape) for h in host]}
        for i, h in enumerate(host):
            # exotic dtypes (bfloat16 et al.) are stored as raw bytes; the
            # true dtype lives in index.json
            raw = h.view(np.uint8) if h.dtype.kind == "V" or \
                h.dtype.name not in np.sctypeDict else h
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), raw)
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
        if on_commit is not None:
            on_commit()

    if blocking:
        write()
        return None
    # non-daemon: an async save must be joined (CheckpointManager.wait /
    # close), never abandoned to interpreter teardown mid-write — the
    # COMMITTED-marker protocol makes a torn write unreadable, but the
    # join guarantees the final checkpoint of a run actually lands
    t = threading.Thread(target=write, name="ckpt-write")
    t.start()
    return t


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "COMMITTED")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; if ``shardings`` (same
    structure) is given, leaves are device_put with those shardings —
    resharding onto whatever mesh is live."""
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "index.json")) as f:
        meta = json.load(f)
    leaves, treedef = _flatten(like_tree)
    assert meta["n_leaves"] == len(leaves), "checkpoint/tree mismatch"
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(path, f"leaf_{i}.npy"))
        want = _np_dtype(meta["dtypes"][i])
        if arr.dtype != want:
            arr = arr.view(want)
        arr = arr.reshape(meta["shapes"][i])
        assert list(arr.shape) == list(ref.shape), \
            f"leaf {i}: {arr.shape} != {ref.shape}"
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Periodic async checkpointing with retention."""

    def __init__(self, directory: str, interval: int = 100, keep: int = 3):
        self.directory = directory
        self.interval = interval
        self.keep = keep
        self._pending: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def maybe_save(self, step: int, tree) -> bool:
        if step % self.interval != 0:
            return False
        self.wait()
        self._pending = save_checkpoint(self.directory, step, tree,
                                        blocking=False, on_commit=self._gc)
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def close(self):
        """Join any in-flight async save (idempotent); use at run end or
        via the context-manager form."""
        self.wait()

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    def restore_latest(self, like_tree, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore_checkpoint(self.directory, step, like_tree,
                                        shardings)
