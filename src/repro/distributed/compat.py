"""Version compatibility shims for the distributed substrate.

``jax.shard_map`` became a top-level API (with ``check_vma`` /
``axis_names``) well after the ``jax.experimental.shard_map`` original
(``check_rep`` / ``auto``).  The toolchain pin floats across that boundary,
so every shard_map call in this package goes through :func:`shard_map`,
which translates the new-style keywords onto whichever implementation the
installed jax provides.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names=None):
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {"check_rep": check_vma}
    if axis_names is not None:
        # old API names the *auto* (un-mapped) axes instead
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs, **kw)
