"""Distribution substrate: sharding rules (DP/TP/EP/SP/CP + pod axis),
sharded elastic checkpointing, fault-tolerant training, error-feedback
gradient compression, and a GPipe-style pipeline option."""

from .sharding import param_pspecs, batch_pspecs, cache_pspecs  # noqa: F401
