"""Pallas TPU kernel for HAMLET's masked prefix propagation.

Solves (I - L) C = B per batch element, where L is strictly lower triangular
(the within-pane predecessor adjacency) and the columns of C are snapshot
coefficients (shared execution) or per-query channels (non-shared execution).

TPU-native formulation (see DESIGN.md §2): rows are processed in tiles of
``tile`` (default 128, MXU-aligned).  For row tile ``r``:

    y_r = B_r + L[r, :] @ C_acc          (cross-tile contribution; one matmul
                                          against the VMEM-resident running C)
    C_r = (I - L_rr)^(-1) y_r            (in-tile solve)

The in-tile solve uses the nilpotency of the strictly-lower-triangular block:
(I - L)^(-1) = prod_i (I + L^(2^i)), realised as log2(tile) rounds of
``c += P @ c; P = P @ P`` — dense MXU matmuls instead of a length-``tile``
sequential dependence chain.  The running solution C_acc lives in a VMEM
scratch buffer that persists across the sequential grid.

Grid: (batch, row_tiles); scratch is re-zeroed at row tile 0 of every batch
element.  Validated in interpret mode on CPU against ``ref.py``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params

__all__ = ["masked_prefix_propagate_pallas"]


def _propagate_kernel(base_ref, mask_ref, out_ref, acc_ref, *, tile: int,
                      n_iters: int, acc_dtype):
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():  # fresh batch element: clear the running solution
        acc_ref[...] = jnp.zeros_like(acc_ref)

    base = base_ref[0].astype(acc_dtype)          # [T, d]
    stripe = mask_ref[0].astype(acc_dtype)        # [T, b]

    # Cross-tile contribution.  Rows >= r*tile of acc are still zero, so the
    # full-width matmul only picks up previously solved tiles.
    y = base + jnp.dot(stripe, acc_ref[...], preferred_element_type=acc_dtype)

    # In-tile Neumann-doubling solve with the diagonal block.
    # (r * 0 keeps both indices in program_id's int32 under jax x64.)
    L = jax.lax.dynamic_slice(stripe, (r * 0, r * tile), (tile, tile))
    c = y
    P = L
    for it in range(n_iters):
        c = c + jnp.dot(P, c, preferred_element_type=acc_dtype)
        if it + 1 < n_iters:
            P = jnp.dot(P, P, preferred_element_type=acc_dtype)

    acc_ref[pl.dslice(r * tile, tile), :] = c
    out_ref[0] = c.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def masked_prefix_propagate_pallas(base: jax.Array, mask: jax.Array, *,
                                   tile: int = 128,
                                   interpret: bool = True) -> jax.Array:
    """Batched masked prefix propagation.

    base : [nb, b, d]  injection rows (b and d already padded: b % tile == 0)
    mask : [nb, b, b]  strictly lower triangular adjacency
    returns [nb, b, d] with c[i] = base[i] + sum_{j<i} mask[i,j] c[j].
    """
    nb, b, d = base.shape
    if b % tile:
        raise ValueError(f"b={b} must be a multiple of tile={tile}")
    if mask.shape != (nb, b, b):
        raise ValueError(f"mask shape {mask.shape} != {(nb, b, b)}")
    n_tiles = b // tile
    n_iters = max(1, math.ceil(math.log2(tile)))
    if jnp.issubdtype(base.dtype, jnp.integer):
        acc_dtype = jnp.int32
    elif base.dtype == jnp.float64:
        acc_dtype = jnp.float64   # interpret/CPU only; TPU uses f32
    else:
        acc_dtype = jnp.float32

    kernel = functools.partial(_propagate_kernel, tile=tile, n_iters=n_iters,
                               acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=(nb, n_tiles),
        in_specs=[
            pl.BlockSpec((1, tile, d), lambda bi, r: (bi, r, 0)),
            pl.BlockSpec((1, tile, b), lambda bi, r: (bi, r, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile, d), lambda bi, r: (bi, r, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, b, d), base.dtype),
        scratch_shapes=[pltpu.VMEM((b, d), acc_dtype)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(base, mask)
