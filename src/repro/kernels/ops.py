"""jit'd public wrappers around the propagation primitive.

``propagate(base, mask, backend=...)`` pads shapes to kernel tiles, dispatches
to the numpy oracle / jnp reference / Pallas kernel, and unpads.  The engine
uses ``backend="np"`` for small host-side bursts and the accelerator backends
for large panes; the dry-run lowers the jnp/pallas paths on the production
mesh.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .hamlet_propagate import masked_prefix_propagate_pallas

__all__ = ["propagate", "propagate_batched", "propagate_dense",
           "propagate_dense_batched", "fold_stacked", "fold_rounds_scan",
           "device_get_all", "PROPAGATE_BACKENDS", "DENSE_B_MAX"]

# largest burst the dense closed form handles exactly (2^b weight range);
# the engine's dense-eligibility test and the executor's fallback share it
DENSE_B_MAX = 512

PROPAGATE_BACKENDS = ("np", "jax", "jax_blocked", "jax_solve", "pallas")

_LANE = 128


def _pad_to(x: np.ndarray | jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def _pallas_padded(base, mask, tile, interpret):
    base, b = _pad_to(base, 1, tile)
    base, d = _pad_to(base, 2, _LANE)
    mask, _ = _pad_to(mask, 1, tile)
    mask, _ = _pad_to(mask, 2, tile)
    out = masked_prefix_propagate_pallas(base, mask, tile=tile, interpret=interpret)
    return out[:, :b, :d]


def propagate_batched(base, mask, *, backend: str = "np", tile: int = 128,
                      interpret: bool = True):
    """Batched propagation: base [nb, b, d], mask [nb, b, b] -> [nb, b, d].

    The batch is ragged-friendly at the edges: ``nb == 0`` returns an empty
    result, and zero-padded trailing rows (zero mask rows/columns) propagate
    to zeros without touching real rows, so callers may pad within a bucket.
    """
    if np.shape(base)[0] == 0:
        return (np.zeros(np.shape(base), dtype=np.asarray(base).dtype)
                if backend == "np"
                else jnp.zeros(np.shape(base), dtype=jnp.asarray(base).dtype))
    if backend == "np":
        base = np.asarray(base)
        mask = np.asarray(mask)
        fast = (base.shape[1] > 24 and
                not np.issubdtype(base.dtype, np.integer))
        if fast:
            # one stacked doubling sweep — slices are bitwise equal to the
            # per-item call (see ref.numpy_prefix_propagate_fast_batched)
            return ref.numpy_prefix_propagate_fast_batched(base, mask)
        return np.stack([ref.numpy_prefix_propagate(base[i], mask[i])
                         for i in range(base.shape[0])])
    if backend == "jax":
        return jax.vmap(ref.masked_prefix_propagate_ref)(jnp.asarray(base),
                                                         jnp.asarray(mask))
    if backend == "jax_blocked":
        base = jnp.asarray(base)
        mask = jnp.asarray(mask)
        b = base.shape[1]
        tile = 128 if b % 128 == 0 else b
        return jax.vmap(lambda bb, mm: ref.masked_prefix_propagate_blocked(
            bb, mm, tile=tile))(base, mask)
    if backend == "jax_solve":
        return jax.vmap(ref.masked_prefix_propagate_solve)(jnp.asarray(base),
                                                           jnp.asarray(mask))
    if backend == "pallas":
        return _pallas_padded(jnp.asarray(base), jnp.asarray(mask), tile, interpret)
    raise ValueError(f"unknown backend {backend!r}; use one of {PROPAGATE_BACKENDS}")


def device_get_all(arrays: list) -> list[np.ndarray]:
    """Fetch many (possibly device-resident) arrays with **one** host sync.

    The pane-batch executor launches every bucket of a flush before pulling
    any result back, then converts the whole backlog here: on the jax/pallas
    backends this is a single ``jax.device_get`` over the list (results stay
    device-resident until this point), instead of one blocking
    ``np.asarray`` round trip per bucket per pane.  Pure-numpy inputs pass
    through untouched.
    """
    if not arrays:
        return []
    if all(isinstance(a, np.ndarray) for a in arrays):
        return list(arrays)
    return [np.asarray(a) for a in jax.device_get(list(arrays))]


def fold_stacked(u0, Ms, *, backend: str = "np"):
    """Stacked window-chain fold: ``u0 [N, C]``, ``Ms [N, n, C, C]`` ->
    ``[N, C]``.

    Slice ``i`` applies the chain ``u = u @ M.T`` over ``Ms[i, 0..n)`` in
    order — the :func:`repro.core.engine.fold_panes` recurrence — so each
    slice is bitwise equal to the per-window fold (the same stacked-matmul
    twin convention as ``propagate_batched``).  One call folds a whole
    bucket of same-length windows: a revision storm re-folds every dirty
    window with ``n`` launches instead of ``n`` per window.

    On the jax backends the result stays device-resident; callers batch
    several buckets and resolve them with **one** :func:`device_get_all`
    sync (see ``core/fold_exec.py``).
    """
    n = np.shape(Ms)[1] if np.ndim(Ms) >= 2 else 0
    if backend == "np":
        U = np.asarray(u0)
        Ms = np.asarray(Ms)
        with np.errstate(over="ignore", invalid="ignore"):
            for j in range(n):
                U = np.matmul(U[:, None, :],
                              np.swapaxes(Ms[:, j], 1, 2))[:, 0]
        return U
    U = jnp.asarray(u0)
    if n == 0:
        return U
    # one compiled lax.scan over the window axis instead of n Python-level
    # matmul dispatches — the whole chain is a single device program whose
    # per-round body is the identical jnp matmul (bitwise equal to the
    # eager per-round loop; see tests/test_fold_scan.py)
    return _fold_stacked_scan(U, jnp.swapaxes(jnp.asarray(Ms), 0, 1))


@jax.jit
def _fold_stacked_scan(U, Ms_t):
    """``u = u @ M.T`` chain as one scanned program; ``Ms_t [n, N, C, C]``."""

    def step(u, M):
        return jnp.matmul(u[:, None, :], jnp.swapaxes(M, 1, 2))[:, 0], None

    u, _ = jax.lax.scan(step, U, Ms_t)
    return u


@functools.partial(jax.jit, static_argnames=("nu", "t", "n_used"))
def fold_rounds_scan(Z0, S, PTM, GQ, SIDX, SC, ER, *, nu, t, n_used):
    """Whole warm fold-flush as **one** device program (see fold_exec.py).

    Executes every d == 0 fold round of a flush with a single
    ``jax.lax.scan`` whose carry is the fused flat state ``Zf
    [J*k*R + 1, C]`` (row ``J*k*R`` is a scratch row absorbing padded
    lanes).  Per round the body runs the exact stacked-twin ops of
    ``FoldExecutor._fold_bucket_fast``: one state gather, the ``W`` build
    matmul, one ``S`` gather, the update matmul, and two scatter-adds
    (arow targets + rrow/end targets).  All index operands are
    precomputed per flush plan and device-resident:

    * ``S    [G*n_used + 1, B_local]`` — per-group column-sum rows, last
      row zeros (padded lanes);
    * ``PTM  [rounds, NMAX, t]``       — pt_mask rows, padded zero;
    * ``GQ   [rounds, NMAX, R]``       — flat state gather rows (padded →
      scratch);
    * ``SIDX [rounds, NMAX, n_used]``  — rows into ``S`` (padded → zeros
      row);
    * ``SC / ER [rounds, NMAX * n_used]`` — scatter rows (padded /
      non-end → scratch).

    Padded lanes read the scratch row and write back only to the scratch
    row / zero ``S`` row, so real state rows never see padding artifacts
    even in the inf/NaN overflow regime.  Within a round the real scatter
    targets are query-disjoint by level construction, so the accumulation
    is order-free.
    """
    C = Z0.shape[1]

    def step(Zf, xs):
        gq, sidx, sc, er, ptm = xs
        zm = Zf[gq]                                       # [NMAX, R, C]
        Wu = jnp.matmul(ptm[:, None, None, :],
                        zm[:, 1:1 + nu * t].reshape(-1, nu, t, C))[:, :, 0, :]
        W = jnp.concatenate([zm[:, 0:1], Wu], axis=1)     # [NMAX, 1+nu, C]
        S_m = S[sidx]                                     # [NMAX, n_used, 1+nu]
        upd = jnp.matmul(S_m, W).reshape(-1, C)
        Zf = Zf.at[sc].add(upd)
        return Zf.at[er].add(upd), None

    Zf, _ = jax.lax.scan(step, Z0, (GQ, SIDX, SC, ER, PTM))
    return Zf


def propagate(base, mask, *, backend: str = "np", tile: int = 128,
              interpret: bool = True):
    """Unbatched propagation: base [b, d], mask [b, b] -> [b, d]."""
    out = propagate_batched(base[None], mask[None], backend=backend, tile=tile,
                            interpret=interpret)
    return out[0]


def propagate_dense(base, *, backend: str = "np"):
    """Propagation for a *dense* burst (strictly-lower all-ones adjacency —
    no edge predicates, no divergent/dead rows): closed form in O(b*d)
    via exponentially weighted cumsum (paper Table 3's doubling).  Falls
    back to the masked path for b > 512 (weight range)."""
    b = base.shape[0]
    if b > DENSE_B_MAX:
        mask = np.tril(np.ones((b, b)), k=-1)
        return propagate(base, mask, backend=backend)
    if backend == "np":
        return ref.prefix_propagate_dense_np(np.asarray(base))
    return ref.prefix_propagate_dense(jnp.asarray(base))


def propagate_dense_batched(base, *, backend: str = "np", tile: int = 64,
                            interpret: bool = True):
    """Batched dense-burst propagation: base [nb, b, d] -> [nb, b, d].

    One launch for a whole size bucket of dense bursts.  ``nb == 0`` returns
    an empty result; trailing zero-padded rows/columns are safe (each real
    row's prefix is unchanged), so ragged buckets pad to a common shape.
    Requires b <= DENSE_B_MAX per burst (the dense weight range) — the
    engine's planner routes larger bursts to the masked path.
    """
    nb, b, d = np.shape(base)
    if b > DENSE_B_MAX:
        raise ValueError(
            f"dense closed form needs b <= {DENSE_B_MAX}, got {b}")
    if nb == 0:
        return (np.zeros((0, b, d), dtype=np.asarray(base).dtype)
                if backend == "np"
                else jnp.zeros((0, b, d), dtype=jnp.asarray(base).dtype))
    if backend == "np":
        return ref.prefix_propagate_dense_np_batched(np.asarray(base))
    if backend in ("jax", "jax_blocked", "jax_solve"):
        return jax.vmap(ref.prefix_propagate_dense)(jnp.asarray(base))
    if backend == "pallas":
        from .hamlet_dense import dense_propagate_pallas

        x = jnp.asarray(base)
        x, b_real = _pad_to(x, 1, tile)
        x, d_real = _pad_to(x, 2, _LANE)
        out = dense_propagate_pallas(x, tile=tile, interpret=interpret)
        return out[:, :b_real, :d_real]
    raise ValueError(f"unknown backend {backend!r}; use one of {PROPAGATE_BACKENDS}")
