"""Pallas TPU kernels for HAMLET hot paths (masked prefix propagation),
with jnp/numpy oracles and jit wrappers.  See hamlet_propagate.py."""

from .ops import propagate, propagate_batched  # noqa: F401
