"""Pallas TPU kernel for *dense* burst propagation (no edge predicates).

For a dense burst the adjacency is strictly-lower all-ones and
(I-L)^{-1}[i,j] = 2^{i-j-1}, so

    c_i = b_i + s_{i-1},   s_i = 2 s_{i-1} + b_i

(the paper's Table-3 doubling in closed form — §Perf it.5).  The kernel
processes row tiles with a precomputed [T, T] weight matrix
K[i,j] = 2^{i-j-1} (j < i) — one MXU matmul per tile — and carries the
running weighted sum ``s`` across tiles in VMEM:

    c_tile = b_tile + K @ b_tile + s_in * pow2[i]
    s_out  = 2^T * s_in + rowpow @ b_tile,   rowpow[j] = 2^{T-1-j}

Tile must satisfy 2^T finite in f32 (T <= 64 keeps the carry exact until
counts themselves saturate — the engine's documented overflow semantics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params

__all__ = ["dense_propagate_pallas"]


def _dense_kernel(k_ref, pow2_ref, rowpow_ref, base_ref, out_ref, s_ref,
                  *, tile):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    b = base_ref[0].astype(jnp.float32)                 # [T, d]
    K = k_ref[...]                                      # [T, T]
    c = b + jnp.dot(K, b, preferred_element_type=jnp.float32)
    c = c + pow2_ref[...].T * s_ref[...]                # s_in * 2^i
    out_ref[0] = c.astype(out_ref.dtype)
    s_new = ((2.0 ** tile) * s_ref[...] +
             jnp.dot(rowpow_ref[...], b, preferred_element_type=jnp.float32))
    s_ref[...] = s_new


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def dense_propagate_pallas(base: jax.Array, *, tile: int = 64,
                           interpret: bool = True) -> jax.Array:
    """base [nb, b, d] with b % tile == 0; returns the dense-burst counts."""
    nb, b, d = base.shape
    if b % tile:
        raise ValueError(f"b={b} must be a multiple of tile={tile}")
    if tile > 64:
        raise ValueError("tile > 64 overflows the f32 carry scale 2^T")
    n_tiles = b // tile

    i = np.arange(tile)
    K = np.where(i[:, None] > i[None, :],
                 2.0 ** (i[:, None] - i[None, :] - 1.0), 0.0)
    pow2 = (2.0 ** i)[None, :].astype(np.float32)        # [1, T]
    rowpow = (2.0 ** (tile - 1.0 - i))[None, :].astype(np.float32)

    kernel = functools.partial(_dense_kernel, tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(nb, n_tiles),
        in_specs=[
            pl.BlockSpec((tile, tile), lambda bi, t: (0, 0)),
            pl.BlockSpec((1, tile), lambda bi, t: (0, 0)),
            pl.BlockSpec((1, tile), lambda bi, t: (0, 0)),
            pl.BlockSpec((1, tile, d), lambda bi, t: (bi, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile, d), lambda bi, t: (bi, t, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, b, d), base.dtype),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(K, jnp.float32), jnp.asarray(pow2), jnp.asarray(rowpow),
      base)
