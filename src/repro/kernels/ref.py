"""Pure reference oracles for the masked prefix-propagation primitive.

The primitive solves the paper's Eq. 1 in batched matrix form: given per-event
injection rows ``base`` [b, d] and a strictly-lower-triangular adjacency
``mask`` [b, b],

    c[i] = base[i] + sum_{j < i} mask[i, j] * c[j]

i.e. ``(I - L) C = B`` with unit diagonal.  ``d`` is the snapshot-basis width
for HAMLET's shared propagation (coefficient rows), or the number of parallel
per-query channels for non-shared GRETA propagation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "numpy_prefix_propagate",
    "numpy_prefix_propagate_batched",
    "numpy_prefix_propagate_fast_batched",
    "prefix_propagate_dense_np_batched",
    "masked_prefix_propagate_ref",
    "masked_prefix_propagate_solve",
]


def numpy_prefix_propagate(base: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """Row-by-row host oracle; dtype-generic (exact for integer dtypes)."""
    b, _ = base.shape
    c = np.zeros_like(base)
    for i in range(b):
        c[i] = base[i]
        if i:
            c[i] = c[i] + mask[i, :i].astype(base.dtype) @ c[:i]
    return c


def numpy_prefix_propagate_fast(base: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """Neumann-doubling host path: (I-L)^{-1} B = prod_i (I + L^{2^i}) B —
    log2(b) BLAS matmuls instead of b Python-level row steps.  Exact while
    path counts stay below 2^53 (f64); beyond that counts saturate, matching
    every float backend (see DESIGN.md on overflow semantics)."""
    import math

    b, _ = base.shape
    if b <= 2:
        return numpy_prefix_propagate(base, mask)
    L = np.tril(mask, k=-1).astype(np.float64, copy=True)
    c = base.astype(np.float64, copy=True)
    n_iters = max(1, math.ceil(math.log2(b)))
    with np.errstate(over="ignore", invalid="ignore"):
        for it in range(n_iters):
            c += L @ c
            if it + 1 < n_iters:
                L = L @ L
    return c.astype(base.dtype, copy=False)


def numpy_prefix_propagate_batched(base: np.ndarray,
                                   mask: np.ndarray) -> np.ndarray:
    """Stacked twin of :func:`numpy_prefix_propagate`: the same row-by-row
    recurrence, vectorized across the batch — row i of every slice advances
    with one batched vecmat.  Each slice is bitwise equal to the unbatched
    oracle (dtype-generic, exact for integer dtypes)."""
    nb, b, _ = base.shape
    c = np.zeros_like(base)
    for i in range(b):
        c[:, i] = base[:, i]
        if i:
            c[:, i] += np.matmul(
                mask[:, i, None, :i].astype(base.dtype), c[:, :i])[:, 0]
    return c


def numpy_prefix_propagate_fast_batched(base: np.ndarray,
                                        mask: np.ndarray) -> np.ndarray:
    """Stacked twin of :func:`numpy_prefix_propagate_fast`: one Neumann-
    doubling sweep over a whole batch ``base [nb, b, d]`` / ``mask
    [nb, b, b]``.  numpy's stacked matmul runs the identical per-slice GEMM,
    so each slice is bitwise equal to the unbatched call — the property the
    engine's batched/per-burst differential tests pin down."""
    import math

    nb, b, _ = base.shape
    if b <= 2:
        return np.stack([numpy_prefix_propagate(base[i], mask[i])
                         for i in range(nb)])
    L = np.tril(mask, k=-1).astype(np.float64, copy=True)
    c = base.astype(np.float64, copy=True)
    n_iters = max(1, math.ceil(math.log2(b)))
    with np.errstate(over="ignore", invalid="ignore"):
        for it in range(n_iters):
            c += np.matmul(L, c)
            if it + 1 < n_iters:
                L = np.matmul(L, L)
    return c.astype(base.dtype, copy=False)


def prefix_propagate_dense_np(base: np.ndarray) -> np.ndarray:
    """Closed form for a *dense* burst (mask = strictly-lower all-ones, the
    no-edge-predicate common case): (I-L)^{-1}[i,j] = 2^{i-j-1}, so with
    s_i = sum_{j<=i} c_j the recurrence collapses to s_i = 2 s_{i-1} + b_i —
    an exponentially weighted cumsum, O(b*d) instead of O(b^2*d log b).
    This is the paper's own Table-3 doubling taken to its closed form.
    Exact for powers of two in f64 up to the saturation regime; falls back
    upstream for b > 512."""
    b, d = base.shape
    i = np.arange(b, dtype=np.float64)
    with np.errstate(over="ignore", invalid="ignore"):
        t = np.cumsum((2.0 ** -i)[:, None] * base, axis=0)
        s = (2.0 ** i)[:, None] * t                 # s_i = sum_{j<=i} c_j
        c = base.astype(np.float64, copy=True)
        c[1:] += s[:-1]
    return c.astype(base.dtype, copy=False)


def prefix_propagate_dense_np_batched(base: np.ndarray) -> np.ndarray:
    """Stacked twin of :func:`prefix_propagate_dense_np` for ``base
    [nb, b, d]``.  Elementwise scaling plus a per-column cumsum along axis 1
    runs in the same scalar order per slice, so slices are bitwise equal to
    the unbatched call — and zero row/column padding never perturbs the real
    region (padding rows sit after every real row in the prefix)."""
    nb, b, d = base.shape
    i = np.arange(b, dtype=np.float64)
    with np.errstate(over="ignore", invalid="ignore"):
        t = np.cumsum((2.0 ** -i)[None, :, None] * base, axis=1)
        s = (2.0 ** i)[None, :, None] * t
        c = base.astype(np.float64, copy=True)
        c[:, 1:] += s[:, :-1]
    return c.astype(base.dtype, copy=False)


def prefix_propagate_dense(base: jax.Array) -> jax.Array:
    """jnp twin of :func:`prefix_propagate_dense_np` (for the pane step)."""
    b, d = base.shape
    i = jnp.arange(b, dtype=jnp.float32)
    t = jnp.cumsum((2.0 ** -i)[:, None] * base, axis=0)
    s = (2.0 ** i)[:, None] * t
    return base.at[1:].add(s[:-1]) if hasattr(base, "at") else base


def masked_prefix_propagate_ref(base: jax.Array, mask: jax.Array) -> jax.Array:
    """jnp oracle via lax.scan over rows (works for float and int dtypes).

    ``mask`` must be strictly lower triangular (enforced here for safety).
    """
    b = base.shape[0]
    mask = jnp.tril(mask, k=-1).astype(base.dtype)

    def step(c_acc, i):
        row = jax.lax.dynamic_index_in_dim(mask, i, axis=0, keepdims=False)
        c_i = jax.lax.dynamic_index_in_dim(base, i, axis=0, keepdims=False)
        c_i = c_i + row @ c_acc
        c_acc = jax.lax.dynamic_update_index_in_dim(c_acc, c_i, i, axis=0)
        return c_acc, None

    c0 = jnp.zeros_like(base)
    c, _ = jax.lax.scan(step, c0, jnp.arange(b))
    return c


def masked_prefix_propagate_solve(base: jax.Array, mask: jax.Array) -> jax.Array:
    """Float-only oracle: direct unit-lower-triangular solve of (I - L) C = B."""
    b = base.shape[0]
    mask = jnp.tril(mask, k=-1).astype(base.dtype)
    a = jnp.eye(b, dtype=base.dtype) - mask
    return jax.scipy.linalg.solve_triangular(a, base, lower=True, unit_diagonal=True)


def masked_prefix_propagate_blocked(base: jax.Array, mask: jax.Array,
                                    tile: int = 128) -> jax.Array:
    """Pure-jnp mirror of the Pallas kernel's algorithm: row tiles solved by
    Neumann doubling (log2(tile) dense matmuls), cross-tile contributions as
    [tile, b] x [b, d] matmuls.  No scan/while — MXU-shaped straight-line HLO,
    used by the production pane step and by the dry-run cost analysis.

    base [b, d]; mask [b, b] strictly lower; b % tile == 0 (pad upstream)."""
    import math as _math

    b, d = base.shape
    assert b % tile == 0, (b, tile)
    mask = jnp.tril(mask, k=-1).astype(base.dtype)
    n_tiles = b // tile
    n_iters = max(1, _math.ceil(_math.log2(tile)))
    c = jnp.zeros_like(base)
    for r in range(n_tiles):
        sl = slice(r * tile, (r + 1) * tile)
        stripe = mask[sl, :]
        y = base[sl] + stripe @ c                 # rows >= r*tile of c are 0
        L = stripe[:, sl]
        x = y
        P = L
        for it in range(n_iters):
            x = x + P @ x
            if it + 1 < n_iters:
                P = P @ P
        c = jax.lax.dynamic_update_slice_in_dim(c, x, r * tile, 0)
    return c
