"""Pallas API compatibility helpers shared by the TPU kernels."""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

__all__ = ["tpu_compiler_params"]


def tpu_compiler_params(**kwargs):
    """Build TPU compiler params across the CompilerParams/TPUCompilerParams
    rename; fails with a version message rather than ``None(...)``."""
    cls = getattr(pltpu, "CompilerParams",
                  getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:
        raise RuntimeError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams; unsupported jax version")
    return cls(**kwargs)
