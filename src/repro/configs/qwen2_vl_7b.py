"""qwen2-vl-7b [arXiv:2409.12191; hf]: 28L d_model=3584 28H (GQA kv=4)
d_ff=18944 vocab=152064 — M-RoPE (temporal/height/width sections), dynamic-
resolution vision frontend STUBBED: input_specs supplies precomputed patch
embeddings."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab=152_064,
    attn_pattern=("global",),
    mrope_sections=(16, 24, 24),
    frontend="patches",
    mlp_gated=True,
    act="silu",
    tie_embeddings=False,
    supports_long_context=False,
)
