"""Architecture config registry.

Each assigned architecture has its own module exporting ``CONFIG``; the
registry maps ``--arch <id>`` to it.  ``reduce_for_smoke`` produces the tiny
same-family config used by the CPU smoke tests.
"""

from __future__ import annotations

import importlib

from .base import ModelConfig, SHAPE_CELLS, input_specs, reduce_for_smoke  # noqa: F401

ARCHS = (
    "gemma2-2b",
    "gemma3-4b",
    "h2o-danube-1.8b",
    "starcoder2-15b",
    "olmoe-1b-7b",
    "llama4-maverick-400b-a17b",
    "qwen2-vl-7b",
    "whisper-tiny",
    "zamba2-7b",
    "rwkv6-7b",
)

_MODULES = {
    "gemma2-2b": "gemma2_2b",
    "gemma3-4b": "gemma3_4b",
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "starcoder2-15b": "starcoder2_15b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "whisper-tiny": "whisper_tiny",
    "zamba2-7b": "zamba2_7b",
    "rwkv6-7b": "rwkv6_7b",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.CONFIG
