"""rwkv6-7b "Finch" [arXiv:2404.05892; hf]: 32L d_model=4096 attention-free
(data-dependent per-channel decay, head size 64), channel-mix d_ff=14336,
vocab=65536."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,        # derived: d_model / rwkv_head_size
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab=65_536,
    attn_pattern=("rwkv6",),
    rwkv_head_size=64,
    mlp_gated=False,
    act="silu",
    tie_embeddings=False,
    supports_long_context=True,   # linear recurrence
)
