"""Unified model configuration for the assigned architecture pool.

One ``ModelConfig`` describes any of the 10 assigned architectures; the
layer plan (``layer_kinds``) drives a scan-over-repeating-groups assembly in
``repro.models.lm``.  ``input_specs`` produces jax.ShapeDtypeStruct stand-ins
for every (shape-cell x step) without allocating memory — the dry-run lowers
against these.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ModelConfig", "SHAPE_CELLS", "input_specs", "reduce_for_smoke"]

# assigned LM shape set: name -> (seq_len, global_batch, step)
SHAPE_CELLS = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # attention behaviour
    attn_pattern: tuple[str, ...] = ("global",)   # per-layer cycle
    window: int = 4_096                           # local-attention window
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    rope_theta: float = 10_000.0
    rope_local_theta: float | None = None         # gemma3: local layers theta
    qk_norm: bool = False
    mrope_sections: tuple[int, int, int] | None = None   # qwen2-vl M-RoPE

    # MLP
    mlp_gated: bool = True
    act: str = "silu"                             # silu | gelu
    post_block_norm: bool = False                 # gemma2 post-norms

    # MoE (family == moe); "moe" layers in attn_pattern use these
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_dense_ff: int = 0                         # d_ff of interleaved dense layers
    capacity_factor: float = 1.25

    # SSM / Mamba2 (family in {hybrid, ssm})
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2

    # RWKV6
    rwkv_head_size: int = 0

    # hybrid (zamba2): weight-tied attention block applied every N layers
    shared_block_period: int = 0

    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0

    # modality frontend stub: none | patches | frames
    frontend: str = "none"

    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # long-context applicability: archs with only full attention skip long_500k
    supports_long_context: bool = False

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_kinds(self) -> list[str]:
        """Per-layer kind: attention flavour / moe / mamba2 / rwkv6."""
        kinds = []
        for i in range(self.n_layers):
            kinds.append(self.attn_pattern[i % len(self.attn_pattern)])
        return kinds

    def layer_plan(self) -> tuple[tuple[str, ...], int, tuple[str, ...]]:
        """(cycle kinds, n_scan_groups, tail kinds): scan over whole cycles,
        unroll the remainder."""
        cyc = tuple(self.attn_pattern)
        n_groups = self.n_layers // len(cyc)
        tail = tuple(self.layer_kinds()[n_groups * len(cyc):])
        return cyc, n_groups, tail

    def supports_cell(self, cell: str) -> str | None:
        """None if the cell applies; otherwise the reason for skipping."""
        seq, batch, step = SHAPE_CELLS[cell]
        if cell == "long_500k" and not self.supports_long_context:
            return ("pure full-attention architecture: 500k decode needs "
                    "sub-quadratic attention (DESIGN.md §Arch-applicability)")
        return None


def input_specs(cfg: ModelConfig, cell: str) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one (arch x shape-cell)."""
    seq, batch, step = SHAPE_CELLS[cell]
    f = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    i32 = jnp.int32

    def s(shape, dt=i32):
        return jax.ShapeDtypeStruct(shape, dt)

    if step == "train":
        if cfg.enc_dec:
            return {"frames": s((batch, seq, cfg.d_model), f),
                    "tokens": s((batch, seq)), "labels": s((batch, seq))}
        if cfg.frontend == "patches":
            n_vis = min(1024, seq // 4)
            out = {"tokens": s((batch, seq - n_vis)),
                   "patch_embeds": s((batch, n_vis, cfg.d_model), f),
                   "labels": s((batch, seq))}
            if cfg.mrope_sections:
                out["positions"] = s((3, batch, seq))
            return out
        return {"tokens": s((batch, seq)), "labels": s((batch, seq))}

    if step == "prefill":
        if cfg.enc_dec:
            return {"frames": s((batch, seq, cfg.d_model), f),
                    "tokens": s((batch, seq))}
        if cfg.frontend == "patches":
            n_vis = min(1024, seq // 4)
            out = {"tokens": s((batch, seq - n_vis)),
                   "patch_embeds": s((batch, n_vis, cfg.d_model), f)}
            if cfg.mrope_sections:
                out["positions"] = s((3, batch, seq))
            return out
        return {"tokens": s((batch, seq))}

    # decode: one new token against a cache of length seq
    out = {"token": s((batch, 1)), "pos": s((batch,))}
    if cfg.mrope_sections:
        out["positions"] = s((3, batch, 1))
    return out


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    cyc = len(cfg.attn_pattern)
    n_layers = max(cyc, 2 if cyc == 1 else cyc)
    if cfg.shared_block_period:
        n_layers = cfg.shared_block_period
    kw = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab=256,
        window=8,
        n_enc_layers=min(cfg.n_enc_layers, 2),
    )
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2))
    if cfg.moe_dense_ff:
        kw.update(moe_dense_ff=256)
    if cfg.ssm_state:
        kw.update(ssm_state=8, ssm_heads=4, ssm_expand=2)
    if cfg.rwkv_head_size:
        kw.update(rwkv_head_size=16)
    if cfg.mrope_sections:
        kw.update(mrope_sections=(2, 3, 3))   # sums to head_dim/2 = 8
    return replace(cfg, **kw)
