"""olmoe-1b-7b [arXiv:2409.02060; hf]: 16L d_model=2048 16H (MHA kv=16)
d_ff=1024/expert vocab=50304, 64 experts top-8."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab=50_304,
    attn_pattern=("global+moe",),
    n_experts=64,
    top_k=8,
    mlp_gated=True,
    act="silu",
    qk_norm=True,
    tie_embeddings=False,
    supports_long_context=False,
)
