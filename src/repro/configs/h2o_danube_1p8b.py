"""h2o-danube-1.8b [arXiv:2401.16818; hf]: 24L d_model=2560 32H (GQA kv=8)
d_ff=6912 vocab=32000 — llama architecture + mistral-style sliding-window
attention."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab=32_000,
    attn_pattern=("local",),
    window=4_096,
    mlp_gated=True,
    act="silu",
    tie_embeddings=False,
    supports_long_context=True,   # SWA bounds the KV cache
)
