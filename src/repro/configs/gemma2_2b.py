"""gemma2-2b [arXiv:2408.00118; hf]: 26L d_model=2304 8H (GQA kv=4)
d_ff=9216 vocab=256000 — 1:1 local:global alternation, attention and final
logit softcaps, pre+post block RMSNorm, GeGLU."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256_000,
    attn_pattern=("local", "global"),
    window=4_096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_gated=True,
    act="gelu",
    post_block_norm=True,
    tie_embeddings=True,
    supports_long_context=True,   # decode is O(KV); local layers bounded
)
