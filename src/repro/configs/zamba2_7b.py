"""zamba2-7b [arXiv:2411.15242; unverified]: 81L d_model=3584 Mamba2
backbone (ssm_state=64) with a weight-tied shared attention+MLP block
(32H kv=32, d_ff=14336) applied every 6th layer — hybrid SSM/attention."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab=32_000,
    attn_pattern=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2",
                  "mamba2+shared"),
    shared_block_period=6,
    ssm_state=64,
    ssm_heads=112,     # d_inner = 2*3584 = 7168; head dim 64
    ssm_expand=2,
    ssm_conv=4,
    mlp_gated=True,
    act="silu",
    tie_embeddings=True,
    supports_long_context=True,   # hybrid: run long_500k
)
