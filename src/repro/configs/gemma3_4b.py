"""gemma3-4b [hf:google/gemma-3-4b-pt; unverified]: 34L d_model=2560 8H
(GQA kv=4) d_ff=10240 vocab=262144 — 5:1 local:global, 1024-token window,
QK-norm, split RoPE thetas (1M global / 10k local)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab=262_144,
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    window=1_024,
    rope_theta=1_000_000.0,
    rope_local_theta=10_000.0,
    qk_norm=True,
    mlp_gated=True,
    act="gelu",
    post_block_norm=True,
    tie_embeddings=True,
    supports_long_context=True,
)
