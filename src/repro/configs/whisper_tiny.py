"""whisper-tiny [arXiv:2212.04356; unverified]: enc-dec, 4L each,
d_model=384 6H d_ff=1536 vocab=51865 — conv frame frontend STUBBED
(input_specs supplies precomputed frame embeddings), sinusoidal positions,
cross attention in the decoder.  Shapes follow the assigned stand-in sequence
lengths, not the production 1500-frame/448-token limits (DESIGN.md)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab=51_865,
    attn_pattern=("global",),
    enc_dec=True,
    n_enc_layers=4,
    frontend="frames",
    mlp_gated=False,
    act="gelu",
    tie_embeddings=True,
    supports_long_context=False,
)
