"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-Maverick-17B-128E;
unverified]: 48L d_model=5120 40H (GQA kv=8) vocab=202048, MoE 128 experts
top-1 + shared expert (d_ff=8192 each), alternating with dense layers
(d_ff=16384) so totals match 400B/17B-active — see DESIGN.md for the
interpretation of the assigned config.  Full attention + RoPE as assigned."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202_048,
    attn_pattern=("global+moe", "global"),
    n_experts=128,
    top_k=1,
    n_shared_experts=1,
    moe_dense_ff=16_384,
    mlp_gated=True,
    act="silu",
    tie_embeddings=False,
    supports_long_context=False,
)
