"""starcoder2-15b [arXiv:2402.19173; hf]: 40L d_model=6144 48H (GQA kv=4)
d_ff=24576 vocab=49152 — full attention + RoPE, plain GELU MLP."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab=49_152,
    attn_pattern=("global",),
    mlp_gated=False,
    act="gelu",
    tie_embeddings=False,
    supports_long_context=False,  # pure full attention: long_500k skipped
)
