"""On-the-wire serving: socket transport for :class:`ServingFrontend`.

PR 9 gave the engine a session tier; everything still lived in one
process.  This module puts the front-end on a real socket with two perf
properties the in-process path already had and the wire must not lose:

* **zero-copy ingest** — event chunks travel as length-prefixed binary
  frames whose payload is the raw struct-of-arrays columns of an
  :class:`EventBatch`; the server decodes them as ``np.frombuffer`` views
  over the received buffer (no per-event Python objects, no copy until
  the batcher merges);
* **churn-free delivery** — deliveries are batched per frame and encoded
  columnar with a per-frame string-intern table (kind / query / aggregate
  names), so a flush that fans out to hundreds of windows serializes
  without building per-record dicts.

Flow control is **credit-based** instead of drop-based: the server grants
each session a window of event credits sized off the serving staging /
ingress high-water mark, frees a submission's credits once the scheduler
seal passes its max timestamp (or sooner, while staging has headroom),
and withholds grants while staging sits above the high-water gate.  A compliant client blocks at zero credits, so
overload surfaces to the producer as backpressure — bounded staging
memory, nothing shed.  A client that keeps pushing past its window is
still shed at the door by ``SessionAdmission`` exactly as in-process.
Grant/withhold counters and the per-session blocked-time histogram land
in the front-end's :class:`Observability` registry (``serve.credits_*``,
``serve.blocked_ms.session.*``).

Wire protocol (all integers little-endian; frame = ``u32 length`` +
``u8 type`` + payload; one TCP connection carries exactly one session):

====  =========  ==========================================================
type  direction  payload
====  =========  ==========================================================
1     C -> S     HELLO: pickled ``{"tenant": int, "groups": ...}``
2     C -> S     SUBMIT: chunk columns (``u32 n, u8 has_seq`` + raw
                 int32/int64/f64 column bytes)
3     C -> S     ADVANCE: ``i64 t`` watermark heartbeat
4     C -> S     CLOSE: end of submit side (deliveries keep flowing)
5     C -> S     BYE: stop consuming; server closes the connection
16    S -> C     SESSION: ``u32 sid, i64 credits, i64 pane``
17    S -> C     CREDIT: ``i64 delta`` freed event credits
18    S -> C     DELIVER: ``f64 t_enc`` + intern table + columnar records
19    S -> C     END: pickled final subscribed ``results()`` (sent on
                 drain; the channel's close sentinel)
====  =========  ==========================================================

Failure semantics: a dropped connection closes its session (the watermark
no longer waits on it), drops its credit state, and cancels its delivery
writer — in-flight deliveries for other sessions are unaffected.  The
END frame doubles as the clean-shutdown marker: a client that sees EOF
without END knows the stream was cut, not drained.

Determinism: TCP preserves per-connection order and the server stages
each connection's submissions in arrival order, so the front-end's
seq-stamping sees exactly the per-session submission sequence — loopback
results are bitwise equal to driving the same sessions in-process.
"""

from __future__ import annotations

import asyncio
import pickle
import socket
import struct
import threading
import time
from collections import deque

import numpy as np

from ..core.events import EventBatch
from ..obs.metrics import serve_blocked_series
from .session import Delivery

__all__ = ["ServingServer", "ServingClient", "CreditGate",
           "encode_chunk", "decode_chunk",
           "encode_deliveries", "decode_deliveries"]

# frame types ---------------------------------------------------------------
_HELLO, _SUBMIT, _ADVANCE, _CLOSE, _BYE = 1, 2, 3, 4, 5
_SESSION, _CREDIT, _DELIVER, _END = 16, 17, 18, 19

_HDR = struct.Struct("<IB")            # frame length (excl. itself) + type
_CHUNK_HDR = struct.Struct("<IB")      # n events, has_seq
_SESSION_S = struct.Struct("<IqQ")     # sid, credits, pane
_CREDIT_S = struct.Struct("<q")        # credit delta
_REC_S = struct.Struct("<HHqqid")      # kind_id, query_id, group, w0,
                                       # revision, latency_ms
_VAL_F64, _VAL_I64, _VAL_PKL = 0, 1, 2


# --------------------------------------------------------------------------
# codec
# --------------------------------------------------------------------------

def encode_chunk(batch: EventBatch) -> bytes:
    """Event columns as raw bytes (the zero-copy wire form of a batch)."""
    has_seq = batch.seq is not None
    parts = [_CHUNK_HDR.pack(len(batch), 1 if has_seq else 0),
             np.ascontiguousarray(batch.type_id).tobytes(),
             np.ascontiguousarray(batch.time).tobytes(),
             np.ascontiguousarray(batch.attrs).tobytes(),
             np.ascontiguousarray(batch.group).tobytes()]
    if has_seq:
        parts.append(np.ascontiguousarray(batch.seq).tobytes())
    return b"".join(parts)


def decode_chunk(schema, payload) -> EventBatch:
    """Decode a SUBMIT payload as zero-copy views over ``payload``.

    The returned batch's arrays are read-only ``np.frombuffer`` views into
    the received buffer — nothing is copied until the batcher merges the
    staged prefix (which concatenates, and therefore copies, anyway).
    """
    buf = memoryview(payload)
    n, has_seq = _CHUNK_HDR.unpack_from(buf, 0)
    off = _CHUNK_HDR.size
    a = max(1, len(schema.attrs))
    type_id = np.frombuffer(buf, np.int32, n, off)
    off += 4 * n
    t = np.frombuffer(buf, np.int64, n, off)
    off += 8 * n
    attrs = np.frombuffer(buf, np.float64, n * a, off).reshape(n, a)
    off += 8 * n * a
    group = np.frombuffer(buf, np.int64, n, off)
    off += 8 * n
    seq = np.frombuffer(buf, np.int64, n, off) if has_seq else None
    return EventBatch(schema, type_id, t, attrs, group, seq=seq)


def encode_deliveries(deliveries, t_enc: float) -> bytes:
    """Columnar DELIVER payload: one string-intern table per frame, one
    fixed-width record per delivery, values tagged f64/i64 (pickle only
    for exotic aggregate values).  No per-record dicts are built."""
    strings: list[bytes] = []
    index: dict[str, int] = {}

    def intern(s: str) -> int:
        i = index.get(s)
        if i is None:
            i = index[s] = len(strings)
            strings.append(s.encode())
        return i

    body = bytearray()
    for d in deliveries:
        body += _REC_S.pack(intern(d.kind), intern(d.query), d.group,
                            d.w0, d.revision, d.latency_ms)
        vals = d.vals
        if vals is None:
            body += struct.pack("<H", 0xFFFF)
            continue
        body += struct.pack("<H", len(vals))
        for k, v in vals.items():
            if type(v) is float:
                body += struct.pack("<HBd", intern(k), _VAL_F64, v)
            elif type(v) is int:
                body += struct.pack("<HBq", intern(k), _VAL_I64, v)
            else:
                p = pickle.dumps(v, protocol=pickle.HIGHEST_PROTOCOL)
                body += struct.pack("<HBI", intern(k), _VAL_PKL, len(p))
                body += p
    head = bytearray(struct.pack("<dHI", t_enc, len(strings),
                                 len(deliveries)))
    for s in strings:
        head += struct.pack("<H", len(s))
        head += s
    return bytes(head) + bytes(body)


def decode_deliveries(payload) -> tuple[float, list[Delivery]]:
    """Inverse of :func:`encode_deliveries`; returns ``(t_enc, records)``."""
    buf = memoryview(payload)
    t_enc, n_strings, n_rec = struct.unpack_from("<dHI", buf, 0)
    off = struct.calcsize("<dHI")
    strings: list[str] = []
    for _ in range(n_strings):
        (ln,) = struct.unpack_from("<H", buf, off)
        off += 2
        strings.append(bytes(buf[off:off + ln]).decode())
        off += ln
    out: list[Delivery] = []
    for _ in range(n_rec):
        kind_id, query_id, group, w0, rev, lat = _REC_S.unpack_from(buf, off)
        off += _REC_S.size
        (n_vals,) = struct.unpack_from("<H", buf, off)
        off += 2
        vals = None
        if n_vals != 0xFFFF:
            vals = {}
            for _ in range(n_vals):
                key_id, tag = struct.unpack_from("<HB", buf, off)
                off += 3
                if tag == _VAL_F64:
                    (v,) = struct.unpack_from("<d", buf, off)
                    off += 8
                elif tag == _VAL_I64:
                    (v,) = struct.unpack_from("<q", buf, off)
                    off += 8
                else:
                    (ln,) = struct.unpack_from("<I", buf, off)
                    off += 4
                    v = pickle.loads(bytes(buf[off:off + ln]))
                    off += ln
                vals[strings[key_id]] = v
        out.append(Delivery(strings[kind_id], strings[query_id], group,
                            w0, vals, rev, lat))
    return t_enc, out


# --------------------------------------------------------------------------
# credit gate (server side)
# --------------------------------------------------------------------------

class CreditGate:
    """Per-session event-credit accounting against the staging high-water.

    A session starts with ``window`` event credits.  ``on_submit`` charges
    a submission and remembers its max timestamp.  Credits recirculate on
    two conditions, checked at every poll:

    * the front-end's seal boundary passed the submission's max timestamp
      — its events left staging and are owned by the engine; or
    * total staged events sit *below* ``staging_high`` — staging has
      headroom, so staged-but-unsealed submissions may recirculate too.
      This clause matters for the session currently holding the seal
      watermark: its last staged pane cannot seal until *future* events
      arrive, so seal-only freeing would deadlock a compliant producer at
      zero credits.

    Grants are withheld — accumulated, not lost — while staged events sit
    at/above ``staging_high``, so a burst across many sessions cannot
    inflate staging memory past the gate: staging is bounded by
    ``staging_high + sessions x window`` (each producer holds at most its
    window past the gate).  ``staging_high`` must comfortably exceed one
    pane's arrival volume: the unsealed tail pane is held in staging by
    the watermark itself, and a gate it keeps shut cannot reopen.
    """

    def __init__(self, frontend, window: int, staging_high: int, obs=None):
        self.frontend = frontend
        self.window = int(window)
        self.staging_high = int(staging_high)
        self.obs = obs
        self.granted = 0               # credits granted (events), lifetime
        self.withheld = 0              # credits that sat gated at least once
        self._lock = threading.Lock()
        self._inflight: dict[int, deque] = {}    # sid -> (t_max, n)
        self._pending: dict[int, int] = {}       # freed but gated
        self._balance: dict[int, int] = {}       # server-side mirror
        self._blocked_since: dict[int, float] = {}

    def register(self, sid: int) -> int:
        with self._lock:
            self._inflight[sid] = deque()
            self._pending[sid] = 0
            self._balance[sid] = self.window
        return self.window

    def forget(self, sid: int) -> None:
        """Session gone (closed or connection dropped): drop its state so
        its in-flight charge never wedges the accounting."""
        with self._lock:
            self._inflight.pop(sid, None)
            self._pending.pop(sid, None)
            self._balance.pop(sid, None)
            self._blocked_since.pop(sid, None)

    def on_submit(self, sid: int, n: int, t_max: int, now: float) -> None:
        if n <= 0:
            return
        with self._lock:
            q = self._inflight.get(sid)
            if q is None:
                return
            q.append((t_max, n))
            self._balance[sid] -= n
            if self._balance[sid] <= 0:
                self._blocked_since.setdefault(sid, now)

    def poll(self, sid: int, now: float) -> int:
        """Free credits whose submissions the seal consumed — plus, while
        staging has headroom, staged-but-unsealed ones; return how many to
        grant right now (0 while the staging gate is shut)."""
        sealed = self.frontend.sealed_to()
        staged = self.frontend.staged_events()
        with self._lock:
            q = self._inflight.get(sid)
            if q is None:
                return 0
            freed = 0
            while q and q[0][0] < sealed:
                freed += q.popleft()[1]
            if staged < self.staging_high:
                while q:
                    freed += q.popleft()[1]
            if staged >= self.staging_high:
                if freed and self.obs is not None:
                    self.obs.count("serve.credits_withheld", freed)
                self.withheld += freed
                self._pending[sid] += freed
                return 0
            grant = freed + self._pending[sid]
            self._pending[sid] = 0
            if grant:
                self.granted += grant
                self._balance[sid] += grant
                t0 = self._blocked_since.pop(sid, None)
                if self.obs is not None:
                    self.obs.count("serve.credits_granted", grant)
                    if t0 is not None:
                        self.obs.observe_blocked(sid, (now - t0) * 1e3)
            return grant

    def summary(self) -> dict:
        with self._lock:
            return {"window": self.window,
                    "staging_high": self.staging_high,
                    "granted": self.granted,
                    "withheld": self.withheld,
                    "inflight": {s: sum(n for _, n in q)
                                 for s, q in self._inflight.items()}}


# --------------------------------------------------------------------------
# server
# --------------------------------------------------------------------------

class _Conn:
    __slots__ = ("sid", "handle", "writer", "alive", "tasks", "wlock")

    def __init__(self):
        self.sid = None
        self.handle = None
        self.writer = None
        self.alive = True
        self.tasks = []
        self.wlock = None


class ServingServer:
    """Asyncio socket server fronting one :class:`ServingFrontend`.

    The event loop runs on a background thread; each accepted connection
    runs a reader coroutine (frames in), a delivery writer (poll the
    session inbox, batch into DELIVER frames), and a credit loop (free /
    grant against the :class:`CreditGate`).  ``drain()`` drains the
    front-end, lets every live writer flush its END frame, and returns the
    final results; ``stop()`` tears the loop down.
    """

    def __init__(self, frontend, host: str = "127.0.0.1", port: int = 0, *,
                 credit_window: int = 2048, staging_high: int | None = None,
                 poll_interval: float = 0.002,
                 clock=time.perf_counter):
        if staging_high is None:
            # size the gate off the ingress high watermark when the
            # backend has one, else a serving-level default
            rt = getattr(frontend._backend, "rt", None)
            q = getattr(rt, "queue", None)
            staging_high = q.high if q is not None else 1 << 12
        self.frontend = frontend
        self.host = host
        self.port = port
        self.poll_interval = poll_interval
        self._clock = clock
        self.gate = CreditGate(frontend, credit_window, staging_high,
                               obs=_GateObs(frontend.obs))
        self.frames_in = 0
        self.frames_out = 0
        self.bytes_in = 0
        self.bytes_out = 0
        self.disconnects = 0
        self.late_frames = 0        # SUBMITs that raced a close / drain
        self._loop: asyncio.AbstractEventLoop | None = None
        self._thread: threading.Thread | None = None
        self._server: asyncio.AbstractServer | None = None
        self._conns: set[_Conn] = set()
        self._conn_tasks: set[asyncio.Task] = set()
        self._ready = threading.Event()
        self._drained = threading.Event()

    # ------------------------------------------------------------ lifecycle

    def start(self, pump_interval: float = 0.002) -> tuple[str, int]:
        """Start the loop thread, bind the listener, start the front-end
        pump; returns the bound ``(host, port)``."""
        self._thread = threading.Thread(target=self._run_loop,
                                        name="serve-transport")
        self._thread.start()
        self._ready.wait()
        if self._server is None:        # bind failed in the loop thread
            self._thread.join()
            raise OSError(f"could not bind {self.host}:{self.port}")
        self.frontend.start(pump_interval)
        return self.host, self.port

    def _run_loop(self) -> None:
        self._loop = asyncio.new_event_loop()
        asyncio.set_event_loop(self._loop)
        try:
            srv = self._loop.run_until_complete(asyncio.start_server(
                self._accept, self.host, self.port))
            self._server = srv
            self.port = srv.sockets[0].getsockname()[1]
        finally:
            self._ready.set()
        if self._server is None:
            self._loop.close()
            return
        try:
            self._loop.run_forever()
        finally:
            self._loop.run_until_complete(self._loop.shutdown_asyncgens())
            self._loop.close()

    def drain(self, timeout: float = 60.0) -> dict:
        """Drain the front-end and flush END down every live connection.

        The owner should drain only once every session is closed (poll
        ``frontend.summary()["sessions"]``): a producer's ``close()``
        returns when the CLOSE frame hits its socket, not when the server
        has processed it, so frames may trail in the socket buffer.  Such
        stragglers don't kill their connection — they are dropped and
        counted as ``late_frames`` — but any events they carried are lost
        to the drained engine."""
        res = self.frontend.drain()
        self._drained.set()
        fut = asyncio.run_coroutine_threadsafe(self._wait_conns(),
                                               self._loop)
        fut.result(timeout=timeout)
        return res

    async def _wait_conns(self) -> None:
        # wait for every live connection's delivery writer to flush its
        # END frame — NOT for the reader (which blocks until the client's
        # BYE), so a single-threaded owner can drain before its clients
        # acknowledge
        ts = [c.tasks[0] for c in list(self._conns) if c.tasks]
        if ts:
            await asyncio.gather(*ts, return_exceptions=True)

    def stop(self) -> None:
        if self._loop is None:
            return
        self.frontend.stop()
        asyncio.run_coroutine_threadsafe(self._shutdown(),
                                         self._loop).result(timeout=30.0)
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join()
        self._loop = None
        self._thread = None

    async def _shutdown(self) -> None:
        self._server.close()
        await self._server.wait_closed()
        for t in list(self._conn_tasks):
            t.cancel()
        if self._conn_tasks:
            await asyncio.gather(*list(self._conn_tasks),
                                 return_exceptions=True)

    # ----------------------------------------------------------- connection

    async def _accept(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        conn = _Conn()
        conn.writer = writer
        conn.wlock = asyncio.Lock()
        self._conns.add(conn)
        task = asyncio.current_task()
        self._conn_tasks.add(task)
        try:
            await self._serve_conn(conn, reader)
        except (asyncio.CancelledError, Exception):
            pass
        finally:
            self._conns.discard(conn)
            try:
                # stay in _conn_tasks until teardown finishes: stop() must
                # be able to cancel/await a connection mid-teardown, else
                # the loop closes under a still-pending task
                await self._teardown(conn)
            finally:
                self._conn_tasks.discard(task)

    async def _serve_conn(self, conn: _Conn,
                          reader: asyncio.StreamReader) -> None:
        fe = self.frontend
        try:
            while True:
                ftype, payload = await self._read_frame(reader)
                if ftype == _HELLO:
                    opts = pickle.loads(payload)
                    h = fe.open_session(tenant=opts.get("tenant", 0),
                                        groups=opts.get("groups"))
                    conn.sid = h.id
                    conn.handle = h
                    credits = self.gate.register(h.id)
                    await self._send(conn, _SESSION, _SESSION_S.pack(
                        h.id, credits, fe.pane))
                    conn.tasks.append(asyncio.ensure_future(
                        self._delivery_writer(conn)))
                    conn.tasks.append(asyncio.ensure_future(
                        self._credit_loop(conn)))
                elif ftype == _SUBMIT:
                    chunk = decode_chunk(fe.workload.schema, payload)
                    n = len(chunk)
                    t_max = int(chunk.time[-1]) if n else -1
                    try:
                        fe.submit(conn.sid, chunk)
                    except RuntimeError:
                        # the session closed (or the owner drained) while
                        # this frame sat in the socket buffer; its events
                        # are past the seal and nothing may consume them —
                        # drop the frame, keep the connection, so END
                        # still reaches a compliant client
                        self.late_frames += 1
                        continue
                    self.gate.on_submit(conn.sid, n, t_max, self._clock())
                elif ftype == _ADVANCE:
                    (t,) = struct.unpack("<q", payload)
                    fe.advance(conn.sid, t)
                elif ftype == _CLOSE:
                    fe.close_session(conn.sid)
                    self.gate.forget(conn.sid)
                elif ftype == _BYE:
                    return
                else:
                    raise ConnectionError(f"bad frame type {ftype}")
        except (asyncio.IncompleteReadError, ConnectionError, OSError):
            # mid-stream drop: the session must not wedge the watermark
            # or hold credits hostage
            if conn.sid is not None:
                self.disconnects += 1
            conn.alive = False
            raise ConnectionError from None

    async def _teardown(self, conn: _Conn) -> None:
        if conn.sid is not None:
            self.frontend.close_session(conn.sid)
            self.gate.forget(conn.sid)
        alive = conn.alive
        conn.alive = False
        try:
            for t in conn.tasks:
                # clean BYE after drain: let the writer flush END first;
                # everything else is cancelled outright
                if alive and (t.done() or self._drained.is_set()):
                    try:
                        await asyncio.wait_for(asyncio.shield(t),
                                               timeout=30.0)
                    except (asyncio.TimeoutError, Exception):
                        t.cancel()
                else:
                    t.cancel()
            if conn.tasks:
                await asyncio.gather(*conn.tasks, return_exceptions=True)
        except asyncio.CancelledError:
            for t in conn.tasks:
                t.cancel()
        try:
            conn.writer.close()
            await conn.writer.wait_closed()
        except (asyncio.CancelledError, ConnectionError, OSError):
            pass

    # ------------------------------------------------------------ coroutines

    async def _delivery_writer(self, conn: _Conn) -> None:
        """Poll the session inbox; batch everything pending into one
        columnar DELIVER frame per poll; send END when the front-end
        drains."""
        h = conn.handle
        try:
            while conn.alive:
                ds = h.poll()
                if ds:
                    await self._send(conn, _DELIVER, encode_deliveries(
                        ds, self._clock()))
                if h.drained:
                    res = {k: v for k, v in
                           self.frontend.results().items()
                           if h.subscribes(k[1])}
                    await self._send(conn, _END, pickle.dumps(
                        res, protocol=pickle.HIGHEST_PROTOCOL))
                    return
                await asyncio.sleep(self.poll_interval)
        except (ConnectionError, OSError, asyncio.CancelledError):
            conn.alive = False

    async def _credit_loop(self, conn: _Conn) -> None:
        try:
            while conn.alive and not self._drained.is_set():
                grant = self.gate.poll(conn.sid, self._clock())
                if grant:
                    await self._send(conn, _CREDIT, _CREDIT_S.pack(grant))
                await asyncio.sleep(self.poll_interval)
        except (ConnectionError, OSError, asyncio.CancelledError):
            conn.alive = False

    # ----------------------------------------------------------------- io

    async def _read_frame(self, reader) -> tuple[int, bytes]:
        head = await reader.readexactly(_HDR.size)
        length, ftype = _HDR.unpack(head)
        payload = await reader.readexactly(length) if length else b""
        self.frames_in += 1
        self.bytes_in += _HDR.size + length
        return ftype, payload

    async def _send(self, conn: _Conn, ftype: int, payload: bytes) -> None:
        async with conn.wlock:
            conn.writer.write(_HDR.pack(len(payload), ftype) + payload)
            await conn.writer.drain()
        self.frames_out += 1
        self.bytes_out += _HDR.size + len(payload)

    # ------------------------------------------------------------- summary

    def summary(self) -> dict:
        return {"host": self.host, "port": self.port,
                "frames_in": self.frames_in, "frames_out": self.frames_out,
                "bytes_in": self.bytes_in, "bytes_out": self.bytes_out,
                "disconnects": self.disconnects,
                "late_frames": self.late_frames,
                "credit": self.gate.summary()}


class _GateObs:
    """Adapter giving :class:`CreditGate` its two obs hooks while keeping
    the gate importable without an :class:`Observability` attached."""

    __slots__ = ("obs",)

    def __init__(self, obs):
        self.obs = obs

    def count(self, name, n=1):
        if self.obs is not None:
            self.obs.count(name, n)

    def observe_blocked(self, sid, ms):
        if self.obs is not None:
            from ..obs.metrics import SERVE_LATENCY_MS_BUCKETS
            self.obs.observe(serve_blocked_series(sid), ms,
                             edges=SERVE_LATENCY_MS_BUCKETS)


# --------------------------------------------------------------------------
# client
# --------------------------------------------------------------------------

class ServingClient:
    """Synchronous socket client: one connection, one session.

    ``submit`` blocks while the credit balance cannot cover the batch (the
    compliant-producer contract; ``block=False`` submits regardless, which
    the server answers with admission-level shedding under overload).
    ``deliveries()`` iterates records until the server's END frame; after
    that :attr:`results` holds the final subscribed window aggregates.
    """

    def __init__(self, host: str, port: int, *, tenant: int = 0,
                 groups=None, timeout: float | None = 60.0):
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._sock.settimeout(None)     # reads block; waits carry timeouts
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._cv = threading.Condition()
        self._credits = 0
        self._inbox: deque = deque()
        self._results: dict | None = None
        self._ended = False
        self._dead = False
        self.sid: int | None = None
        self.pane: int | None = None
        self.blocked_s = 0.0            # client-side credit-wait time
        self.t_enc_last: float | None = None
        # per-DELIVER-frame (t_encoded, t_received, n_records); clocks are
        # comparable only when client and server share a host (loopback)
        self.wire_samples: list[tuple[float, float, int]] = []
        self._send(_HELLO, pickle.dumps({"tenant": tenant,
                                         "groups": groups}))
        self._reader = threading.Thread(target=self._read_loop,
                                        name="serve-client-rx")
        self._reader.start()
        with self._cv:
            if not self._cv.wait_for(lambda: self.sid is not None
                                     or self._dead, timeout=timeout):
                raise TimeoutError("no SESSION reply")
            if self.sid is None:
                raise ConnectionError("server closed before SESSION")

    # ------------------------------------------------------------- producer

    def submit(self, batch: EventBatch, block: bool = True,
               timeout: float | None = 60.0) -> int:
        n = len(batch)
        if n and block:
            t0 = time.perf_counter()
            with self._cv:
                if not self._cv.wait_for(
                        lambda: self._credits >= n or self._dead,
                        timeout=timeout):
                    raise TimeoutError("credit starvation")
                if self._dead:
                    raise ConnectionError("connection lost")
                self._credits -= n
            self.blocked_s += time.perf_counter() - t0
        elif n:
            with self._cv:
                self._credits -= n
        self._send(_SUBMIT, encode_chunk(batch))
        return n

    def advance_to(self, t: int) -> None:
        self._send(_ADVANCE, struct.pack("<q", int(t)))

    def close(self) -> None:
        """End the submit side (server releases the watermark hold)."""
        self._send(_CLOSE, b"")

    # ------------------------------------------------------------- consumer

    def deliveries(self):
        """Blocking record iterator; ends at the server's END frame."""
        while True:
            with self._cv:
                self._cv.wait_for(lambda: self._inbox or self._ended
                                  or self._dead)
                if self._inbox:
                    d = self._inbox.popleft()
                else:
                    if self._dead and not self._ended:
                        raise ConnectionError(
                            "connection lost before END")
                    return
            yield d

    def poll(self) -> list:
        with self._cv:
            out = list(self._inbox)
            self._inbox.clear()
        return out

    @property
    def results(self) -> dict | None:
        """Final subscribed results (None until END)."""
        with self._cv:
            return self._results

    @property
    def drained(self) -> bool:
        with self._cv:
            return self._ended

    @property
    def credits(self) -> int:
        with self._cv:
            return self._credits

    def wait_end(self, timeout: float | None = 60.0) -> dict:
        with self._cv:
            if not self._cv.wait_for(lambda: self._ended or self._dead,
                                     timeout=timeout):
                raise TimeoutError("no END frame")
            if not self._ended:
                raise ConnectionError("connection lost before END")
            return self._results

    def shutdown(self) -> None:
        """Best-effort BYE, close the socket, join the reader."""
        try:
            self._send(_BYE, b"")
        except (ConnectionError, OSError):
            pass
        self._close_sock()
        self._reader.join()

    def kill(self) -> None:
        """Hard drop (no BYE) — the disconnect-race test hook."""
        self._close_sock()
        self._reader.join()

    # ------------------------------------------------------------ internals

    def _close_sock(self) -> None:
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()

    def _send(self, ftype: int, payload: bytes) -> None:
        try:
            self._sock.sendall(_HDR.pack(len(payload), ftype) + payload)
        except OSError as e:
            with self._cv:
                self._dead = True
                self._cv.notify_all()
            raise ConnectionError(str(e)) from e

    def _recv_exact(self, n: int) -> bytes:
        buf = bytearray()
        while len(buf) < n:
            part = self._sock.recv(n - len(buf))
            if not part:
                raise ConnectionError("EOF")
            buf += part
        return bytes(buf)

    def _read_loop(self) -> None:
        try:
            while True:
                length, ftype = _HDR.unpack(self._recv_exact(_HDR.size))
                payload = self._recv_exact(length) if length else b""
                if ftype == _SESSION:
                    sid, credits, pane = _SESSION_S.unpack(payload)
                    with self._cv:
                        self.sid = sid
                        self._credits += credits
                        self.pane = pane
                        self._cv.notify_all()
                elif ftype == _CREDIT:
                    (delta,) = _CREDIT_S.unpack(payload)
                    with self._cv:
                        self._credits += delta
                        self._cv.notify_all()
                elif ftype == _DELIVER:
                    t_enc, ds = decode_deliveries(payload)
                    with self._cv:
                        self.t_enc_last = t_enc
                        self.wire_samples.append(
                            (t_enc, time.perf_counter(), len(ds)))
                        self._inbox.extend(ds)
                        self._cv.notify_all()
                elif ftype == _END:
                    res = pickle.loads(payload)
                    with self._cv:
                        self._results = res
                        self._ended = True
                        self._cv.notify_all()
                    return
        except (ConnectionError, OSError):
            pass
        finally:
            with self._cv:
                self._dead = True
                self._cv.notify_all()
