"""Continuous-batching scheduler: session trickles -> watermark-sealed panes.

The epoch-synchronous service hands the runtime pre-chunked epochs; real
serving is N concurrent sessions trickling small batches at their own pace.
The :class:`ContinuousBatcher` turns those trickles into the engine's unit
of work — complete panes — *continuously*: a flush forms from whatever is
sealed right now, not from a fixed epoch grid.

Mechanics:

* every submission is staged (already seq-stamped by the front-end, so the
  eventual merge order is a pure function of the submissions, never of
  their interleaving);
* each open session carries a **frontier** — the promise that its future
  events have ``time >= frontier`` (advanced by its own submissions, by
  ``advance_to`` heartbeats, or released by ``close``);
* the **serving watermark** is ``min(session frontiers) - skew``; every
  pane ending at or below it is complete *regardless of which session the
  events came from*;
* ``seal()`` merges the staged events below the pane-aligned watermark into
  one time-ordered chunk and hands it (plus the boundary) to the caller —
  the backend then steps exactly the panes that are ready, and the
  runtime's ``micro_batch`` fuses them across sessions into shared
  launches: concurrent trickle streams fill the same K-pane micro-batches
  a batch workload would.

Determinism: seq stamps are session-scoped (``sid << 32 | counter``), so
``EventBatch.merge`` produces one canonical order for any interleaving of
session submissions — the foundation of the serving determinism contract.
"""

from __future__ import annotations

import numpy as np

from ..core.events import EventBatch, StreamSchema

__all__ = ["ContinuousBatcher", "SessionAdmission"]

_SEQ_SPAN = 1 << 32      # per-session seq namespace width


class ContinuousBatcher:
    """Stage per-session submissions; seal pane-complete prefixes.

    Not thread-safe by itself — the owning front-end serializes access
    (it holds its staging lock around ``stage``/``seal``).
    """

    def __init__(self, schema: StreamSchema, pane: int, skew: int = 0):
        if pane <= 0:
            raise ValueError("pane must be positive")
        if skew < 0:
            raise ValueError("skew must be non-negative")
        self.schema = schema
        self.pane = int(pane)
        self.skew = int(skew)
        self._staged: list[EventBatch] = []
        self._n_staged = 0
        self._frontiers: dict[int, int] = {}     # open sessions only
        self._max_staged = -1
        self.sealed_to = 0
        self.sealed_events = 0

    def __len__(self) -> int:
        return self._n_staged

    # ------------------------------------------------------------- staging

    def stage(self, sid: int, batch: EventBatch) -> None:
        """Stage one session's submission (time-ordered, seq-stamped)."""
        if len(batch):
            self._staged.append(batch)
            self._n_staged += len(batch)
            t_max = int(batch.time[-1])
            self._max_staged = max(self._max_staged, t_max)
            cur = self._frontiers.get(sid)
            self._frontiers[sid] = max(cur if cur is not None else 0,
                                       t_max + 1)
        elif sid not in self._frontiers:
            self._frontiers[sid] = 0

    def advance(self, sid: int, t: int) -> None:
        """Session promise: no future event of ``sid`` has ``time < t``."""
        cur = self._frontiers.get(sid)
        if cur is not None:
            self._frontiers[sid] = max(cur, int(t))

    def track(self, sid: int) -> None:
        """Register an open session (holds the watermark at 0 until its
        first submission or heartbeat)."""
        self._frontiers.setdefault(sid, 0)

    def release(self, sid: int) -> None:
        """Session closed: it no longer holds the watermark back."""
        self._frontiers.pop(sid, None)

    # ------------------------------------------------------------- sealing

    def watermark(self) -> int:
        """Event time below which every open session's promise holds."""
        if self._frontiers:
            return min(self._frontiers.values()) - self.skew
        # No open sessions: HOLD, don't finalize.  close() only ends the
        # submit side — a session opening a moment later (a wire client
        # connecting after an earlier client already closed) must not find
        # its whole stream pre-sealed into straggler territory.  The
        # explicit drain() is the only "no more sessions ever" signal, and
        # it seals by its own computed boundary, not through here.
        return self.sealed_to

    def seal(self, upto: int | None = None) -> tuple[EventBatch | None, int]:
        """Merge and hand out every staged event below the pane-aligned
        watermark (or the explicit ``upto``); returns ``(chunk, boundary)``
        with ``chunk=None`` when nothing new is ready.

        A staged event *below* the already-sealed boundary (a straggler in
        a seq-preserving replayed trace) is handed out on the next seal
        even when the boundary itself does not advance — the event-time
        backend revises it into the emitted windows; in-order backends
        treat it as late by their own accounting."""
        wm = self.watermark() if upto is None else int(upto)
        boundary = max((wm // self.pane) * self.pane, self.sealed_to)
        advanced = boundary > self.sealed_to
        if not self._staged:
            if not advanced:
                return None, self.sealed_to
            self.sealed_to = boundary
            return self._empty(), boundary
        merged = (self._staged[0] if len(self._staged) == 1
                  else EventBatch.merge(self._staged))
        hi = int(np.searchsorted(merged.time, boundary, side="left"))
        if hi == 0 and not advanced:
            return None, self.sealed_to
        out = merged.select(np.arange(hi))
        rest = merged.select(np.arange(hi, len(merged)))
        self._staged = [rest] if len(rest) else []
        self._n_staged = len(rest)
        self.sealed_to = boundary
        self.sealed_events += len(out)
        return out, boundary

    def _empty(self) -> EventBatch:
        return EventBatch(self.schema, np.array([], np.int32),
                          np.array([], np.int64), None)


class SessionAdmission:
    """Per-session admission hook into the backend's PID controller.

    The overload runtime's :class:`~repro.overload.controller.
    LatencyController` observes amortized pane-processing latency and
    publishes a shed ratio; this hook actuates that ratio *per session at
    submit time* (drop-tail within the submission), so a hot session is
    shed at the door instead of inflating every shared flush.  Shed events
    are charged to the backend's error accountant (unwitnessed), keeping
    the ``true <= 3^s * emitted`` certificates sound.

    With admission off (the default) the serving path sheds nothing and
    the determinism contract vs the merged-stream oracle is exact.
    """

    def __init__(self, controller, accountant=None):
        self.controller = controller
        self.accountant = accountant
        self.shed_total = 0

    def admit(self, batch: EventBatch) -> tuple[EventBatch, int]:
        """Returns ``(kept prefix, shed count)`` for one submission."""
        n = len(batch)
        if n == 0 or self.controller is None:
            return batch, 0
        ratio = float(self.controller.shed_ratio)
        keep = min(n, max(0, int(n * (1.0 - ratio) + 1e-9)))
        if keep == n:
            return batch, 0
        kept = batch.select(np.arange(keep))
        shed = batch.select(np.arange(keep, n))
        if self.accountant is not None:
            self.accountant.record(shed, witnessed=False)
        self.shed_total += n - keep
        return kept, n - keep
