"""Client session handles for the Hamlet serving front-end.

A :class:`SessionHandle` is one client's half of the serving contract:
``submit`` trickles events in (any number of sessions submit concurrently —
the front-end merges them into shared micro-batched flushes), and the
session's **inbox** receives the deliveries for the groups it subscribes
to: ``emit`` records for newly closed windows, and ``retract``/``amend``
pairs when a previously delivered value is revised (event-time backends).

Consumption is pull- or push-style:

* ``poll()`` — non-blocking drain (the deterministic test/pump mode);
* ``for d in session:`` — blocking iterator that ends when the front-end
  drains the stream and closes the channel;
* ``async for d in session.stream():`` — the asyncio twin, for clients
  living on an event loop while the engine runs on threads.

Sessions are *producers with a promise*: events within one session arrive
in time order up to the front-end's configured ``skew``.  The scheduler's
watermark is the minimum promise over open sessions, so one silent session
can hold the whole stream back — ``advance_to`` (an application-level
heartbeat) or ``close`` releases the hold.
"""

from __future__ import annotations

import asyncio
import queue as _queue
from dataclasses import dataclass, field

__all__ = ["Delivery", "SessionHandle"]

_CLOSE = object()        # inbox sentinel: no further deliveries will arrive


@dataclass(frozen=True)
class Delivery:
    """One record on a session's emission/retraction channel.

    kind        "emit" (first value for this window), "retract" (withdraws
                the previous value) or "amend" (the replacement, always
                immediately preceded by its retract)
    query       user-level query name (atomic name on event-time revision
                records, which revise at atomic granularity)
    group       group partition key
    w0          window start (ticks)
    vals        aggregate values; on a retract, the *withdrawn* values
    revision    0 for first emission, incremented per amendment
    latency_ms  wall-clock delay from the window's pane being sealed by the
                scheduler watermark to this delivery entering the inbox
    """

    kind: str
    query: str
    group: int
    w0: int
    vals: dict | None = None
    revision: int = 0
    latency_ms: float = 0.0


@dataclass
class _SessionState:
    """Front-end-private bookkeeping (kept off the public handle)."""

    seq_next: int = 0
    frontier: int | None = None    # promise: future events have time >= this
    shed: int = 0
    submitted: int = 0
    delivered: int = 0
    closed: bool = False
    opened_at: float = field(default=0.0)


class SessionHandle:
    """One client session: submit side + delivery inbox.

    All methods are thread-safe; the inbox is a ``SimpleQueue`` so any
    number of front-end pump threads may deliver while the client drains.
    """

    def __init__(self, frontend, sid: int, tenant: int, groups=None):
        self.id = int(sid)
        self.tenant = int(tenant)
        self.groups = (None if groups is None
                       else frozenset(int(g) for g in groups))
        self._frontend = frontend
        self._inbox: _queue.SimpleQueue = _queue.SimpleQueue()
        self._done = False

    # ------------------------------------------------------------- producer

    def submit(self, events) -> int:
        """Trickle one time-ordered :class:`EventBatch` in; returns the
        number of events accepted (admission may shed)."""
        return self._frontend.submit(self.id, events)

    def advance_to(self, t: int) -> None:
        """Promise that every future submission has ``time >= t`` (an idle
        session's watermark heartbeat)."""
        self._frontend.advance(self.id, t)

    def close(self) -> None:
        """End the submit side: the session stops holding the watermark.
        The inbox keeps receiving deliveries for its groups until the
        front-end drains."""
        self._frontend.close_session(self.id)

    # ------------------------------------------------------------- consumer

    def subscribes(self, group: int) -> bool:
        return self.groups is None or group in self.groups

    def poll(self, max_n: int | None = None) -> list[Delivery]:
        """Non-blocking drain of everything currently in the inbox."""
        out: list[Delivery] = []
        while max_n is None or len(out) < max_n:
            try:
                d = self._inbox.get_nowait()
            except _queue.Empty:
                break
            if d is _CLOSE:
                self._done = True
                break
            out.append(d)
        return out

    def __iter__(self):
        """Blocking delivery iterator; ends when the front-end drains."""
        while True:
            d = self._inbox.get()
            if d is _CLOSE:
                self._done = True
                return
            yield d

    async def stream(self):
        """Async delivery iterator (``async for d in session.stream()``).

        The inbox get blocks on a worker thread so the event loop stays
        free; back-to-back deliveries short-circuit through the
        non-blocking fast path.
        """
        loop = asyncio.get_running_loop()
        while True:
            try:
                d = self._inbox.get_nowait()
            except _queue.Empty:
                d = await loop.run_in_executor(None, self._inbox.get)
            if d is _CLOSE:
                self._done = True
                return
            yield d

    # ------------------------------------------------------------ internals

    @property
    def drained(self) -> bool:
        """True once the close sentinel has been consumed."""
        return self._done

    def _deliver(self, d: Delivery) -> None:
        self._inbox.put(d)

    def _finish(self) -> None:
        self._inbox.put(_CLOSE)
