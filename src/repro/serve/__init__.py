"""Batched serving engine: request queue, gang-scheduled batched prefill +
masked decode with per-request lengths and EOS early exit."""

from .engine import ServeEngine, Request  # noqa: F401
