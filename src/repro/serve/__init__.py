"""Serving layer.

Two independent subsystems live here:

* the Hamlet **session front-end** — concurrent client sessions trickling
  event streams into one shared engine through a continuous-batching
  scheduler (:class:`ServingFrontend`, :class:`SessionHandle`,
  :class:`ContinuousBatcher`);
* the batched **token serving engine** for the learned components
  (:class:`ServeEngine`, :class:`Request`): request queue, gang-scheduled
  batched prefill + masked decode with per-request lengths.

The front-end additionally speaks a real wire protocol
(:mod:`repro.serve.transport`): :class:`ServingServer` puts it on an
asyncio socket with zero-copy chunk ingest and credit-based per-session
flow control; :class:`ServingClient` is the synchronous producer/consumer
counterpart.
"""

from .engine import ServeEngine, Request  # noqa: F401
from .frontend import ServingFrontend  # noqa: F401
from .scheduler import ContinuousBatcher, SessionAdmission  # noqa: F401
from .session import Delivery, SessionHandle  # noqa: F401
from .transport import CreditGate, ServingClient, ServingServer  # noqa: F401
