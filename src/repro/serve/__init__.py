"""Serving layer.

Two independent subsystems live here:

* the Hamlet **session front-end** — concurrent client sessions trickling
  event streams into one shared engine through a continuous-batching
  scheduler (:class:`ServingFrontend`, :class:`SessionHandle`,
  :class:`ContinuousBatcher`);
* the batched **token serving engine** for the learned components
  (:class:`ServeEngine`, :class:`Request`): request queue, gang-scheduled
  batched prefill + masked decode with per-request lengths.
"""

from .engine import ServeEngine, Request  # noqa: F401
from .frontend import ServingFrontend  # noqa: F401
from .scheduler import ContinuousBatcher, SessionAdmission  # noqa: F401
from .session import Delivery, SessionHandle  # noqa: F401
