"""Batched serving engine.

Gang-scheduled batching: admit up to ``max_batch`` queued requests, left-pad
prompts to a common length, run one batched prefill, then a jitted decode
loop where finished requests are masked (EOS or per-request ``max_new``).
Greedy sampling by default; temperature sampling optional.  The KV cache is
allocated once per gang at ``cap = max_prompt + max_new`` (ring-bounded for
sliding-window layers by ``init_cache``).

Iteration-level continuous batching (per-step slot admission) is the known
next step; the queue/latency accounting here is the substrate for it.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models.lm import decode_fn, init_cache, prefill_fn

__all__ = ["Request", "ServeEngine"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [len] int32
    max_new: int = 16
    eos_id: int | None = None
    submitted_at: float = field(default_factory=time.perf_counter)
    tokens: list = field(default_factory=list)
    first_token_at: float | None = None
    done_at: float | None = None


class ServeEngine:
    def __init__(self, cfg, params, *, max_batch: int = 8,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.temperature = temperature
        self._queue: deque[Request] = deque()
        self._next_rid = 0
        self._key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(prefill_fn(cfg, with_cache=True))
        self._decode = jax.jit(decode_fn(cfg))
        self.completed: dict[int, Request] = {}

    def submit(self, prompt, max_new: int = 16, eos_id: int | None = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, np.asarray(prompt, np.int32),
                                   max_new, eos_id))
        return rid

    # -- one gang: admit, prefill, decode to completion --

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._key, k = jax.random.split(self._key)
        return jax.random.categorical(k, logits / self.temperature,
                                      axis=-1).astype(jnp.int32)

    def run_once(self) -> list[Request]:
        if not self._queue:
            return []
        gang = [self._queue.popleft()
                for _ in range(min(self.max_batch, len(self._queue)))]
        B = len(gang)
        lp = max(len(r.prompt) for r in gang)
        max_new = max(r.max_new for r in gang)
        cap = lp + max_new

        # left-pad prompts so every last prompt token sits at index lp-1
        toks = np.zeros((B, lp), np.int32)
        for i, r in enumerate(gang):
            toks[i, lp - len(r.prompt):] = r.prompt

        cache = init_cache(self.cfg, B, cap=cap)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.enc_dec:
            batch["frames"] = jnp.zeros((B, lp, self.cfg.d_model),
                                        jnp.float32)
        logits, cache = self._prefill(self.params, cache, batch)
        nxt = self._sample(logits)
        now = time.perf_counter()
        for i, r in enumerate(gang):
            r.first_token_at = now
            r.tokens.append(int(nxt[i]))

        alive = np.ones(B, bool)
        for i, r in enumerate(gang):
            if r.eos_id is not None and r.tokens[-1] == r.eos_id:
                alive[i] = False
        for step in range(max_new - 1):
            if not alive.any():
                break
            dec = {"token": nxt[:, None],
                   "pos": jnp.full((B,), lp + step, jnp.int32)}
            if self.cfg.mrope_sections:
                dec["positions"] = jnp.broadcast_to(
                    jnp.asarray(lp + step, jnp.int32), (3, B, 1))
            logits, cache = self._decode(self.params, cache, dec)
            nxt = self._sample(logits)
            for i, r in enumerate(gang):
                if not alive[i]:
                    continue
                tok = int(nxt[i])
                r.tokens.append(tok)
                if (len(r.tokens) >= r.max_new or
                        (r.eos_id is not None and tok == r.eos_id)):
                    alive[i] = False
        now = time.perf_counter()
        for r in gang:
            r.done_at = now
            r.tokens = r.tokens[: r.max_new]
            self.completed[r.rid] = r
        return gang

    def run(self) -> dict:
        """Drain the queue; returns latency/throughput stats."""
        n_tokens = 0
        t0 = time.perf_counter()
        while self._queue:
            for r in self.run_once():
                n_tokens += len(r.tokens)
        dt = time.perf_counter() - t0
        ttfts = [r.first_token_at - r.submitted_at
                 for r in self.completed.values()]
        return {"requests": len(self.completed), "tokens": n_tokens,
                "wall_s": dt, "tok_per_s": n_tokens / max(dt, 1e-9),
                "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0}
