"""Asynchronous serving front-end for the Hamlet trend-aggregation engine.

:class:`ServingFrontend` is the session tier the batch service never had:
N clients open sessions (mapped to tenants), trickle events in at their own
pace, and consume per-group emission/retraction channels — while ONE shared
engine underneath keeps doing what makes Hamlet fast: fusing panes from all
sessions into the same K-pane micro-batched flushes a batch workload would
fill, sharing Kleene bursts across queries inside each flush.

The pieces:

* :class:`~repro.serve.session.SessionHandle` — the per-client API
  (``submit`` / ``poll`` / sync+async delivery iterators);
* :class:`~repro.serve.scheduler.ContinuousBatcher` — stages submissions,
  seals pane-complete prefixes against the session watermark, so flushes
  form from whatever is ready instead of a fixed epoch grid;
* three backend adapters sharing one small interface::

      ingest(chunk, boundary) -> records|None   # sealed prefix, in order
      finish(t_end)           -> records|None   # stream end
      pending_flush()         -> bool           # micro-batch still open?
      results() / stats() / shutdown()

  - ``overload``  — one :class:`OverloadRuntime` (admission + shedding +
    micro-batched pane pipeline, optional ``pipeline_flush`` overlap);
    emissions are computed by diffing ``results()`` snapshots;
  - ``sharded``   — a :class:`ShardedHamletService`; with
    ``ShardServiceConfig.parallel`` the shard drive cycles run on a
    thread pool and the watermark aligner is a real rendezvous barrier;
  - ``eventtime`` — an :class:`EventTimeRuntime`; its
    :class:`EmissionRecord` channel (emit/retract/amend) is forwarded
    verbatim, giving sessions a true retraction channel under disorder.

Determinism contract: submissions are seq-stamped per session
(``sid << 32 | counter``), staged events merge via the canonical
``lexsort(time, seq)`` order, and panes seal on the session watermark —
so for ANY interleaving of session submissions the engine consumes the
exact event sequence of the merged stream, and final ``results()`` are
bitwise equal to the single-threaded epoch-synchronous run.  Pumping from
a background thread, from callers' threads, or inline makes no difference.

Latency accounting: every seal records ``(boundary, wall_clock)``; a
window ``(q, g, w0)`` becomes *ready* at the first seal whose boundary
reaches ``w0 + within(q)``, and its delivery latency is the wall-clock
distance from that seal to the delivery entering the session inbox.
Histograms are kept per session and per tenant (see ``obs/metrics.py``
``serve_latency_series``) and surfaced through ``summary()`` /
``Observability.collect()``.
"""

from __future__ import annotations

import bisect
import threading
import time
from typing import Callable

import numpy as np

from ..core.engine import vals_equal
from ..core.events import EventBatch
from ..obs.metrics import (SERVE_LATENCY_MS_BUCKETS, Histogram,
                           serve_latency_series)
from .scheduler import _SEQ_SPAN, ContinuousBatcher, SessionAdmission
from .session import Delivery, SessionHandle, _SessionState

__all__ = ["ServingFrontend"]


# --------------------------------------------------------------------------
# backend adapters
# --------------------------------------------------------------------------

class _OverloadBackend:
    """Adapter over one shared :class:`OverloadRuntime`."""

    name = "overload"
    retracts = False

    def __init__(self, workload, cfg=None, policy=None, backend="np",
                 obs=None):
        from ..overload.config import OverloadConfig
        from ..overload.runtime import OverloadRuntime
        self.rt = OverloadRuntime(workload, cfg or OverloadConfig(
            shed_policy="none"), policy=policy, backend=backend, obs=obs)
        self.pane = self.rt.pane
        self.controller = self.rt.controller
        self.accountant = self.rt.accountant

    def ingest(self, chunk, boundary):
        if chunk is not None and len(chunk):
            self.rt.offer(chunk)
        while self.rt.t_now + self.pane <= boundary:
            self.rt.step_pane()
        return None

    def finish(self, t_end):
        while self.rt.t_now + self.pane <= t_end:
            self.rt.step_pane()
        self.rt.flush_panes()
        return None

    def pending_flush(self):
        return len(self.rt._backlog) > 0

    def results(self):
        return self.rt.results()

    def stats(self):
        return {"backend": self.name, "metrics": self.rt.metrics.summary(),
                "errors": self.rt.accountant.report()}

    def shutdown(self):
        self.rt.shutdown()


class _ShardedBackend:
    """Adapter over a :class:`ShardedHamletService` (optionally with
    ``parallel=True`` thread-pool shard drives)."""

    name = "sharded"
    retracts = False

    def __init__(self, workload, cfg, obs=None):
        # (shard workers own their observability via cfg.obs; the serving
        # facade's registry is merged at collect time, not pushed down)
        from ..shardsvc.service import ShardedHamletService
        self.svc = ShardedHamletService(workload, cfg)
        self.pane = self.svc.pane
        self.controller = None          # admission lives per shard
        self.accountant = None
        self._closed = False

    def ingest(self, chunk, boundary):
        # The scheduler's watermark is a stronger order promise than the
        # router's max-seen heuristic: honour it so shards seal panes the
        # routed chunk alone would leave open.
        self.svc.promise(boundary - 1)
        self.svc.ingest(chunk)
        return None

    def finish(self, t_end):
        self.svc.promise(t_end - 1)
        if not self._closed:
            self.svc.close()
            self._closed = True
        return None

    def pending_flush(self):
        return any(w.pending_flush() for w in self.svc.workers)

    def results(self):
        return self.svc.results()

    def stats(self):
        return {"backend": self.name, **self.svc.collect()}

    def shutdown(self):
        if not self._closed:
            self.svc.close()
            self._closed = True


class _EventTimeBackend:
    """Adapter over an :class:`EventTimeRuntime` — the only backend with a
    native emission channel (including retract/amend revisions), so
    deliveries forward its :class:`EmissionRecord` stream verbatim.
    Note the records carry *atomic* query names (revision granularity);
    final ``results()`` are combined to user queries as everywhere else."""

    name = "eventtime"
    retracts = True

    def __init__(self, workload, cfg=None, policy=None, backend="np",
                 micro_batch=1, obs=None):
        from ..eventtime.config import EventTimeConfig
        from ..eventtime.revision import EventTimeRuntime
        self.rt = EventTimeRuntime(workload, cfg or EventTimeConfig(),
                                   policy=policy, backend=backend,
                                   micro_batch=micro_batch, obs=obs)
        self.pane = self.rt.pane
        self.controller = None
        self.accountant = None

    def ingest(self, chunk, boundary):
        if chunk is None or not len(chunk):
            return []
        return self.rt.ingest(chunk)

    def finish(self, t_end):
        return self.rt.flush(t_end)

    def pending_flush(self):
        return False

    def results(self):
        return self.rt.results()

    def stats(self):
        return {"backend": self.name, "metrics": self.rt.metrics.summary()}

    def shutdown(self):
        pass


def _make_backend(workload, backend, *, overload=None, shard_cfg=None,
                  eventtime=None, policy=None, np_backend="np",
                  micro_batch=1, obs=None):
    if backend == "overload":
        return _OverloadBackend(workload, overload, policy=policy,
                                backend=np_backend, obs=obs)
    if backend == "sharded":
        if shard_cfg is None:
            raise ValueError("sharded backend needs a ShardServiceConfig")
        return _ShardedBackend(workload, shard_cfg, obs=obs)
    if backend == "eventtime":
        return _EventTimeBackend(workload, eventtime, policy=policy,
                                 backend=np_backend,
                                 micro_batch=micro_batch, obs=obs)
    raise ValueError(f"unknown serving backend {backend!r}")


# --------------------------------------------------------------------------
# front-end
# --------------------------------------------------------------------------

class ServingFrontend:
    """Session front-end + continuous-batching pump over one engine.

    Thread model: ``submit``/``advance``/``close_session`` take the staging
    lock only (cheap, many producers); ``pump`` takes the pump lock (one
    flush former at a time — either the background thread started by
    ``start()`` or callers pumping inline) and holds the staging lock only
    while sealing.  Delivery inboxes are lock-free queues.

    Parameters
    ----------
    workload        the shared :class:`Workload`
    backend         "overload" (default) | "sharded" | "eventtime"
    skew            serving-level disorder allowance subtracted from the
                    session watermark before sealing (event-time backends
                    additionally revise stragglers past it)
    groups_per_tenant
                    tenancy layout: group ``g`` belongs to tenant
                    ``g // groups_per_tenant`` (used when a session
                    subscribes by tenant and for per-tenant latency series)
    session_admission
                    actuate the backend PID controller's shed ratio per
                    session at submit time (overload backend only)
    """

    def __init__(self, workload, *, backend: str = "overload",
                 overload=None, shard_cfg=None, eventtime=None,
                 policy=None, np_backend: str = "np", micro_batch: int = 1,
                 skew: int = 0, groups_per_tenant: int = 1,
                 session_admission: bool = False, obs=None,
                 clock: Callable[[], float] = time.perf_counter):
        self.workload = workload
        self.obs = obs
        self._clock = clock
        self._backend = _make_backend(
            workload, backend, overload=overload, shard_cfg=shard_cfg,
            eventtime=eventtime, policy=policy, np_backend=np_backend,
            micro_batch=micro_batch, obs=obs)
        self.pane = self._backend.pane
        self.groups_per_tenant = max(1, int(groups_per_tenant))
        self._batcher = ContinuousBatcher(workload.schema, self.pane,
                                          skew=skew)
        self._admission = (SessionAdmission(self._backend.controller,
                                            self._backend.accountant)
                           if session_admission else None)
        # user-query readiness horizon: a window (q, g, w0) is complete
        # once the seal boundary reaches w0 + within(q)
        self._within = {qname: max(workload.atomic[i].within for i in idxs)
                        for qname, idxs, _ in workload.combines}
        self._atomic_within = {q.name: q.within for q in workload.atomic}

        self._lock = threading.Lock()        # staging + session registry
        self._pump_lock = threading.Lock()   # one flush former at a time
        self._sessions: dict[int, SessionHandle] = {}
        self._states: dict[int, _SessionState] = {}
        self._next_sid = 0
        self._drained = False

        # delivery bookkeeping (guarded by the pump lock)
        self._published: dict = {}           # (q, g, w0) -> vals
        self._revno: dict = {}               # (q, g, w0) -> revision counter
        self._seal_bounds: list[int] = []    # sorted seal boundaries ...
        self._seal_walls: list[float] = []   # ... and their wall clocks
        self._dirty = False                  # panes stepped since last diff

        # observability (histograms live here; mirrored into obs when set)
        self._lat_all = Histogram("serve.latency_ms.all",
                                  SERVE_LATENCY_MS_BUCKETS)
        self._lat_session: dict[int, Histogram] = {}
        self._lat_tenant: dict[int, Histogram] = {}
        self.deliveries = 0
        self.submitted = 0
        self.pump_cycles = 0
        self.pump_wall_s = 0.0
        self.staging_hwm = 0          # high-water of staged-not-yet-sealed

        self._pump_thread: threading.Thread | None = None
        self._stop = threading.Event()

    # ------------------------------------------------------------- sessions

    def open_session(self, tenant: int = 0, groups=None) -> SessionHandle:
        """Open a client session.  ``groups=None`` subscribes the session to
        its tenant's group block; pass an iterable for an explicit set, or
        ``groups="all"`` for everything."""
        if groups is None:
            lo = tenant * self.groups_per_tenant
            groups = range(lo, lo + self.groups_per_tenant)
        elif groups == "all":
            groups = None
        with self._lock:
            if self._drained:
                raise RuntimeError("front-end already drained")
            sid = self._next_sid
            self._next_sid += 1
            h = SessionHandle(self, sid, tenant, groups)
            self._sessions[sid] = h
            self._states[sid] = _SessionState(opened_at=self._clock())
            self._batcher.track(sid)
        return h

    def submit(self, sid: int, events) -> int:
        """Stage one session's submission (called via the handle).  Events
        must be a time-ordered :class:`EventBatch`.

        Merge-order keys: when the batch carries no ``seq``, stamps are
        assigned here as ``sid << 32 | submit counter`` — merge order is a
        pure function of per-session submission order, never of
        cross-session interleaving.  A batch that *does* carry ``seq`` is
        taken as producer-assigned order keys and staged verbatim (the
        replayed-trace regime: equal-timestamp events across sessions
        order by producer seq, exactly as ``EventBatch.from_unsorted``
        traces do in the event-time layer); the caller then owns
        cross-session key uniqueness."""
        if not isinstance(events, EventBatch):
            raise TypeError("submit() takes an EventBatch")
        with self._lock:
            st = self._states[sid]
            if st.closed or self._drained:
                raise RuntimeError(f"session {sid} is closed")
            batch, shed = events, 0
            if self._admission is not None:
                batch, shed = self._admission.admit(events)
                st.shed += shed
            n = len(batch)
            if n and batch.seq is None:
                seq = (np.arange(st.seq_next, st.seq_next + n,
                                 dtype=np.int64) + sid * _SEQ_SPAN)
                st.seq_next += n
                batch = EventBatch(batch.schema, batch.type_id, batch.time,
                                   batch.attrs, batch.group, seq=seq)
            self._batcher.stage(sid, batch)
            st.submitted += n
            self.submitted += n
            staged = len(self._batcher)
            if staged > self.staging_hwm:
                self.staging_hwm = staged
        if self.obs is not None:
            self.obs.count("serve.submitted", n)
            self.obs.set_gauge("serve.staging_events", staged)
            self.obs.set_gauge("serve.staging_hwm", self.staging_hwm)
            if shed:
                self.obs.count("serve.session_shed", shed)
        return n

    def advance(self, sid: int, t: int) -> None:
        with self._lock:
            self._batcher.advance(sid, t)

    def close_session(self, sid: int) -> None:
        with self._lock:
            st = self._states.get(sid)
            if st is None or st.closed:
                return
            st.closed = True
            self._batcher.release(sid)

    @property
    def sessions(self) -> list[SessionHandle]:
        with self._lock:
            return list(self._sessions.values())

    def staged_events(self) -> int:
        """Events staged but not yet sealed (the transport's credit gate
        reads this as the serving-side occupancy signal)."""
        with self._lock:
            return len(self._batcher)

    def sealed_to(self) -> int:
        """Boundary below which every staged event has been sealed (credit
        accounting: a producer's in-flight batch is 'consumed' once the
        seal boundary passes its max timestamp)."""
        with self._lock:
            return self._batcher.sealed_to

    # ---------------------------------------------------------------- pump

    def pump(self) -> int:
        """Form one flush from whatever is sealed right now: merge the
        pane-complete staged prefix, feed it to the backend, route the new
        emissions.  Returns the number of events forwarded (0 when no new
        pane was complete).  Safe to call from any thread."""
        with self._pump_lock:
            return self._pump_locked()

    def _pump_locked(self, upto: int | None = None) -> int:
        c0 = self._clock()
        with self._lock:
            chunk, boundary = self._batcher.seal(upto)
        n = 0
        if chunk is not None:
            self._log_seal(boundary)
            if self.obs is not None:
                with self.obs.span("serve.flush", cat="serve",
                                   args={"events": len(chunk),
                                         "boundary": boundary}):
                    records = self._backend.ingest(chunk, boundary)
            else:
                records = self._backend.ingest(chunk, boundary)
            n = len(chunk)
            self._dirty = True
            if records:
                self._route_records(records)
        # diff-based backends emit only on flush boundaries: collect when
        # the micro-batch has actually flushed, never force a partial one
        if (not self._backend.retracts and self._dirty
                and not self._backend.pending_flush()):
            self._route_diff()
            self._dirty = False
        self.pump_cycles += 1
        self.pump_wall_s += self._clock() - c0
        return n

    def start(self, interval_s: float = 0.002) -> None:
        """Run the pump on a background thread until ``stop``/``drain``."""
        if self._pump_thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self.pump()
                self._stop.wait(interval_s)

        self._pump_thread = threading.Thread(target=loop, name="serve-pump")
        self._pump_thread.start()

    def stop(self) -> None:
        if self._pump_thread is not None:
            self._stop.set()
            self._pump_thread.join()
            self._pump_thread = None

    def drain(self) -> dict:
        """Stream end: close every session, seal everything staged, flush
        the backend, deliver the tail, post the close sentinel on every
        inbox, and shut worker pools down.  Returns final ``results()``."""
        self.stop()
        with self._pump_lock:
            with self._lock:
                self._drained = True
                for sid, st in self._states.items():
                    if not st.closed:
                        st.closed = True
                        self._batcher.release(sid)
                t_hi = max(self._batcher._max_staged + 1,
                           self._batcher.sealed_to)
                t_end = ((t_hi + self.pane - 1) // self.pane) * self.pane
            self._pump_locked(upto=t_end)
            self._log_seal(t_end)
            records = self._backend.finish(t_end)
            if records:
                self._route_records(records)
            if not self._backend.retracts:
                self._route_diff()
                self._dirty = False
            res = self._backend.results()
            with self._lock:
                for h in self._sessions.values():
                    h._finish()
            self._backend.shutdown()
            return res

    # ------------------------------------------------------------ delivery

    def _log_seal(self, boundary: int) -> None:
        if not self._seal_bounds or boundary > self._seal_bounds[-1]:
            self._seal_bounds.append(boundary)
            self._seal_walls.append(self._clock())

    def _ready_wall(self, close_t: int, now: float) -> float:
        """Wall clock of the first seal whose boundary covered ``close_t``
        (the moment the window *could* first have been delivered)."""
        i = bisect.bisect_left(self._seal_bounds, close_t)
        return self._seal_walls[i] if i < len(self._seal_bounds) else now

    def _route_diff(self) -> None:
        res = self._backend.results()
        now = self._clock()
        for key, vals in res.items():
            old = self._published.get(key)
            if old is not None and vals_equal(old, vals):
                continue
            q, g, w0 = key
            rev = self._revno.get(key, -1) + 1
            self._revno[key] = rev
            ready = self._ready_wall(w0 + self._within[q], now)
            lat = max(0.0, (now - ready) * 1e3)
            if old is not None:
                self._deliver(Delivery("retract", q, g, w0, old, rev - 1,
                                       lat), count=False)
                kind = "amend"
            else:
                kind = "emit"
            self._published[key] = vals
            self._deliver(Delivery(kind, q, g, w0, vals, rev, lat))

    def _route_records(self, records) -> None:
        now = self._clock()
        for r in records:
            within = self._atomic_within.get(r.query,
                                             self._within.get(r.query, 0))
            ready = self._ready_wall(r.w0 + within, now)
            lat = max(0.0, (now - ready) * 1e3)
            self._deliver(Delivery(r.kind, r.query, r.group, r.w0, r.vals,
                                   r.revision, lat),
                          count=r.kind != "retract")

    def _deliver(self, d: Delivery, count: bool = True) -> None:
        tenant = d.group // self.groups_per_tenant
        with self._lock:
            targets = [h for h in self._sessions.values()
                       if h.subscribes(d.group)]
            for h in targets:
                self._states[h.id].delivered += 1
        for h in targets:
            h._deliver(d)
        self.deliveries += len(targets)
        if count and targets:
            self._lat_all.observe(d.latency_ms)
            t_h = self._lat_tenant.get(tenant)
            if t_h is None:
                t_h = self._lat_tenant[tenant] = Histogram(
                    serve_latency_series("tenant", tenant),
                    SERVE_LATENCY_MS_BUCKETS)
            t_h.observe(d.latency_ms)
            for h in targets:
                s_h = self._lat_session.get(h.id)
                if s_h is None:
                    s_h = self._lat_session[h.id] = Histogram(
                        serve_latency_series("session", h.id),
                        SERVE_LATENCY_MS_BUCKETS)
                s_h.observe(d.latency_ms)
            if self.obs is not None:
                self.obs.count("serve.deliveries", len(targets))
                self.obs.observe("serve.latency_ms", d.latency_ms,
                                 edges=SERVE_LATENCY_MS_BUCKETS)

    # ------------------------------------------------------------- results

    def results(self) -> dict:
        return self._backend.results()

    def summary(self) -> dict:
        """Serving-tier summary (merged into ``Observability.collect``)."""
        with self._lock:
            sess = {sid: {"tenant": self._sessions[sid].tenant,
                          "submitted": st.submitted,
                          "delivered": st.delivered,
                          "shed": st.shed,
                          "closed": st.closed}
                    for sid, st in self._states.items()}
        for sid, h in self._lat_session.items():
            if sid in sess:
                sess[sid]["p50_ms"] = h.quantile(0.50)
                sess[sid]["p99_ms"] = h.quantile(0.99)
        return {
            "backend": self._backend.name,
            "sessions": sess,
            "tenants": {t: {"p50_ms": h.quantile(0.50),
                            "p99_ms": h.quantile(0.99),
                            "n": h.count}
                        for t, h in self._lat_tenant.items()},
            "latency_ms": {"p50": self._lat_all.quantile(0.50),
                           "p90": self._lat_all.quantile(0.90),
                           "p99": self._lat_all.quantile(0.99),
                           "n": self._lat_all.count},
            "submitted": self.submitted,
            "deliveries": self.deliveries,
            "sealed_events": self._batcher.sealed_events,
            "sealed_to": self._batcher.sealed_to,
            "staging": {"staged": len(self._batcher),
                        "hwm": self.staging_hwm},
            "session_shed": (self._admission.shed_total
                             if self._admission else 0),
            "pump_cycles": self.pump_cycles,
            "pump_wall_s": self.pump_wall_s,
        }

    def collect(self) -> dict:
        out = {"serving": self.summary()}
        out["engine"] = self._backend.stats()
        return out
