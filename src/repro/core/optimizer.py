"""Dynamic sharing optimizer (paper Sec. 4).

Per burst, the policy picks which subset of the candidate queries (those with
a shareable ``E+``, Def. 4) share the new graphlet:

* **Snapshot-driven pruning** (Thm. 4.1): queries that introduce no event-level
  snapshots for this burst always share.
* **Benefit-driven pruning** (Thm. 4.2): each snapshot-introducing query q is
  classified by comparing ``Shared(Q)`` with ``Shared(Q\\{q}) + NonShared(q)``
  — O(m) plan evaluations instead of the exponential plan space (Fig. 7).
* The surviving set is shared only if its benefit (Def. 11/12) is positive.

``AlwaysShare`` / ``NeverShare`` realise the paper's static baselines
(Figs. 12-13); ``FlopPolicy`` is the beyond-paper variant whose cost model
counts the actual dense-algebra FLOPs of this implementation.

``d_rows`` maps each candidate query to a boolean per-event vector marking
the burst events whose signature (match status / start status / edge-predicate
row) differs from the reference query's — i.e. the events that would become
event-level snapshots (Def. 9) if that query shares.
"""

from __future__ import annotations

import numpy as np

from . import benefit as B

__all__ = ["DynamicPolicy", "AlwaysShare", "NeverShare", "FlopPolicy"]


def _union_count(d_rows: dict[int, np.ndarray], S) -> int:
    rows = [d_rows[q] for q in S if q in d_rows]
    if not rows:
        return 0
    return int(np.any(np.stack(rows), axis=0).sum())


class _PolicyBase:
    # True when ``decide`` never reads ``d_rows`` (nor any other per-burst
    # structure): the engine then skips the divergence pass entirely and the
    # policy is handed ``d_rows=None``
    decision_static = False

    def decide(self, *, ctx, el, candidates, d_rows, b, n, stats) -> list[list[int]]:
        raise NotImplementedError


class AlwaysShare(_PolicyBase):
    """Static plan: share every shareable burst (paper's static optimizer)."""

    decision_static = True

    def decide(self, *, ctx, el, candidates, d_rows, b, n, stats):
        stats.decisions += 1
        return [list(candidates)]


class NeverShare(_PolicyBase):
    """Non-shared execution for every burst (GRETA-equivalent plan)."""

    decision_static = True

    def decide(self, *, ctx, el, candidates, d_rows, b, n, stats):
        stats.decisions += 1
        return [[q] for q in candidates]


class DynamicPolicy(_PolicyBase):
    """The HAMLET optimizer (Sec. 4.2/4.3) with the Def. 11 benefit model.

    The Thm 4.1/4.2 classification is exactly optimal under the paper's
    assumption that removing a query leaves the snapshot counts unchanged.
    With *partially overlapping* per-query divergence sets that assumption
    breaks (choosing the shared subset becomes set-cover-like), so we refine
    the classification with a single-move local search (beyond-paper; still
    O(m^2) plan evaluations per burst, m = snapshot-introducing queries)."""

    def __init__(self, model: str = "v1", local_search: bool = True):
        self.model = model
        self.local_search = local_search

    def _costs(self, *, s_new: int, b: int, n: int, k: int, g: int, t: int):
        s_c = 1 + s_new          # graphlet snapshot x + event-level snapshots
        s_p = 1 + s_new
        if self.model == "v1":
            return B.benefit_v1(b=b, n=n, s_p=s_p, s_c=s_c, k=k, g=g, t=t)
        return B.benefit_v2(b=b, n=n, s_p=s_p, s_c=s_c, k=k, g=g, p=max(1, t // 2))

    def decide(self, *, ctx, el, candidates, d_rows, b, n, stats):
        stats.decisions += 1
        n = max(n, b)
        t = max(1, ctx.layout.t)
        g = b

        d_q = {q: int(d_rows[q].sum()) for q in candidates}
        free = [q for q in candidates if d_q[q] == 0]   # Thm 4.1: share for free
        snap = [q for q in candidates if d_q[q] > 0]

        shared = list(free)
        Q = list(candidates)
        full = self._costs(s_new=_union_count(d_rows, Q), b=b, n=n, k=len(Q),
                           g=g, t=t)
        for q in snap:                                   # Thm 4.2 classification
            without_q = [x for x in Q if x != q]
            alt = (self._costs(s_new=_union_count(d_rows, without_q), b=b, n=n,
                               k=len(without_q), g=g, t=t).shared
                   + B.nonshared_cost_v1(b, n, 1))
            if full.shared <= alt:
                shared.append(q)

        if self.local_search:
            shared = self._refine(shared, candidates, d_rows, b, n, g, t)

        if len(shared) < 2:
            return [[q] for q in candidates]
        final = self._costs(s_new=_union_count(d_rows, shared), b=b, n=n,
                            k=len(shared), g=g, t=t)
        if final.benefit <= 0:
            stats.split_bursts += 1
            return [[q] for q in candidates]
        return [shared] + [[q] for q in candidates if q not in shared]

    def _plan_cost(self, S, candidates, d_rows, b, n, g, t) -> float:
        rest = len(candidates) - len(S)
        cost = B.nonshared_cost_v1(b, n, rest) if rest else 0.0
        if len(S) >= 2:
            cost += self._costs(s_new=_union_count(d_rows, S), b=b, n=n,
                                k=len(S), g=g, t=t).shared
        elif len(S) == 1:
            cost += B.nonshared_cost_v1(b, n, 1)
        return cost

    def _refine(self, shared, candidates, d_rows, b, n, g, t) -> list[int]:
        """Multi-start single-move local search over shared-set membership."""

        def descend(S: set) -> tuple[set, float]:
            best = self._plan_cost(S, candidates, d_rows, b, n, g, t)
            improved = True
            while improved:
                improved = False
                for q in list(candidates):
                    S2 = S ^ {q}
                    if len(S2) == 1:
                        continue
                    c2 = self._plan_cost(S2, candidates, d_rows, b, n, g, t)
                    if c2 < best - 1e-12:
                        S, best, improved = S2, c2, True
            return S, best

        starts = [set(shared), set(candidates)]
        # cheapest pair as a growth seed (single moves cannot leave |S| < 2)
        if len(candidates) >= 2:
            pair = min(
                ((a, c) for i, a in enumerate(candidates)
                 for c in candidates[i + 1:]),
                key=lambda p: self._plan_cost(set(p), candidates, d_rows,
                                              b, n, g, t))
            starts.append(set(pair))
        best_S, best_c = None, float("inf")
        for s0 in starts:
            S, c = descend(s0)
            if c < best_c:
                best_S, best_c = S, c
        return sorted(best_S)


class FlopPolicy(_PolicyBase):
    """Beyond-paper cost model: counts the dense-algebra FLOPs this engine
    actually executes.  Shared: one [b x B_local] solve plus per-query
    snapshot resolution; non-shared: k solves of width ~nu."""

    def decide(self, *, ctx, el, candidates, d_rows, b, n, stats):
        stats.decisions += 1
        k = len(candidates)
        nu = ctx.nu
        C = ctx.layout.size
        u = _union_count(d_rows, candidates)
        B_local = 1 + nu + u * nu
        shared = b * b * B_local + u * k * (b * B_local + B_local * C) + k * B_local * C
        nonshared = k * (b * b * (1 + nu) + (1 + nu) * C)
        if k >= 2 and shared < nonshared:
            return [list(candidates)]
        stats.split_bursts += 1 if k >= 2 else 0
        return [[q] for q in candidates]
