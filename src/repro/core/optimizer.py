"""Dynamic sharing optimizer (paper Sec. 4).

Per burst, the policy picks which subset of the candidate queries (those with
a shareable ``E+``, Def. 4) share the new graphlet:

* **Snapshot-driven pruning** (Thm. 4.1): queries that introduce no event-level
  snapshots for this burst always share.
* **Benefit-driven pruning** (Thm. 4.2): each snapshot-introducing query q is
  classified by comparing ``Shared(Q)`` with ``Shared(Q\\{q}) + NonShared(q)``
  — O(m) plan evaluations instead of the exponential plan space (Fig. 7).
* The surviving set is shared only if its benefit (Def. 11/12) is positive.

``AlwaysShare`` / ``NeverShare`` realise the paper's static baselines
(Figs. 12-13); ``FlopPolicy`` is the beyond-paper variant whose cost model
counts the actual dense-algebra FLOPs of this implementation.

``d_rows`` maps each candidate query to a boolean per-event vector marking
the burst events whose signature (match status / start status / edge-predicate
row) differs from the reference query's — i.e. the events that would become
event-level snapshots (Def. 9) if that query shares.
"""

from __future__ import annotations

import math
from collections import OrderedDict
from fractions import Fraction

import numpy as np

from . import benefit as B

__all__ = ["DynamicPolicy", "AlwaysShare", "NeverShare", "FlopPolicy",
           "divergence_patterns"]


# --------------------------------------------------------------------------
# exact decision memoization over the running event count
# --------------------------------------------------------------------------
#
# Every quantity the v1/v2 benefit models compute is *affine* in ``n`` (the
# running event count): ``shared = b*n*s_p + s_c*k*g*t`` and ``nonshared =
# k*b*n`` never multiply ``n`` by itself.  The sharing decision is therefore
# a deterministic function of the signs of finitely many affine comparisons,
# i.e. piecewise-constant in ``n`` with exactly computable flip thresholds.
# ``_Aff`` threads an affine number through the untouched cost code; every
# comparison it takes records the exact integer interval of ``n`` on which
# its outcome is stable, so one recorded decision replays bit-for-bit for
# every ``n`` inside the interval — the warm-pane fast path is one dict hit
# plus an interval check instead of the full classification + local search.


class _IntervalRecorder:
    """Integer interval of ``n`` on which every recorded comparison keeps
    the outcome it had at ``n0`` (inclusive bounds; ±inf = unbounded)."""

    __slots__ = ("n0", "lo", "hi")

    def __init__(self, n0: int):
        self.n0 = n0
        self.lo = -math.inf
        self.hi = math.inf

    def constrain(self, da, dc, strict: bool, outcome: bool) -> None:
        # predicate: da*n + dc < 0 (strict) / <= 0; held `outcome` at n0
        r = (Fraction(-dc, da) if isinstance(da, int) and isinstance(dc, int)
             else Fraction(-dc) / Fraction(da))
        if outcome == strict:
            # n strictly below/above the threshold
            if (da > 0) == outcome:
                self.hi = min(self.hi, math.ceil(r) - 1)
            else:
                self.lo = max(self.lo, math.floor(r) + 1)
        else:
            if (da > 0) == outcome:
                self.hi = min(self.hi, math.floor(r))
            else:
                self.lo = max(self.lo, math.ceil(r))


class _Aff:
    """``a*n + c`` evaluated at the recorder's ``n0``; comparisons record
    their exact stability interval.  Products of two n-dependent values are
    rejected — the cost models are affine by construction."""

    __slots__ = ("rec", "a", "c")

    def __init__(self, rec, a, c):
        self.rec = rec
        self.a = a
        self.c = c

    def _coerce(self, o):
        if isinstance(o, _Aff):
            return o
        if isinstance(o, (int, float)):
            return _Aff(self.rec, 0, o)
        return None

    def __float__(self):
        return float(self.a * self.rec.n0 + self.c)

    def __add__(self, o):
        o = self._coerce(o)
        if o is None:
            return NotImplemented
        return _Aff(self.rec, self.a + o.a, self.c + o.c)

    __radd__ = __add__

    def __sub__(self, o):
        o = self._coerce(o)
        if o is None:
            return NotImplemented
        return _Aff(self.rec, self.a - o.a, self.c - o.c)

    def __rsub__(self, o):
        o = self._coerce(o)
        if o is None:
            return NotImplemented
        return _Aff(self.rec, o.a - self.a, o.c - self.c)

    def __neg__(self):
        return _Aff(self.rec, -self.a, -self.c)

    def __mul__(self, o):
        if isinstance(o, _Aff):
            if o.a == 0:
                o = o.c
            elif self.a == 0:
                return _Aff(self.rec, o.a * self.c, o.c * self.c)
            else:
                raise TypeError("product of two n-dependent costs")
        if not isinstance(o, (int, float)):
            return NotImplemented
        return _Aff(self.rec, self.a * o, self.c * o)

    __rmul__ = __mul__

    def _cmp(self, other, strict: bool, flip: bool):
        o = self._coerce(other)
        if o is None:
            return NotImplemented
        da, dc = self.a - o.a, self.c - o.c
        if flip:
            da, dc = -da, -dc
        out = ((da * self.rec.n0 + dc < 0) if strict
               else (da * self.rec.n0 + dc <= 0))
        if da != 0 and math.isfinite(dc):
            self.rec.constrain(da, dc, strict, out)
        return out

    def __lt__(self, o):
        return self._cmp(o, True, False)

    def __le__(self, o):
        return self._cmp(o, False, False)

    def __gt__(self, o):
        return self._cmp(o, True, True)

    def __ge__(self, o):
        return self._cmp(o, False, True)


_MEMO_CAP = 4096


def _union_count(d_rows: dict[int, np.ndarray], S) -> int:
    rows = [d_rows[q] for q in S if q in d_rows]
    if not rows:
        return 0
    return int(np.any(np.stack(rows), axis=0).sum())


def divergence_patterns(d_rows: dict[int, np.ndarray],
                        candidates) -> tuple:
    """Exact compression of ``d_rows`` into everything the benefit model can
    read: the multiset of per-event *coverage patterns* — for each burst
    event, the subset of candidates whose signature diverges there (a
    bitmask over ``candidates``), with multiplicity.  Any subset's snapshot
    union count is recoverable exactly (sum the counts of intersecting
    patterns), so decisions taken from patterns are bit-for-bit the
    decisions taken from the raw rows.  This is the plan cache's quantized
    benefit-model fingerprint: two panes with equal patterns (and equal
    ``b``/``n``) provably take the same sharing decision."""
    if not candidates:
        return ()
    D = np.stack([np.asarray(d_rows[q], dtype=bool) for q in candidates])
    if len(candidates) < 60:
        codes = (1 << np.arange(len(candidates), dtype=np.int64)) @ D
        codes = codes[codes != 0]
        if not len(codes):
            return ()
        vals, counts = np.unique(codes, return_counts=True)
        return tuple(zip(vals.tolist(), counts.tolist()))
    # wide candidate sets overflow a fixed-width bitmask: pack each event's
    # coverage column into bytes and rebuild arbitrary-width Python ints
    packed = np.packbits(D, axis=0, bitorder="little")
    cols, counts = np.unique(packed, axis=1, return_counts=True)
    out = []
    for ci in range(cols.shape[1]):
        mask = int.from_bytes(cols[:, ci].tobytes(), "little")
        if mask:
            out.append((mask, int(counts[ci])))
    return tuple(sorted(out))


class _PolicyBase:
    # True when ``decide`` never reads ``d_rows`` (nor any other per-burst
    # structure): the engine then skips the divergence pass entirely and the
    # policy is handed ``d_rows=None``
    decision_static = False
    # True when the decision reads ``d_rows`` only through coverage-pattern
    # counts (``divergence_patterns``): the engine's dynamic-policy plan-key
    # fast path then recomputes the decision from a vectorized fingerprint
    # via ``decide_patterns`` instead of the per-burst plan walk
    pattern_based = False
    # inputs/outputs of the most recent decision, read by the engine's
    # sharing-decision audit log (``repro.obs.audit``); None for policies
    # whose decision never evaluates the benefit model
    last_benefit = None
    last_patterns = None
    # closed interval of the running event count ``n`` on which the most
    # recent decision is replay-stable (``None`` when unknown — non-memoized
    # models).  Lets the engine memoize whole-pane decision walks: a pane's
    # decisions replay verbatim while ``n`` stays inside the intersection of
    # its bursts' intervals (see ``engine._dyn_fast_groups``).
    last_interval: tuple | None = None

    def decide(self, *, ctx, el, candidates, d_rows, b, n, stats) -> list[list[int]]:
        raise NotImplementedError


class AlwaysShare(_PolicyBase):
    """Static plan: share every shareable burst (paper's static optimizer)."""

    decision_static = True

    def decide(self, *, ctx, el, candidates, d_rows, b, n, stats):
        stats.decisions += 1
        return [list(candidates)]


class NeverShare(_PolicyBase):
    """Non-shared execution for every burst (GRETA-equivalent plan)."""

    decision_static = True

    def decide(self, *, ctx, el, candidates, d_rows, b, n, stats):
        stats.decisions += 1
        return [[q] for q in candidates]


class DynamicPolicy(_PolicyBase):
    """The HAMLET optimizer (Sec. 4.2/4.3) with the Def. 11 benefit model.

    The Thm 4.1/4.2 classification is exactly optimal under the paper's
    assumption that removing a query leaves the snapshot counts unchanged.
    With *partially overlapping* per-query divergence sets that assumption
    breaks (choosing the shared subset becomes set-cover-like), so we refine
    the classification with a single-move local search (beyond-paper; still
    O(m^2) plan evaluations per burst, m = snapshot-introducing queries)."""

    pattern_based = True

    def __init__(self, model: str = "v1", local_search: bool = True):
        self.model = model
        self.local_search = local_search
        # (patterns, candidates, b, t) -> [(n_lo, n_hi, groups, benefit,
        # split)]: exact decision replay intervals over the running event
        # count (see the _Aff instrumentation above)
        self._memo: "OrderedDict[tuple, list]" = OrderedDict()

    def _costs(self, *, s_new: int, b: int, n: int, k: int, g: int, t: int):
        s_c = 1 + s_new          # graphlet snapshot x + event-level snapshots
        s_p = 1 + s_new
        if self.model == "v1":
            return B.benefit_v1(b=b, n=n, s_p=s_p, s_c=s_c, k=k, g=g, t=t)
        return B.benefit_v2(b=b, n=n, s_p=s_p, s_c=s_c, k=k, g=g, p=max(1, t // 2))

    def decide(self, *, ctx, el, candidates, d_rows, b, n, stats):
        return self.decide_patterns(
            patterns=divergence_patterns(d_rows, candidates),
            candidates=candidates, b=b, n=n, t=max(1, ctx.layout.t),
            stats=stats)

    def decide_patterns(self, *, patterns, candidates, b, n, t, stats):
        """Decide from the compressed decision inputs: every snapshot union
        count the classification / refinement reads is recovered from the
        coverage-pattern multiset, so this is bit-for-bit :meth:`decide` —
        the engine's plan-key fast path calls it straight off a vectorized
        per-burst fingerprint (see ``engine._dyn_fast_groups``).

        Decisions are memoized per (patterns, candidates, b, t) with the
        exact interval of the running event count ``n`` on which the
        recorded decision trajectory is stable (all cost comparisons keep
        their sign — see ``_Aff``), so a warm stream replays each decision
        from one dict hit while benefit flips at the recorded thresholds
        still recompute and land in fresh intervals.

        Only the v1 model memoizes: its costs are pure integer arithmetic,
        so the affine replay is bit-for-bit.  v2's ``log2`` terms make the
        instrumented arithmetic round differently near decision boundaries
        — it takes the plain path."""
        if self.model != "v1":
            self.last_interval = None
            return self._decide_impl(patterns=patterns,
                                     candidates=candidates, b=b, n=n, t=t,
                                     stats=stats)
        n = int(n)
        key = (patterns, tuple(candidates), b, t)
        ent = self._memo.get(key)
        if ent is not None:
            self._memo.move_to_end(key)
            for lo, hi, groups, benefit, split in ent:
                if lo <= n <= hi:
                    stats.decisions += 1
                    if split:
                        stats.split_bursts += 1
                    self.last_interval = (lo, hi)
                    self.last_patterns = patterns
                    # the benefit value is itself affine in n: evaluate the
                    # recorded coefficients at this pane's event count
                    self.last_benefit = (None if benefit is None
                                         else float(benefit[0] * n
                                                    + benefit[1]))
                    return [list(g) for g in groups]
        rec = _IntervalRecorder(n)
        split0 = stats.split_bursts
        out = self._decide_impl(patterns=patterns, candidates=candidates,
                                b=b, n=_Aff(rec, 1, 0), t=t, stats=stats)
        lb = self.last_benefit
        if isinstance(lb, _Aff):
            benefit = (lb.a, lb.c)
            self.last_benefit = float(lb)
        else:
            benefit = None if lb is None else (0, lb)
        if ent is None:
            ent = self._memo[key] = []
            while len(self._memo) > _MEMO_CAP:
                self._memo.popitem(last=False)
        ent.append((rec.lo, rec.hi, tuple(map(tuple, out)),
                    benefit, stats.split_bursts > split0))
        self.last_interval = (rec.lo, rec.hi)
        return out

    def _decide_impl(self, *, patterns, candidates, b, n, t, stats):
        stats.decisions += 1
        self.last_patterns = patterns
        self.last_benefit = None
        n = max(n, b)
        g = b
        bit = {q: 1 << i for i, q in enumerate(candidates)}

        def union(S) -> int:
            m = 0
            for q in S:
                m |= bit[q]
            return sum(c for code, c in patterns if code & m)

        d_q = {q: union((q,)) for q in candidates}
        free = [q for q in candidates if d_q[q] == 0]   # Thm 4.1: share for free
        snap = [q for q in candidates if d_q[q] > 0]

        shared = list(free)
        Q = list(candidates)
        full = self._costs(s_new=union(Q), b=b, n=n, k=len(Q), g=g, t=t)
        for q in snap:                                   # Thm 4.2 classification
            without_q = [x for x in Q if x != q]
            alt = (self._costs(s_new=union(without_q), b=b, n=n,
                               k=len(without_q), g=g, t=t).shared
                   + B.nonshared_cost_v1(b, n, 1))
            if full.shared <= alt:
                shared.append(q)

        if self.local_search:
            shared = self._refine(shared, candidates, union, b, n, g, t)

        if len(shared) < 2:
            return [[q] for q in candidates]
        final = self._costs(s_new=union(shared), b=b, n=n,
                            k=len(shared), g=g, t=t)
        self.last_benefit = final.benefit
        if final.benefit <= 0:
            stats.split_bursts += 1
            return [[q] for q in candidates]
        return [shared] + [[q] for q in candidates if q not in shared]

    def _plan_cost(self, S, candidates, union, b, n, g, t) -> float:
        rest = len(candidates) - len(S)
        cost = B.nonshared_cost_v1(b, n, rest) if rest else 0.0
        if len(S) >= 2:
            cost += self._costs(s_new=union(S), b=b, n=n,
                                k=len(S), g=g, t=t).shared
        elif len(S) == 1:
            cost += B.nonshared_cost_v1(b, n, 1)
        return cost

    def _refine(self, shared, candidates, union, b, n, g, t) -> list[int]:
        """Multi-start single-move local search over shared-set membership."""

        def descend(S: set) -> tuple[set, float]:
            best = self._plan_cost(S, candidates, union, b, n, g, t)
            improved = True
            while improved:
                improved = False
                for q in list(candidates):
                    S2 = S ^ {q}
                    if len(S2) == 1:
                        continue
                    c2 = self._plan_cost(S2, candidates, union, b, n, g, t)
                    if c2 < best - 1e-12:
                        S, best, improved = S2, c2, True
            return S, best

        starts = [set(shared), set(candidates)]
        # cheapest pair as a growth seed (single moves cannot leave |S| < 2)
        if len(candidates) >= 2:
            pair = min(
                ((a, c) for i, a in enumerate(candidates)
                 for c in candidates[i + 1:]),
                key=lambda p: self._plan_cost(set(p), candidates, union,
                                              b, n, g, t))
            starts.append(set(pair))
        best_S, best_c = None, float("inf")
        for s0 in starts:
            S, c = descend(s0)
            if c < best_c:
                best_S, best_c = S, c
        return sorted(best_S)


class FlopPolicy(_PolicyBase):
    """Beyond-paper cost model: counts the dense-algebra FLOPs this engine
    actually executes.  Shared: one [b x B_local] solve plus per-query
    snapshot resolution; non-shared: k solves of width ~nu."""

    def decide(self, *, ctx, el, candidates, d_rows, b, n, stats):
        stats.decisions += 1
        k = len(candidates)
        nu = ctx.nu
        C = ctx.layout.size
        u = _union_count(d_rows, candidates)
        B_local = 1 + nu + u * nu
        shared = b * b * B_local + u * k * (b * B_local + B_local * C) + k * B_local * C
        nonshared = k * (b * b * (1 + nu) + (1 + nu) * C)
        self.last_benefit = float(nonshared - shared)
        self.last_patterns = None
        if k >= 2 and shared < nonshared:
            return [list(candidates)]
        stats.split_bursts += 1 if k >= 2 else 0
        return [[q] for q in candidates]
