"""Brute-force trend enumeration oracle.

Enumerates every event trend (Def. 3) explicitly — the exponential two-step
semantics that HAMLET/GRETA avoid — and aggregates over the constructed
trends.  Deliberately written with slow, independent Python loops so it
validates the engine's propagation algebra rather than sharing code with it.

Semantics (shared across engine / GRETA / brute — see DESIGN.md):
* a trend is a time-increasing subsequence of matched events whose adjacent
  pairs follow the template edges;
* same-type edge predicates apply between adjacent same-type events within
  one *run* (maximal same-type stretch of the component-relevant event
  sequence); across runs Kleene adjacency is unconstrained (the graphlet
  snapshot abstraction, Def. 8);
* NOT semantics per Sec. 5: a matched negative event cuts connections from
  ``before``-type matches earlier than it to ``after``-type matches later
  than it; leading/trailing NOT constrain the first/last trend event.
"""

from __future__ import annotations

import numpy as np

from ..events import EventBatch, StreamSchema, pane_size_for
from ..query import AtomicQuery, AggKind, Workload

__all__ = ["window_eval_brute", "brute_run"]

MAX_TRENDS = 2_000_000


def window_eval_brute(schema: StreamSchema, q: AtomicQuery, ev: EventBatch,
                      run_type_ids: list[int] | None = None,
                      pane: int | None = None) -> dict:
    info = q.info
    pos_ids = {schema.type_id(t) for t in info.types}
    neg_ids = {schema.type_id(n.neg_type) for n in info.negatives}
    if run_type_ids is None:
        run_type_ids = sorted(pos_ids | neg_ids)

    keep = [i for i in range(len(ev)) if int(ev.type_id[i]) in set(run_type_ids)]
    n = len(keep)
    tid = [int(ev.type_id[i]) for i in keep]
    tname = [schema.types[t] for t in tid]
    times = [int(ev.time[i]) for i in keep]
    attrs = [ev.attrs[i] for i in keep]

    # run ids: maximal same-type stretches of the relevant sequence, scoped to
    # panes (graphlets never span panes — Sec. 3.1)
    run = [0] * n
    for i in range(1, n):
        new_run = tid[i] != tid[i - 1]
        if pane is not None and times[i] // pane != times[i - 1] // pane:
            new_run = True
        run[i] = run[i - 1] + (1 if new_run else 0)

    def type_preds_ok(i: int) -> bool:
        for p in q.preds_for(tname[i]):
            col = schema.attr_col(p.attr)
            if not p.eval(attrs[i][None, :], schema)[0]:
                return False
        return True

    matched = [tid[i] in pos_ids and type_preds_ok(i) for i in range(n)]
    neg_matched = [tid[i] in neg_ids and type_preds_ok(i) for i in range(n)]
    # negation uses arrival (index) order — ties in timestamps resolve by
    # arrival, matching the engine's burst-sequential semantics
    neg_idx = {}
    for nc in info.negatives:
        nid = schema.type_id(nc.neg_type)
        neg_idx[nc] = [i for i in range(n) if neg_matched[i] and tid[i] == nid]

    def edge_ok(j: int, i: int) -> bool:
        if not (matched[j] and matched[i]):
            return False
        if (tname[j], tname[i]) not in info.edges:
            return False
        if tname[j] == tname[i] and run[j] == run[i]:
            for ep in q.edge_preds_for(tname[i]):
                col = schema.attr_col(ep.attr)
                if not ep.eval_pairs(np.array([attrs[j][col]]),
                                     np.array([attrs[i][col]]))[0, 0]:
                    return False
        for nc in info.negatives:
            if nc.before is None or nc.after is None:
                continue
            if tname[j] in nc.before and tname[i] in nc.after:
                if any(j < k < i for k in neg_idx[nc]):
                    return False
        return True

    def start_ok(i: int) -> bool:
        if not (matched[i] and tname[i] in info.start):
            return False
        for nc in info.negatives:
            if nc.before is None:  # leading NOT
                if any(k < i for k in neg_idx[nc]):
                    return False
        return True

    def end_ok(i: int) -> bool:
        if not (matched[i] and tname[i] in info.end):
            return False
        for nc in info.negatives:
            if nc.after is None:  # trailing NOT
                if any(k > i for k in neg_idx[nc]):
                    return False
        return True

    trends: list[tuple[int, ...]] = []

    def dfs(path: list[int]) -> None:
        if len(trends) > MAX_TRENDS:
            raise RuntimeError("brute-force trend explosion; shrink the stream")
        i = path[-1]
        if end_ok(i):
            trends.append(tuple(path))
        for j in range(i + 1, n):
            if edge_ok(i, j):
                path.append(j)
                dfs(path)
                path.pop()

    for i in range(n):
        if start_ok(i):
            dfs([i])

    out: dict[str, float] = {}
    for agg in q.aggs:
        if agg.kind == AggKind.COUNT_STAR:
            out[repr(agg)] = float(len(trends))
            continue
        e_id = schema.type_id(agg.type_name)
        col = schema.attr_col(agg.attr) if agg.attr else None
        if agg.kind == AggKind.COUNT_TYPE:
            out[repr(agg)] = float(sum(sum(1 for i in tr if tid[i] == e_id)
                                       for tr in trends))
        elif agg.kind == AggKind.SUM:
            out[repr(agg)] = float(sum(sum(attrs[i][col] for i in tr if tid[i] == e_id)
                                       for tr in trends))
        elif agg.kind == AggKind.AVG:
            s = sum(sum(attrs[i][col] for i in tr if tid[i] == e_id) for tr in trends)
            c = sum(sum(1 for i in tr if tid[i] == e_id) for tr in trends)
            out[repr(agg)] = float(s / c) if c else float("nan")
        elif agg.kind in (AggKind.MIN, AggKind.MAX):
            vals = [attrs[i][col] for tr in trends for i in tr if tid[i] == e_id]
            if not vals:
                out[repr(agg)] = float("nan")
            else:
                out[repr(agg)] = float(min(vals) if agg.kind == AggKind.MIN
                                       else max(vals))
    return out


def brute_run(workload: Workload, batch: EventBatch,
              t_end: int | None = None) -> dict:
    """Full-workload brute-force driver mirroring HamletRuntime.run()."""
    from ..engine import ComponentContext, combine_results

    pane = pane_size_for(workload.windows)
    if t_end is None:
        t_end = int(batch.time.max()) + 1 if len(batch) else 0
    t_end = ((t_end + pane - 1) // pane) * pane

    comps = workload.sharable_components()
    run_ids_for: dict[int, list[int]] = {}
    for comp in comps:
        ctx = ComponentContext(workload.schema, [workload.atomic[i] for i in comp])
        for aqi in comp:
            run_ids_for[aqi] = ctx.relevant_type_ids

    atomic: dict = {}
    for gk, gbatch in batch.partition_by_group().items():
        for aqi, q in enumerate(workload.atomic):
            w0 = 0
            while w0 + q.within <= t_end:
                ev = gbatch.time_slice(w0, w0 + q.within)
                atomic[(aqi, gk, w0)] = window_eval_brute(
                    workload.schema, q, ev, run_ids_for[aqi], pane=pane)
                w0 += q.slide
    return combine_results(workload, atomic)
