"""GRETA baseline: non-shared online trend aggregation (paper Sec. 3.2, [33]).

Each query is processed independently: per window, the full event adjacency
is materialised and the trend-count recurrence (Eq. 1) is solved once per
query — the ``k x n^2`` cost of Eq. 3.  No graphlets, no snapshots.  This is
both the paper's principal comparison point (Figs. 9-11) and an independent
quadratic oracle for the HAMLET engine tests.
"""

from __future__ import annotations

import numpy as np

from ...kernels import ops
from ..events import EventBatch, StreamSchema, pane_size_for
from ..query import AtomicQuery, AggKind, Workload
from ..template import build_template

__all__ = ["window_adjacency", "window_eval_greta", "greta_run"]


def window_adjacency(schema: StreamSchema, q: AtomicQuery, ev: EventBatch,
                     run_type_ids: list[int] | None = None,
                     pane: int | None = None):
    """Build (adj, start_vec, end_valid, matched, sub) for one window.

    ``adj[i, j] = 1`` iff event j is a predecessor event of i (pe(e_i, q)).
    ``sub`` is the EventBatch restricted to the relevant types.
    """
    info = q.info
    tmpl = build_template(schema, q)
    pos_ids = {schema.type_id(t) for t in info.types}
    neg_ids = {schema.type_id(n.neg_type) for n in info.negatives}
    if run_type_ids is None:
        run_type_ids = sorted(pos_ids | neg_ids)

    keep = np.isin(ev.type_id, np.array(sorted(set(run_type_ids)), dtype=np.int32))
    sub = ev.select(np.nonzero(keep)[0])
    n = len(sub)
    tid = sub.type_id
    times = sub.time

    run = np.zeros(n, dtype=np.int64)
    if n > 1:
        cut = tid[1:] != tid[:-1]
        if pane is not None:
            cut = cut | (times[1:] // pane != times[:-1] // pane)
        run[1:] = np.cumsum(cut)

    matched = np.zeros(n, dtype=bool)
    for t in info.types:
        t_id = schema.type_id(t)
        sel = tid == t_id
        if not sel.any():
            continue
        m = sel.copy()
        for p in q.preds_for(t):
            m &= p.eval(sub.attrs, schema)
        matched |= m

    # negation uses arrival (index) order (ties resolve by arrival)
    neg_matched: dict = {}
    for nc in info.negatives:
        nid = schema.type_id(nc.neg_type)
        m = tid == nid
        for p in q.preds_for(nc.neg_type):
            m = m & p.eval(sub.attrs, schema)
        neg_matched[nc] = np.nonzero(m)[0]

    # adjacency
    adj = np.zeros((n, n))
    lower = np.tril(np.ones((n, n), dtype=bool), k=-1)
    for i_t in np.unique(tid):
        for j_t in np.unique(tid):
            if not tmpl.pred_type[i_t, j_t]:
                continue
            rows = tid == i_t
            cols = tid == j_t
            blk = lower & rows[:, None] & cols[None, :]
            blk &= matched[:, None] & matched[None, :]
            if i_t == j_t:
                eps = q.edge_preds_for(schema.types[int(i_t)])
                if eps:
                    same_run = run[:, None] == run[None, :]
                    ep_ok = np.ones((n, n), dtype=bool)
                    for ep in eps:
                        col = sub.attrs[:, schema.attr_col(ep.attr)]
                        ep_ok &= ep.eval_pairs(col, col).T  # [succ, pred]
                    blk &= ~same_run | ep_ok
            adj[blk] = 1.0

    # mid-pattern NOT cuts
    for nc in info.negatives:
        if nc.before is None or nc.after is None:
            continue
        kn = neg_matched[nc]
        if len(kn) == 0:
            continue
        before = np.isin(tid, [schema.type_id(t) for t in nc.before])
        after = np.isin(tid, [schema.type_id(t) for t in nc.after])
        idx = np.arange(n)
        between = np.zeros((n, n), dtype=bool)
        for k in kn:
            between |= (idx[None, :] < k) & (idx[:, None] > k)
        adj[after[:, None] & before[None, :] & between] = 0.0

    # start / end validity
    start_vec = np.zeros(n)
    for t in info.start:
        start_vec[(tid == schema.type_id(t)) & matched] = 1.0
    for nc in info.negatives:
        if nc.before is None and len(neg_matched[nc]):
            start_vec[np.arange(n) > neg_matched[nc].min()] = 0.0
    end_valid = np.zeros(n, dtype=bool)
    for t in info.end:
        end_valid |= (tid == schema.type_id(t)) & matched
    for nc in info.negatives:
        if nc.after is None and len(neg_matched[nc]):
            end_valid &= np.arange(n) > neg_matched[nc].max()

    return adj, start_vec, end_valid, matched, sub


def window_eval_greta(schema: StreamSchema, q: AtomicQuery, ev: EventBatch,
                      run_type_ids: list[int] | None = None,
                      backend: str = "np", pane: int | None = None) -> dict:
    adj, start_vec, end_valid, matched, sub = window_adjacency(
        schema, q, ev, run_type_ids, pane=pane)
    n = len(sub)
    out: dict[str, float] = {}
    if n == 0:
        for agg in q.aggs:
            out[repr(agg)] = 0.0 if agg.kind in (
                AggKind.COUNT_STAR, AggKind.COUNT_TYPE, AggKind.SUM) else float("nan")
        return out

    counts = np.asarray(ops.propagate(start_vec[:, None], adj,
                                      backend=backend))[:, 0]
    fin = counts * end_valid

    sums: dict[tuple, np.ndarray] = {}
    for u in q.units:
        if u[0] != "sum":
            continue
        _, e_name, attr = u
        e_id = schema.type_id(e_name)
        vals = np.ones(n) if attr is None else sub.attrs[:, schema.attr_col(attr)]
        base = np.where((sub.type_id == e_id) & matched, vals * counts, 0.0)
        sums[u] = np.asarray(ops.propagate(base[:, None], adj,
                                           backend=backend))[:, 0]

    for agg in q.aggs:
        if agg.kind == AggKind.COUNT_STAR:
            out[repr(agg)] = float(fin.sum())
        elif agg.kind == AggKind.COUNT_TYPE:
            out[repr(agg)] = float((sums[("sum", agg.type_name, None)] * end_valid).sum())
        elif agg.kind == AggKind.SUM:
            out[repr(agg)] = float(
                (sums[("sum", agg.type_name, agg.attr)] * end_valid).sum())
        elif agg.kind == AggKind.AVG:
            s = (sums[("sum", agg.type_name, agg.attr)] * end_valid).sum()
            c = (sums[("sum", agg.type_name, None)] * end_valid).sum()
            out[repr(agg)] = float(s / c) if c else float("nan")
        elif agg.kind in (AggKind.MIN, AggKind.MAX):
            out[repr(agg)] = _minmax_propagate(schema, agg, sub, adj, counts,
                                               start_vec, end_valid)
    return out


def _minmax_propagate(schema, agg, sub, adj, counts, start_vec, end_valid) -> float:
    """GRETA-style idempotent propagation of MIN/MAX over trend events."""
    n = len(sub)
    sign = 1.0 if agg.kind == AggKind.MIN else -1.0
    e_id = schema.type_id(agg.type_name)
    col = schema.attr_col(agg.attr)
    own = np.where(sub.type_id == e_id, sign * sub.attrs[:, col], np.inf)
    m = np.full(n, np.inf)
    for i in range(n):
        best = np.inf
        if start_vec[i] > 0:
            best = own[i]
        preds = np.nonzero((adj[i, :i] > 0) & (counts[:i] > 0))[0]
        if len(preds):
            best = min(best, min(np.minimum(m[preds], own[i])))
        m[i] = best
    cand = m[(end_valid) & (counts > 0)]
    cand = cand[np.isfinite(cand)]
    if len(cand) == 0:
        return float("nan")
    return float(sign * cand.min())


def greta_run(workload: Workload, batch: EventBatch, t_end: int | None = None,
              backend: str = "np") -> dict:
    """Full-workload GRETA driver mirroring HamletRuntime.run()."""
    from ..engine import ComponentContext, combine_results

    pane = pane_size_for(workload.windows)
    if t_end is None:
        t_end = int(batch.time.max()) + 1 if len(batch) else 0
    t_end = ((t_end + pane - 1) // pane) * pane

    run_ids_for: dict[int, list[int]] = {}
    for comp in workload.sharable_components():
        ctx = ComponentContext(workload.schema, [workload.atomic[i] for i in comp])
        for aqi in comp:
            run_ids_for[aqi] = ctx.relevant_type_ids

    atomic: dict = {}
    for gk, gbatch in batch.partition_by_group().items():
        for aqi, q in enumerate(workload.atomic):
            w0 = 0
            while w0 + q.within <= t_end:
                ev = gbatch.time_slice(w0, w0 + q.within)
                atomic[(aqi, gk, w0)] = window_eval_greta(
                    workload.schema, q, ev, run_ids_for[aqi], backend=backend,
                    pane=pane)
                w0 += q.slide
    return combine_results(workload, atomic)
