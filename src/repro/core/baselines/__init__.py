"""Baselines from the paper's evaluation (Table 1): GRETA (non-shared online),
MCEP-style two-step construction, SHARON-style flattened sequences, plus a
brute-force trend enumeration oracle used by the tests."""
