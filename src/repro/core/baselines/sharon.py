"""SHARON-style baseline [35]: online aggregation of *fixed-length* sequences.

SHARON does not support Kleene closure.  Following the paper's methodology
(Sec. 6.1), each Kleene sub-pattern ``E+`` is flattened into a set of
fixed-length sequence queries covering every length up to the longest
possible match ``l`` in the window; each fixed-length query is aggregated
online (A-Seq style dynamic program, no sequence construction).  The ``l``-fold
flattening overhead is what dominates its latency in Figs. 9-10.

COUNT(*) only (the paper's Fig. 9-10 metric); other aggregates fall back to
the per-length DP with value accumulation.
"""

from __future__ import annotations

import numpy as np

from ..events import EventBatch, StreamSchema, pane_size_for
from ..query import AtomicQuery, AggKind, Workload
from .greta import window_adjacency

__all__ = ["sharon_window_eval", "sharon_run"]


def sharon_window_eval(schema: StreamSchema, q: AtomicQuery, ev: EventBatch,
                       run_type_ids: list[int] | None = None,
                       pane: int | None = None,
                       max_len: int | None = None) -> dict:
    """Evaluate one window by summing per-exact-Kleene-length DP counts.

    Reuses the window adjacency semantics; the DP computes, per event, the
    number of trends of exactly ``m`` events ending there, for m = 1..l —
    the flattened workload SHARON would run.
    """
    adj, start_vec, end_valid, matched, sub = window_adjacency(
        schema, q, ev, run_type_ids, pane=pane)
    n = len(sub)
    out: dict[str, float] = {}
    if n == 0:
        for agg in q.aggs:
            out[repr(agg)] = 0.0 if agg.kind in (
                AggKind.COUNT_STAR, AggKind.COUNT_TYPE, AggKind.SUM) else float("nan")
        return out

    l = int(matched.sum()) if max_len is None else max_len
    l = max(1, l)
    # counts[m][i]: trends with exactly m events ending at i
    cur = start_vec.copy()
    total = np.zeros(n)
    total += cur * end_valid
    for _m in range(2, l + 1):
        cur = adj @ cur          # one flattened fixed-length query per length
        if not cur.any():
            break
        total += cur * end_valid

    for agg in q.aggs:
        if agg.kind == AggKind.COUNT_STAR:
            out[repr(agg)] = float(total.sum())
        else:
            # non-count aggregates: defer to the quadratic online path
            from .greta import window_eval_greta

            out.update(window_eval_greta(schema, q, ev, run_type_ids, pane=pane))
            break
    return out


def sharon_run(workload: Workload, batch: EventBatch,
               t_end: int | None = None) -> dict:
    from ..engine import ComponentContext, combine_results

    pane = pane_size_for(workload.windows)
    if t_end is None:
        t_end = int(batch.time.max()) + 1 if len(batch) else 0
    t_end = ((t_end + pane - 1) // pane) * pane

    run_ids_for: dict[int, list[int]] = {}
    for comp in workload.sharable_components():
        ctx = ComponentContext(workload.schema, [workload.atomic[i] for i in comp])
        for aqi in comp:
            run_ids_for[aqi] = ctx.relevant_type_ids

    atomic: dict = {}
    for gk, gbatch in batch.partition_by_group().items():
        for aqi, q in enumerate(workload.atomic):
            w0 = 0
            while w0 + q.within <= t_end:
                ev = gbatch.time_slice(w0, w0 + q.within)
                atomic[(aqi, gk, w0)] = sharon_window_eval(
                    workload.schema, q, ev, run_ids_for[aqi], pane=pane)
                w0 += q.slide
    return combine_results(workload, atomic)
