"""MCEP-style baseline [22]: shared two-step trend processing.

MCEP shares event *trend construction* across the workload, then computes
aggregates per query as a post-processing step over the constructed trends.
Construction is shared by enumerating trends over the union of the queries'
template edges once per window; each trend is then validated/aggregated per
query.  The exponential construction cost the paper highlights (Figs. 9-10)
is inherent: the number of trends is exponential in matched events.
"""

from __future__ import annotations

import numpy as np

from ..events import EventBatch, StreamSchema, pane_size_for
from ..query import AtomicQuery, AggKind, Workload
from .brute import window_eval_brute

__all__ = ["mcep_window_eval", "mcep_run"]

MAX_TRENDS = 2_000_000


def mcep_window_eval(schema: StreamSchema, queries: list[AtomicQuery],
                     ev: EventBatch, run_type_ids: list[int],
                     pane: int | None = None) -> list[dict]:
    """Shared construction over the union template; per-query aggregation."""
    union_edges: set[tuple[str, str]] = set()
    union_start: set[str] = set()
    union_end: set[str] = set()
    pos_names: set[str] = set()
    for q in queries:
        union_edges |= set(q.info.edges)
        union_start |= set(q.info.start)
        union_end |= set(q.info.end)
        pos_names |= set(q.info.types)

    keep = [i for i in range(len(ev))
            if int(ev.type_id[i]) in set(run_type_ids)]
    n = len(keep)
    tname = [schema.types[int(ev.type_id[i])] for i in keep]
    times = [int(ev.time[i]) for i in keep]
    attrs = [ev.attrs[i] for i in keep]
    run = [0] * n
    for i in range(1, n):
        new_run = tname[i] != tname[i - 1]
        if pane is not None and times[i] // pane != times[i - 1] // pane:
            new_run = True
        run[i] = run[i - 1] + (1 if new_run else 0)

    # shared construction: any event of a positive type may participate; the
    # union adjacency over-approximates each query's adjacency
    trends: list[tuple[int, ...]] = []

    def dfs(path: list[int]) -> None:
        if len(trends) > MAX_TRENDS:
            raise RuntimeError("MCEP trend explosion; shrink the stream")
        i = path[-1]
        if tname[i] in union_end:
            trends.append(tuple(path))
        for j in range(i + 1, n):
            if (tname[i], tname[j]) in union_edges and tname[j] in pos_names:
                path.append(j)
                dfs(path)
                path.pop()

    for i in range(n):
        if tname[i] in union_start:
            dfs([i])

    # per-query validation + aggregation (post-processing step)
    out = []
    for q in queries:
        neg_idx: dict = {}
        for nc in q.info.negatives:
            nid = schema.type_id(nc.neg_type)
            ks = []
            for i in range(n):
                if schema.type_id(tname[i]) != nid:
                    continue
                ok = True
                for p in q.preds_for(tname[i]):
                    if not p.eval(attrs[i][None, :], schema)[0]:
                        ok = False
                if ok:
                    ks.append(i)
            neg_idx[nc] = ks

        def matched(i: int) -> bool:
            if tname[i] not in q.info.types:
                return False
            for p in q.preds_for(tname[i]):
                if not p.eval(attrs[i][None, :], schema)[0]:
                    return False
            return True

        def valid(tr: tuple[int, ...]) -> bool:
            if tname[tr[0]] not in q.info.start or tname[tr[-1]] not in q.info.end:
                return False
            if not all(matched(i) for i in tr):
                return False
            for a, b in zip(tr, tr[1:]):
                if (tname[a], tname[b]) not in q.info.edges:
                    return False
                if tname[a] == tname[b] and run[a] == run[b]:
                    for ep in q.edge_preds_for(tname[a]):
                        col = schema.attr_col(ep.attr)
                        if not ep.eval_pairs(np.array([attrs[a][col]]),
                                             np.array([attrs[b][col]]))[0, 0]:
                            return False
                for nc in q.info.negatives:
                    if nc.before is None or nc.after is None:
                        continue
                    if tname[a] in nc.before and tname[b] in nc.after:
                        if any(a < k < b for k in neg_idx[nc]):
                            return False
            for nc in q.info.negatives:
                if nc.before is None and any(k < tr[0] for k in neg_idx[nc]):
                    return False
                if nc.after is None and any(k > tr[-1] for k in neg_idx[nc]):
                    return False
            return True

        q_trends = [tr for tr in trends if valid(tr)]
        vals: dict[str, float] = {}
        for agg in q.aggs:
            if agg.kind == AggKind.COUNT_STAR:
                vals[repr(agg)] = float(len(q_trends))
                continue
            e_id = agg.type_name
            col = schema.attr_col(agg.attr) if agg.attr else None
            members = [(i, attrs[i][col] if col is not None else 1.0)
                       for tr in q_trends for i in tr if tname[i] == e_id]
            if agg.kind == AggKind.COUNT_TYPE:
                vals[repr(agg)] = float(len(members))
            elif agg.kind == AggKind.SUM:
                vals[repr(agg)] = float(sum(v for _, v in members))
            elif agg.kind == AggKind.AVG:
                vals[repr(agg)] = (float(sum(v for _, v in members) / len(members))
                                   if members else float("nan"))
            elif agg.kind == AggKind.MIN:
                vals[repr(agg)] = (float(min(v for _, v in members))
                                   if members else float("nan"))
            elif agg.kind == AggKind.MAX:
                vals[repr(agg)] = (float(max(v for _, v in members))
                                   if members else float("nan"))
        out.append(vals)
    return out


def mcep_run(workload: Workload, batch: EventBatch,
             t_end: int | None = None) -> dict:
    from ..engine import ComponentContext, combine_results

    pane = pane_size_for(workload.windows)
    if t_end is None:
        t_end = int(batch.time.max()) + 1 if len(batch) else 0
    t_end = ((t_end + pane - 1) // pane) * pane

    comps = workload.sharable_components()
    atomic: dict = {}
    for gk, gbatch in batch.partition_by_group().items():
        for comp in comps:
            ctx = ComponentContext(workload.schema,
                                   [workload.atomic[i] for i in comp])
            # group queries with identical windows to share construction
            by_window: dict[tuple[int, int], list[int]] = {}
            for aqi in comp:
                q = workload.atomic[aqi]
                by_window.setdefault((q.within, q.slide), []).append(aqi)
            for (within, slide), aqis in by_window.items():
                w0 = 0
                while w0 + within <= t_end:
                    ev = gbatch.time_slice(w0, w0 + within)
                    vals = mcep_window_eval(
                        workload.schema,
                        [workload.atomic[i] for i in aqis],
                        ev, ctx.relevant_type_ids, pane=pane)
                    for aqi, v in zip(aqis, vals):
                        atomic[(aqi, gk, w0)] = v
                    w0 += slide
    return combine_results(workload, atomic)
