"""HAMLET executor (paper Sec. 3.3 / Algorithm 1) and windowed runtime.

Execution model
---------------
Events arrive in panes (gcd of all windows/slides).  Within a pane, events of
the types relevant to a sharable component are segmented into *bursts*
(maximal same-type runs — Def. 10); each burst forms a new *graphlet*
(Def. 6).  Per burst the sharing policy decides which queries share the
graphlet (Sec. 4).  Shared propagation maintains per-event *coefficient rows*
over a small local snapshot basis:

    idx 0          gate entry      (start contributions; value = query's gate)
    idx 1..nu      x_u             graphlet-level snapshot per linear unit
                                   (Def. 8: value = sum of predecessor-type
                                   running aggregates)
    idx nu+1..     z               event-level snapshots for divergent events
                                   (Def. 9: predicate differences)

Plan-then-execute pipeline
--------------------------
A pane is processed in three phases rather than one kernel launch per burst:

1. **plan** — every burst is segmented, the sharing policy decides its
   groups, and each group's masks/adjacency/injection rows are captured as
   propagation *jobs*.  Nothing here depends on the running aggregates, so
   the whole pane plans up front.
2. **execute** — jobs go to a :class:`~repro.core.batch_exec
   .PaneBatchExecutor`, which buckets them by size (ragged edges padded
   where exact) and solves each bucket with **one** batched launch of the
   masked prefix-propagation primitive (``repro.kernels``) or the dense
   closed form.  Two rounds: count-unit jobs first, then the sum-unit jobs
   that inject their coefficients.
3. **finalize** — a cheap sequential replay in stream order applies negation
   gates, fills event-level snapshot functionals, and folds coefficient
   column-sums (one stacked einsum per graphlet) into per-query *state
   functionals* (linear maps over the pane-entry state channels), so the
   pane yields one transfer matrix ``M[q]`` per query.

Sliding-window instances then advance with a single batched [C×C] matmul per
pane — overlapping windows share all per-event work (the paper's pane
sharing, Sec. 3.1).

Trend counts grow like 2^g and overflow fixed-width types for realistic panes
(the paper is silent on this); the engine computes in float64 by default.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..kernels.ops import DENSE_B_MAX
from .batch_exec import PaneBatchExecutor, PropagateJob
from .events import EventBatch, StreamSchema, pane_size_for, split_panes
from .query import AtomicQuery, Workload
from .template import QueryTemplate, build_template

__all__ = ["ComponentContext", "PaneProcessor", "HamletRuntime", "RunStats",
           "fold_panes", "vals_equal"]


# --------------------------------------------------------------------------
# static per-component context
# --------------------------------------------------------------------------


@dataclass
class _NegRule:
    kind: str                 # "leading" | "mid" | "trailing"
    before_local: np.ndarray  # local type indices whose A-sums are cut (mid)


class ComponentContext:
    """Prepared static info for one sharable component of the workload."""

    def __init__(self, schema: StreamSchema, queries: list[AtomicQuery]):
        self.schema = schema
        self.queries = list(queries)
        self.k = len(queries)
        self.templates: list[QueryTemplate] = [build_template(schema, q) for q in queries]

        pos: set[int] = set()
        neg: set[int] = set()
        for t in self.templates:
            pos |= set(np.nonzero(t.match)[0].tolist())
            neg |= set(np.nonzero(t.negative)[0].tolist())
        self.pos_type_ids = sorted(pos)
        self.neg_type_ids = sorted(neg)
        self.relevant_type_ids = sorted(pos | neg)
        self.local = {e: i for i, e in enumerate(self.pos_type_ids)}

        units: set[tuple] = set()
        for q in queries:
            units |= set(u for u in q.units if u[0] in ("count", "sum"))
        from .snapshot import ChannelLayout

        self.units = tuple(sorted(units, key=lambda u: (u[0] != "count",
                                                        tuple(str(x) for x in u))))
        self.layout = ChannelLayout(list(self.units), self.pos_type_ids)
        self.nu = len(self.units)

        # channel-column lookup tables for the vectorized pane assembly
        self.a_cols = np.array(
            [[self.layout.a_idx(u, e) for e in self.pos_type_ids]
             for u in self.units], dtype=int).reshape(self.nu, -1)
        self.rp_cols = np.array([self.layout.rp_idx(u) for u in self.units],
                                dtype=int)

        t = len(self.pos_type_ids)
        self.start_flag = np.zeros((self.k, t), dtype=bool)
        self.end_flag = np.zeros((self.k, t), dtype=bool)
        self.match_flag = np.zeros((self.k, t), dtype=bool)
        self.kleene_flag = np.zeros((self.k, t), dtype=bool)
        # pt_mask[q, e, e'] over local positive types
        self.pt_mask = np.zeros((self.k, t, t), dtype=bool)
        for qi, tmpl in enumerate(self.templates):
            for e, el in self.local.items():
                self.start_flag[qi, el] = tmpl.start[e]
                self.end_flag[qi, el] = tmpl.end[e]
                self.match_flag[qi, el] = tmpl.match[e]
                self.kleene_flag[qi, el] = tmpl.kleene[e]
                for e2, el2 in self.local.items():
                    self.pt_mask[qi, el, el2] = tmpl.pred_type[e, e2]

        # negation rules: neg type id -> list[(query idx, _NegRule)]
        self.neg_rules: dict[int, list[tuple[int, _NegRule]]] = {}
        for qi, q in enumerate(self.queries):
            for nc in q.info.negatives:
                nid = schema.type_id(nc.neg_type)
                if nc.before is None:
                    rule = _NegRule("leading", np.array([], dtype=int))
                elif nc.after is None:
                    rule = _NegRule("trailing", np.array([], dtype=int))
                else:
                    bl = np.array(sorted(self.local[schema.type_id(b)]
                                         for b in nc.before), dtype=int)
                    rule = _NegRule("mid", bl)
                self.neg_rules.setdefault(nid, []).append((qi, rule))

        # per-(query,type) predicate/edge-pred lookup
        self._preds = {}
        self._edge_preds = {}
        for qi, q in enumerate(self.queries):
            for tname, ps in q.preds:
                self._preds[(qi, schema.type_id(tname))] = ps
            for tname, eps in q.edge_preds:
                self._edge_preds[(qi, schema.type_id(tname))] = eps

        # queries that share E+ (Def. 4): kleene flag per local type
        self.kleene_queries = {
            el: [qi for qi in range(self.k) if self.kleene_flag[qi, el]]
            for el in range(t)
        }
        # which queries need the min/max side path
        self.minmax_queries = [qi for qi, q in enumerate(self.queries)
                               if any(u[0] == "minmax" for u in q.units)]

    def match_vec(self, qi: int, type_id: int, attrs: np.ndarray) -> np.ndarray:
        ps = self._preds.get((qi, type_id), ())
        m = np.ones(len(attrs), dtype=bool)
        for p in ps:
            m &= p.eval(attrs, self.schema)
        return m

    def edge_mask(self, qi: int, type_id: int, attrs: np.ndarray) -> np.ndarray | None:
        """[successor, predecessor]-oriented edge-predicate mask, or None."""
        eps = self._edge_preds.get((qi, type_id), ())
        if not eps:
            return None
        b = len(attrs)
        m = np.ones((b, b), dtype=bool)
        for ep in eps:
            col = attrs[:, self.schema.attr_col(ep.attr)]
            m &= ep.eval_pairs(col, col).T
        return m


# --------------------------------------------------------------------------
# statistics (drives the benefit model and the benchmark metrics)
# --------------------------------------------------------------------------


@dataclass
class RunStats:
    events: int = 0
    bursts: int = 0
    shared_bursts: int = 0
    split_bursts: int = 0
    graphlets: int = 0
    shared_graphlets: int = 0
    snapshots_created: int = 0
    snapshots_propagated: int = 0
    propagate_cells: int = 0      # total solved cells (rows x basis cols)
    decisions: int = 0
    panes: int = 0
    windows_emitted: int = 0

    def merge(self, o: "RunStats") -> None:
        for f in self.__dataclass_fields__:
            setattr(self, f, getattr(self, f) + getattr(o, f))


# --------------------------------------------------------------------------
# pane processor (Algorithm 1 over one pane, producing transfer matrices)
# --------------------------------------------------------------------------


@dataclass
class _NegStep:
    """Negation rules that fired for one burst (applied during finalize)."""

    hits: list  # [(query idx, _NegRule)]


@dataclass
class _GroupPlan:
    """One graphlet's planned propagation: masks, adjacency, and job handles.

    Captured during the plan phase; coefficients arrive from the batched
    executor; the finalize phase folds them into the state functionals.
    """

    g: list
    el: int
    type_id: int
    attrs: np.ndarray
    b: int
    mvec: np.ndarray              # [len(g), b]
    epm: list
    shared: bool
    div: np.ndarray               # [b] divergence flags
    div_rows: np.ndarray
    live: np.ndarray
    dead: np.ndarray
    B_local: int
    z_ids: dict
    dense: bool
    em: np.ndarray | None         # in-burst adjacency (None when dense)
    start_q0: bool
    sum_units: list               # [(ui, injection values | None)]
    cjob: PropagateJob | None = None
    sjobs: dict = field(default_factory=dict)   # ui -> PropagateJob


class PaneProcessor:
    def __init__(self, ctx: ComponentContext, policy, backend: str = "np",
                 max_local_basis: int = 512, executor=None):
        self.ctx = ctx
        self.policy = policy
        self.backend = backend
        self.max_local_basis = max_local_basis
        self.executor = (executor if executor is not None
                         else PaneBatchExecutor(backend=backend))

    # -- burst segmentation (Def. 10) --

    @staticmethod
    def _segment(type_ids: np.ndarray) -> list[tuple[int, slice]]:
        if len(type_ids) == 0:
            return []
        cut = np.nonzero(np.diff(type_ids))[0] + 1
        bounds = np.concatenate([[0], cut, [len(type_ids)]])
        return [(int(type_ids[bounds[i]]), slice(int(bounds[i]), int(bounds[i + 1])))
                for i in range(len(bounds) - 1)]

    # -- main entry --

    def process(self, pane: EventBatch, stats: RunStats) -> np.ndarray:
        """Process one pane; returns per-query transfer matrices M [k, C, C].

        Three phases: plan every burst's jobs, execute them as bucketed
        batched launches, then replay the pane in stream order to fold
        coefficients into the state functionals (see module docstring).
        """
        ctx = self.ctx
        C = ctx.layout.size
        k = ctx.k
        nu = ctx.nu
        t = len(ctx.pos_type_ids)

        # state functionals over pane-entry channels
        arow = np.zeros((k, nu, t, C))
        if nu and t:
            arow[:, np.arange(nu)[:, None], np.arange(t)[None, :],
                 ctx.a_cols] = 1.0
        rrow = np.zeros((k, nu, C))
        if nu:
            rrow[:, np.arange(nu), ctx.rp_cols] = 1.0
        gaterow = np.zeros((k, C))
        gaterow[:, ctx.layout.GATE] = 1.0

        # counts saturate to inf past float64 range (documented overflow
        # semantics) — keep the whole pipeline quiet about it
        with np.errstate(over="ignore", invalid="ignore"):
            return self._process_inner(pane, stats, arow, rrow, gaterow)

    def _process_inner(self, pane, stats, arow, rrow, gaterow) -> np.ndarray:
        ctx = self.ctx
        C = ctx.layout.size
        k = ctx.k
        nu = ctx.nu
        t = len(ctx.pos_type_ids)

        # phase 1: plan
        steps = self._plan_pane(pane, stats)

        # phase 2: execute (two rounds — sum jobs inject count coefficients)
        plans = [s for s in steps if isinstance(s, _GroupPlan)]
        ex = self.executor
        for p in plans:
            p.cjob = ex.submit(self._count_base(p),
                               None if p.dense else p.em)
            stats.propagate_cells += p.b * p.B_local
        ex.flush()
        for p in plans:
            for ui, vals in p.sum_units:
                p.sjobs[ui] = ex.submit(self._sum_base(p, ui, vals),
                                        None if p.dense else p.em)
                stats.propagate_cells += p.b * p.B_local
        ex.flush()

        # phase 3: finalize in stream order
        for s in steps:
            if isinstance(s, _NegStep):
                for qi, rule in s.hits:
                    if rule.kind == "leading":
                        gaterow[qi, :] = 0.0
                    elif rule.kind == "trailing":
                        rrow[qi, :, :] = 0.0
                    else:
                        arow[qi, :, rule.before_local, :] = 0.0
            else:
                self._finalize_group(s, arow, rrow, gaterow)

        # assemble transfer matrices (vectorized over queries)
        M = np.zeros((k, C, C))
        M[:, ctx.layout.CONST, ctx.layout.CONST] = 1.0
        M[:, ctx.layout.GATE, :] = gaterow
        if nu and t:
            M[:, ctx.a_cols.reshape(-1), :] = arow.reshape(k, nu * t, C)
        if nu:
            M[:, ctx.rp_cols, :] = rrow
        return M

    # -- phase 1: plan --

    def _plan_pane(self, pane: EventBatch, stats: RunStats) -> list:
        ctx = self.ctx
        k = ctx.k

        keep = np.isin(pane.type_id, ctx.relevant_type_ids)
        ev = pane.select(np.nonzero(keep)[0])
        stats.events += len(ev)
        stats.panes += 1

        steps: list = []
        for type_id, sl in self._segment(ev.type_id):
            attrs = ev.attrs[sl]
            b = sl.stop - sl.start
            stats.bursts += 1

            # negative-type handling (Sec. 5): applies per query with a rule
            hits = [(qi, rule) for qi, rule in ctx.neg_rules.get(type_id, [])
                    if ctx.match_vec(qi, type_id, attrs).any()]
            if hits:
                steps.append(_NegStep(hits))

            if type_id not in ctx.local:
                continue
            el = ctx.local[type_id]
            q_pos = [qi for qi in range(k) if ctx.match_flag[qi, el]]
            if not q_pos:
                continue

            mvec = np.stack([ctx.match_vec(qi, type_id, attrs) for qi in q_pos])
            epm = [ctx.edge_mask(qi, type_id, attrs) for qi in q_pos]

            # sharing decision (Sec. 4): candidates are queries with E+ (Def. 4)
            kle = [qi for qi in q_pos if ctx.kleene_flag[qi, el]]
            groups: list[list[int]] = []
            if len(kle) >= 2:
                d_rows = self._divergence_rows(q_pos, kle, el, mvec, epm)
                shared_sets = self.policy.decide(
                    ctx=ctx, el=el, candidates=kle, d_rows=d_rows, b=b,
                    n=stats.events, stats=stats)
                in_shared = set(qq for s in shared_sets for qq in s)
                groups.extend([s for s in shared_sets if len(s) >= 2])
                groups.extend([[qi] for s in shared_sets if len(s) == 1 for qi in s])
                groups.extend([[qi] for qi in kle if qi not in in_shared])
            else:
                groups.extend([[qi] for qi in kle])
            groups.extend([[qi] for qi in q_pos if qi not in kle])

            for g in groups:
                if len(g) >= 2:
                    stats.shared_bursts += 1
                    stats.shared_graphlets += 1
                stats.graphlets += 1
                self._plan_group(
                    g, el, type_id, attrs, b,
                    mvec[[q_pos.index(qi) for qi in g]],
                    [epm[q_pos.index(qi)] for qi in g],
                    steps, stats)
        return steps

    # -- divergence detection (per-event signature differences) --

    def _divergence_rows(self, q_pos, kle, el, mvec, epm) -> dict[int, np.ndarray]:
        """Per-candidate boolean rows: events where q's signature differs from
        the reference (first candidate).  Drives Thms 4.1/4.2."""
        ctx = self.ctx
        ref = kle[0]
        ri = q_pos.index(ref)
        b = mvec.shape[1]
        ref_edge = epm[ri]
        d: dict[int, np.ndarray] = {}
        for qi in kle:
            i = q_pos.index(qi)
            diff = mvec[i] != mvec[ri]
            if ctx.start_flag[qi, el] != ctx.start_flag[ref, el]:
                diff = diff | mvec[i] | mvec[ri]
            a, bq = ref_edge, epm[i]
            if (a is None) != (bq is None) or (
                    a is not None and bq is not None and not np.array_equal(a, bq)):
                am = np.ones((b, b), dtype=bool) if a is None else a
                bm = np.ones((b, b), dtype=bool) if bq is None else bq
                diff = diff | np.any(np.tril(am != bm, k=-1), axis=1)
            d[qi] = diff
        return d

    # -- group (graphlet) planning --

    def _plan_group(self, g, el, type_id, attrs, b, mvec, epm,
                    steps: list, stats: RunStats) -> None:
        ctx = self.ctx
        nu = ctx.nu
        shared = len(g) >= 2
        kleene = all(ctx.kleene_flag[qi, el] for qi in g)
        assert shared is False or kleene, "shared groups must be Kleene (Def. 4)"

        # per-event divergence flags within this group
        if shared:
            div = np.zeros(b, dtype=bool)
            m0 = mvec[0]
            e0 = epm[0]
            s0 = ctx.start_flag[g[0], el]
            for i in range(1, len(g)):
                div |= mvec[i] != m0
                if ctx.start_flag[g[i], el] != s0:
                    div |= mvec[i] | m0
                a, bq = e0, epm[i]
                if (a is None) != (bq is None) or (
                        a is not None and bq is not None and not np.array_equal(a, bq)):
                    am = np.ones((b, b), dtype=bool) if a is None else a
                    bm = np.ones((b, b), dtype=bool) if bq is None else bq
                    div |= np.any(np.tril(am != bm, k=-1), axis=1)
        else:
            div = np.zeros(b, dtype=bool)

        d = int(div.sum())
        n_z = d * nu
        B_local = 1 + nu + n_z
        if B_local > self.max_local_basis and shared:
            # basis would blow up: force split (the optimizer should normally
            # have prevented this; AlwaysShare can reach it)
            for qi in g:
                self._plan_group([qi], el, type_id, attrs, b,
                                 mvec[[g.index(qi)]], [epm[g.index(qi)]],
                                 steps, stats)
            stats.split_bursts += 1
            return

        live = mvec.all(axis=0) & ~div
        dead = ~mvec.any(axis=0) & ~div

        # local basis: 0 = gate, 1..nu = x_u, nu+1.. = z snapshots
        z_ids = {}
        nxt = 1 + nu
        div_rows = np.nonzero(div)[0]
        for i in div_rows:
            for ui in range(nu):
                z_ids[(int(i), ui)] = nxt
                nxt += 1
        if shared:
            # snapshots are a *shared-execution* artifact (Defs. 8/9); the
            # non-shared path keeps plain per-query aggregates
            stats.snapshots_created += nu + n_z
            stats.snapshots_propagated += B_local

        # dense fast path: no edge predicates and no divergent/dead rows
        # means the in-burst adjacency is exactly strictly-lower all-ones,
        # with the O(b) closed form (beyond-paper; see kernels/ops.py)
        dense = (kleene and epm[0] is None and d == 0 and not dead.any()
                 and b <= DENSE_B_MAX)

        # common in-burst adjacency
        if dense:
            em = None
        else:
            if kleene:
                em = np.tril(np.ones((b, b)), k=-1)
                if epm[0] is not None:
                    em *= np.tril(epm[0], k=-1)
            else:
                em = np.zeros((b, b))
            em[div | dead, :] = 0.0
            if not shared:
                em[~mvec[0], :] = 0.0

        sum_units = []
        for ui, u in enumerate(ctx.units):
            if u[0] != "sum":
                continue
            _, e_name, attr = u
            vals = None
            if ctx.schema.type_id(e_name) == type_id:
                vals = (np.ones(b) if attr is None
                        else attrs[:, ctx.schema.attr_col(attr)])
            sum_units.append((ui, vals))

        steps.append(_GroupPlan(
            g=list(g), el=el, type_id=type_id, attrs=attrs, b=b, mvec=mvec,
            epm=epm, shared=shared, div=div, div_rows=div_rows, live=live,
            dead=dead, B_local=B_local, z_ids=z_ids, dense=dense, em=em,
            start_q0=bool(ctx.start_flag[g[0], el]), sum_units=sum_units))

    # -- phase 2 helpers: injection rows for the batched launches --

    def _count_base(self, p: _GroupPlan) -> np.ndarray:
        base_c = np.zeros((p.b, p.B_local))
        base_c[p.live, 1 + 0] = 1.0               # x_count entry
        if p.start_q0:
            base_c[p.live, 0] = 1.0               # gate entry (start contribution)
        for i in p.div_rows:
            base_c[i, p.z_ids[(int(i), 0)]] = 1.0
        return base_c

    def _sum_base(self, p: _GroupPlan, ui: int, vals) -> np.ndarray:
        # injection shares the mask and includes attr*count coefficients
        ccoef = p.cjob.result
        base_s = np.zeros((p.b, p.B_local))
        base_s[p.live, 1 + ui] = 1.0
        if vals is not None:
            base_s[p.live] += vals[p.live, None] * ccoef[p.live]
        for i in p.div_rows:
            base_s[i, :] = 0.0
            base_s[i, p.z_ids[(int(i), ui)]] = 1.0
        return base_s

    # -- phase 3: fold a graphlet's coefficients into the state functionals --

    def _finalize_group(self, p: _GroupPlan, arow, rrow, gaterow) -> None:
        ctx = self.ctx
        C = ctx.layout.size
        nu = ctx.nu
        g = p.g
        b = p.b
        el = p.el
        ccoef = p.cjob.result
        scoefs = {ui: p.sjobs[ui].result for ui, _ in p.sum_units}
        z_ids = p.z_ids
        div_rows = p.div_rows

        W = np.zeros((len(g), p.B_local, C))
        for gi, qi in enumerate(g):
            W[gi, 0] = gaterow[qi]
            for ui in range(nu):
                W[gi, 1 + ui] = ctx.pt_mask[qi, el] @ arow[qi, ui]

        # event-level snapshot value functionals (Def. 9), ascending order.
        # P[u] caches coef_u @ W[gi]; every snapshot fill is a rank-1 update
        # so *live* rows that reference earlier z columns stay current.
        if len(div_rows):
            coefs = {0: ccoef, **scoefs}
            lower = np.tril(np.ones((b, b), dtype=bool), k=-1)
            for gi, qi in enumerate(g):
                P = {u: coefs[u] @ W[gi] for u in coefs}

                def fill(zcol: int, f: np.ndarray) -> None:
                    W[gi, zcol] = f
                    for u in coefs:
                        col = coefs[u][:, zcol]
                        if col.any():
                            P[u] += np.outer(col, f)

                adj_q = lower.copy()
                if p.epm[gi] is not None:
                    adj_q &= p.epm[gi]
                adj_q &= p.mvec[gi][None, :]
                startq = 1.0 if ctx.start_flag[qi, el] else 0.0
                for i in div_rows:
                    i = int(i)
                    row = adj_q[i].astype(float)
                    if p.mvec[gi][i]:
                        f_c = startq * gaterow[qi] + W[gi, 1 + 0] + row @ P[0]
                    else:
                        f_c = np.zeros(C)
                    fill(z_ids[(i, 0)], f_c)
                    for ui, u in enumerate(ctx.units):
                        if u[0] != "sum":
                            continue
                        _, e_name, attr = u
                        if p.mvec[gi][i]:
                            f_s = W[gi, 1 + ui] + row @ P[ui]
                            if ctx.schema.type_id(e_name) == p.type_id:
                                v = (1.0 if attr is None
                                     else p.attrs[i, ctx.schema.attr_col(attr)])
                                f_s = f_s + v * f_c
                        else:
                            f_s = np.zeros(C)
                        fill(z_ids[(i, ui)], f_s)

        # fold column sums into state functionals: one stacked einsum per
        # graphlet instead of a matvec per (member, unit)
        used = [0] + sorted(scoefs)               # unit rows: count first
        S = np.stack([ccoef.sum(axis=0)] +
                     [scoefs[ui].sum(axis=0) for ui in sorted(scoefs)])
        upd = np.einsum("ub,gbc->guc", S, W)      # [len(g), len(used), C]
        for gi, qi in enumerate(g):
            end = ctx.end_flag[qi, el]
            for r, ui in enumerate(used):
                arow[qi, ui, el] += upd[gi, r]
                if end:
                    rrow[qi, ui] += upd[gi, r]


# --------------------------------------------------------------------------
# windowed runtime: panes -> sliding windows -> per-query results
# --------------------------------------------------------------------------


@dataclass
class _Instance:
    start: int
    u: np.ndarray
    events: list = field(default_factory=list)  # retained only for min/max


def fold_panes(Ms: list[np.ndarray], u0: np.ndarray) -> np.ndarray:
    """Replay a window's state from per-pane transfer matrices.

    Applies the panes' transfer matrices to the fresh state ``u0`` in stream
    order — the same ``u @ M.T`` fold :func:`advance_instances` performs
    incrementally, so replaying a window from stored matrices reproduces the
    incremental run.  This is the event-time revision primitive: after a late
    event dirties one pane, only that pane's ``M`` is recomputed and the
    window is re-folded from the stored matrices of the clean panes.
    """
    u = u0
    with np.errstate(over="ignore", invalid="ignore"):
        for M in Ms:
            u = u @ M.T
    return u


def advance_instances(M: np.ndarray, insts: dict[int, "_Instance"]) -> None:
    """Advance every open window instance by one pane: a single [n, C] x
    [C, C] matmul instead of one matvec per instance (the per-pane fold of
    the transfer matrix, vectorized across overlapping windows)."""
    if not insts:
        return
    members = list(insts.values())
    with np.errstate(over="ignore", invalid="ignore"):
        U = np.stack([inst.u for inst in members]) @ M.T
    for i, inst in enumerate(members):
        inst.u = U[i]


class HamletRuntime:
    """Evaluates a workload over a stream, pane by pane (Sec. 2.2 / 3.1)."""

    def __init__(self, workload: Workload, policy=None, backend: str = "np",
                 batch_exec: bool = True, shard_slices=None):
        from .optimizer import DynamicPolicy

        self.workload = workload
        self.policy = policy if policy is not None else DynamicPolicy()
        self.backend = backend
        self.pane = pane_size_for(workload.windows)
        self.components = workload.sharable_components()
        self.ctxs = [ComponentContext(workload.schema,
                                      [workload.atomic[i] for i in comp])
                     for comp in self.components]
        # one executor for the whole runtime: every pane — shed or admitted,
        # any component — funnels its jobs through the same bucketed batches
        self.executor = PaneBatchExecutor(backend=backend, batched=batch_exec,
                                          shard_slices=shard_slices)
        self.stats = RunStats()
        self._empty_M: list[np.ndarray] | None = None

    def empty_pane_matrices(self) -> list[np.ndarray]:
        """Per-component transfer matrix of an event-free pane (cached).

        Every empty pane folds identically, so the event-time layer stores
        matrices only for panes that saw events and substitutes this one for
        the gaps when replaying a window (see :func:`fold_panes`).
        """
        if self._empty_M is None:
            empty = EventBatch(self.workload.schema, np.array([], np.int32),
                               np.array([], np.int64), None)
            scratch = RunStats()
            self._empty_M = [
                PaneProcessor(ctx, self.policy, backend=self.backend,
                              executor=self.executor).process(empty, scratch)
                for ctx in self.ctxs]
        return self._empty_M

    def run(self, batch: EventBatch, t_end: int | None = None) -> dict:
        """Process a stream; returns {(query, group, window_start): {agg: val}}.

        Results for user queries with top-level Or/And are combined per
        Sec. 5.  Windows are aligned to multiples of each query's slide,
        starting at 0; only windows fully contained in [0, t_end) emit.
        """
        if t_end is None:
            t_end = int(batch.time.max()) + 1 if len(batch) else 0
        t_end = ((t_end + self.pane - 1) // self.pane) * self.pane

        atomic_results: dict[tuple[int, int, int], dict] = {}
        for group_key, gbatch in batch.partition_by_group().items():
            self._run_partition(gbatch, t_end, group_key, atomic_results)

        return self._combine(atomic_results)

    # -- per group partition --

    def _run_partition(self, batch: EventBatch, t_end: int, group_key: int,
                       out: dict) -> None:
        for comp, ctx in zip(self.components, self.ctxs):
            proc = PaneProcessor(ctx, self.policy, backend=self.backend,
                                 executor=self.executor)
            insts: list[dict[int, _Instance]] = [dict() for _ in comp]
            for t0, pane_ev in split_panes(batch, self.pane, 0, t_end):
                M = proc.process(pane_ev, self.stats)
                for ci, aqi in enumerate(comp):
                    q = self.workload.atomic[aqi]
                    # open new instances whose window starts at this pane
                    if t0 % q.slide == 0 and t0 + q.within <= t_end:
                        insts[ci][t0] = _Instance(t0, ctx.layout.fresh_state())
                    needs_minmax = ci in ctx.minmax_queries
                    advance_instances(M[ci], insts[ci])
                    for w0, inst in list(insts[ci].items()):
                        if needs_minmax and len(pane_ev):
                            inst.events.append(pane_ev)
                        if w0 + q.within == t0 + self.pane:
                            out[(aqi, group_key, w0)] = self._emit(
                                ctx, ci, q, inst, group_key)
                            del insts[ci][w0]
                            self.stats.windows_emitted += 1

    def _emit(self, ctx: ComponentContext, ci: int, q: AtomicQuery,
              inst: _Instance, group_key: int) -> dict:
        from .query import Agg, AggKind

        u = inst.u
        vals: dict[str, float] = {}
        for agg in q.aggs:
            if agg.kind == AggKind.COUNT_STAR:
                vals[repr(agg)] = float(u[ctx.layout.rp_idx(("count",))])
            elif agg.kind == AggKind.COUNT_TYPE:
                vals[repr(agg)] = float(u[ctx.layout.rp_idx(("sum", agg.type_name, None))])
            elif agg.kind == AggKind.SUM:
                vals[repr(agg)] = float(
                    u[ctx.layout.rp_idx(("sum", agg.type_name, agg.attr))])
            elif agg.kind == AggKind.AVG:
                s = u[ctx.layout.rp_idx(("sum", agg.type_name, agg.attr))]
                c = u[ctx.layout.rp_idx(("sum", agg.type_name, None))]
                vals[repr(agg)] = float(s / c) if c else float("nan")
            elif agg.kind in (AggKind.MIN, AggKind.MAX):
                from .minmax import window_minmax

                evs = (EventBatch.concat(inst.events) if inst.events
                       else None)
                vals[repr(agg)] = window_minmax(
                    self.workload.schema, q, evs, agg,
                    run_type_ids=ctx.relevant_type_ids, pane=self.pane)
        return vals

    # -- Or/And combination (Sec. 5) --

    def _combine(self, atomic_results: dict) -> dict:
        return combine_results(self.workload, atomic_results)


def vals_equal(a: dict, b: dict) -> bool:
    """Exact equality of window aggregate dicts, treating NaN == NaN (an
    AVG over zero matches is NaN in both runs and must not read as a
    difference)."""
    import math

    if a.keys() != b.keys():
        return False
    for k, va in a.items():
        vb = b[k]
        if va != vb and not (isinstance(va, float) and isinstance(vb, float)
                             and math.isnan(va) and math.isnan(vb)):
            return False
    return True


def combine_results(workload: Workload, atomic_results: dict) -> dict:
    """Combine atomic sub-query results into user-query results (Sec. 5)."""
    out: dict = {}
    for qname, idxs, comb in workload.combines:
        if comb is None:
            aqi = idxs[0]
            for (ai, gk, w0), vals in atomic_results.items():
                if ai == aqi:
                    out[(qname, gk, w0)] = vals
            continue
        left, right = idxs
        keys = set((gk, w0) for (ai, gk, w0) in atomic_results if ai == left)
        keys |= set((gk, w0) for (ai, gk, w0) in atomic_results if ai == right)
        for gk, w0 in keys:
            lv = atomic_results.get((left, gk, w0), {})
            rv = atomic_results.get((right, gk, w0), {})
            c1 = lv.get("COUNT(*)", 0.0)
            c2 = rv.get("COUNT(*)", 0.0)
            out[(qname, gk, w0)] = {"COUNT(*)": comb.combine_counts(c1, c2)}
    return out
