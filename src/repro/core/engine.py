"""HAMLET executor (paper Sec. 3.3 / Algorithm 1) and windowed runtime.

Execution model
---------------
Events arrive in panes (gcd of all windows/slides).  Within a pane, events of
the types relevant to a sharable component are segmented into *bursts*
(maximal same-type runs — Def. 10); each burst forms a new *graphlet*
(Def. 6).  Per burst the sharing policy decides which queries share the
graphlet (Sec. 4).  Shared propagation maintains per-event *coefficient rows*
over a small local snapshot basis:

    idx 0          gate entry      (start contributions; value = query's gate)
    idx 1..nu      x_u             graphlet-level snapshot per linear unit
                                   (Def. 8: value = sum of predecessor-type
                                   running aggregates)
    idx nu+1..     z               event-level snapshots for divergent events
                                   (Def. 9: predicate differences)

Four-phase pipeline (plan → execute → finalize → fold)
------------------------------------------------------
A pane is processed in three engine phases plus the runtime's window fold:

1. **plan** — the *prologue* runs batched across all K panes of a
   micro-batch flush (:meth:`PaneProcessor.plan_prologues`): one
   concatenated relevance filter, one run-length segmentation (memoized on
   the flush's type sequence — the same structural recurrence the plan
   cache banks on), and one stacked per-(query, type) predicate pass over
   every event of each type across the whole flush, sliced back per pane;
   the packed signature bytes the cache probe consumes are assembled in
   the same pass.  The order-sensitive *finish* then walks panes in
   submission order: the sharing policy decides each burst's groups (a
   whole-pane decision memo keyed on the divergence image replays
   decisions while the running event count stays inside the policy's
   replay-stable interval), and each group's masks/adjacency/injection
   rows are captured as propagation *jobs*.  Nothing here depends on the
   running aggregates, so the whole pane plans up front.  The structural
   output of this phase is memoized in a
   :class:`~repro.core.plan_cache.PanePlanCache`: the cache key is the
   pane signature — type run-length encoding, packed per-burst predicate /
   edge-mask bits, negation hits, and the optimizer's decided groups — so a
   repeated pane shape skips group construction, adjacency/injection-row
   building and the snapshot column layout entirely and only swaps in fresh
   attribute data (or reuses the cached step list zero-copy).  The sharing
   decision is recomputed every pane and lives in the *key*, so plan reuse
   never freezes the share/no-share choice.
2. **execute** — jobs go to a :class:`~repro.core.batch_exec
   .PaneBatchExecutor`, which buckets them by size (ragged edges padded
   where exact) and solves each bucket with **one** batched launch of the
   masked prefix-propagation primitive (``repro.kernels``) or the dense
   closed form.  Two rounds: count-unit jobs first, then the sum-unit jobs
   that inject their coefficients.  A :class:`PaneMicroBatcher` extends the
   backlog *across panes*: up to ``micro_batch`` planned panes flush
   together, one launch per size bucket per K panes, with finalize deferred
   per pane.
3. **finalize** — executed coefficients fold into per-query *state
   functionals* (linear maps over the pane-entry state channels), so the
   pane yields one transfer matrix ``M[q]`` per query.  By default this
   phase runs through the :class:`~repro.core.fold_exec.FoldExecutor`: the
   pane's steps are *levelized* (each per-query chain of graphlets — and
   its negation gates — stays strictly ordered; query-disjoint steps share
   a level) and every level folds as one stacked launch per shape bucket,
   across the pane **and** across every pane of a micro-batch flush.  The
   level schedule is cached on the :class:`~repro.core.plan_cache.PanePlan`
   and the merged K-pane flush plan in the executor's own LRU, so warm
   panes skip fold planning entirely.  A *scannable* flush plan (no
   negation splits, one d == 0 bucket per round) carries a compiled
   execution form: on the jax/pallas backends the whole warm flush is
   **one** ``jax.lax.scan`` device program
   (:func:`repro.kernels.ops.fold_rounds_scan`) — one launch and one host
   sync however deep the fold chain is — and on the numpy backend its
   fused host twin (one flush-wide segmented ``S`` fill + gather, then the
   identical stacked ops per round).  :meth:`PaneProcessor.finalize` keeps
   the sequential per-graphlet replay as the reference path
   (``fold_exec=False``) — all paths are bitwise identical
   (``tests/test_fold_exec.py``, ``tests/test_fold_scan.py``).
4. **fold** — sliding-window instances advance with a single batched [C×C]
   matmul per pane — overlapping windows share all per-event work (the
   paper's pane sharing, Sec. 3.1).  Under micro-batching the drained panes
   fold as one stacked matmul chain, in stream order, so the fold stays
   bitwise identical to per-pane execution.  Window *replays* (the
   event-time revision path) go through the same executor:
   :meth:`FoldExecutor.fold_windows` is the batched twin of
   :func:`fold_panes`, re-folding every dirty window of a revision storm
   as one stacked launch set.

``RunStats`` carries wall-clock timers for all four phases (``plan_s`` /
``execute_s`` / ``finalize_s`` / ``fold_s``) and the plan-cache hit/miss
counters, so benchmarks read the phase split straight from the engine.

Observability: every layer accepts an optional ``obs=`` handle (a
:class:`repro.obs.Observability` facade — span tracer, metrics registry,
sharing-decision audit log).  Phase spans are recorded from the *same*
``perf_counter`` readings that feed ``RunStats``, so per-pane spans sum to
the phase totals; the audit log captures each optimizer share/no-share
decision verbatim as it enters the plan-cache key.  With ``obs=None``
(default) every hook is a single guarded attribute test — zero cost.

Host/device residency on a fully-warm flush: the host side is the batched
prologue (numpy vector passes), the plan-cache dict probes, and the
executor submit bookkeeping; everything shape-dependent was precomputed
into cached plans.  On the jax/pallas backends the execute phase launches
every bucket before syncing once via ``ops.device_get_all`` (bucket
outputs stay device-resident until that fetch — see ``batch_exec.py``),
and the fold phase is one ``lax.scan`` launch whose index operands and
fresh state already live on device; its single ``np.asarray`` of the
scanned state is the flush's one fold-side sync point.  On the numpy
backend the executor reuses host staging buffers across flushes instead.

Trend counts grow like 2^g and overflow fixed-width types for realistic panes
(the paper is silent on this); the engine computes in float64 by default.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from time import perf_counter
from typing import ClassVar

import numpy as np

from ..kernels.ops import DENSE_B_MAX
from ..obs.trace import NULL_SPAN
from .batch_exec import PaneBatchExecutor, PropagateJob
from .events import EventBatch, StreamSchema, pane_size_for, split_panes
from .fold_exec import FoldExecutor
from .plan_cache import PanePlan, PanePlanCache
from .query import AtomicQuery, Workload
from .template import QueryTemplate, build_template

__all__ = ["ComponentContext", "PaneProcessor", "PaneMicroBatcher",
           "HamletRuntime", "RunStats", "fold_panes", "vals_equal"]


# --------------------------------------------------------------------------
# static per-component context
# --------------------------------------------------------------------------


@dataclass
class _NegRule:
    kind: str                 # "leading" | "mid" | "trailing"
    before_local: np.ndarray  # local type indices whose A-sums are cut (mid)


class ComponentContext:
    """Prepared static info for one sharable component of the workload."""

    def __init__(self, schema: StreamSchema, queries: list[AtomicQuery]):
        self.schema = schema
        self.queries = list(queries)
        self.k = len(queries)
        self.templates: list[QueryTemplate] = [build_template(schema, q) for q in queries]

        pos: set[int] = set()
        neg: set[int] = set()
        for t in self.templates:
            pos |= set(np.nonzero(t.match)[0].tolist())
            neg |= set(np.nonzero(t.negative)[0].tolist())
        self.pos_type_ids = sorted(pos)
        self.neg_type_ids = sorted(neg)
        self.relevant_type_ids = sorted(pos | neg)
        # O(1) relevance filter: keep = lut[type_id] (np.isin re-sorts the
        # needle list on every pane; the plan prologue is on the warm path)
        self.relevant_lut = np.zeros(len(schema.types), dtype=bool)
        self.relevant_lut[self.relevant_type_ids] = True
        self.local = {e: i for i, e in enumerate(self.pos_type_ids)}

        units: set[tuple] = set()
        for q in queries:
            units |= set(u for u in q.units if u[0] in ("count", "sum"))
        from .snapshot import ChannelLayout

        self.units = tuple(sorted(units, key=lambda u: (u[0] != "count",
                                                        tuple(str(x) for x in u))))
        self.layout = ChannelLayout(list(self.units), self.pos_type_ids)
        self.nu = len(self.units)

        # channel-column lookup tables for the vectorized pane assembly
        self.a_cols = np.array(
            [[self.layout.a_idx(u, e) for e in self.pos_type_ids]
             for u in self.units], dtype=int).reshape(self.nu, -1)
        self.rp_cols = np.array([self.layout.rp_idx(u) for u in self.units],
                                dtype=int)

        t = len(self.pos_type_ids)
        self.start_flag = np.zeros((self.k, t), dtype=bool)
        self.end_flag = np.zeros((self.k, t), dtype=bool)
        self.match_flag = np.zeros((self.k, t), dtype=bool)
        self.kleene_flag = np.zeros((self.k, t), dtype=bool)
        # pt_mask[q, e, e'] over local positive types
        self.pt_mask = np.zeros((self.k, t, t), dtype=bool)
        for qi, tmpl in enumerate(self.templates):
            for e, el in self.local.items():
                self.start_flag[qi, el] = tmpl.start[e]
                self.end_flag[qi, el] = tmpl.end[e]
                self.match_flag[qi, el] = tmpl.match[e]
                self.kleene_flag[qi, el] = tmpl.kleene[e]
                for e2, el2 in self.local.items():
                    self.pt_mask[qi, el, el2] = tmpl.pred_type[e, e2]

        # negation rules: neg type id -> list[(query idx, _NegRule)]
        self.neg_rules: dict[int, list[tuple[int, _NegRule]]] = {}
        for qi, q in enumerate(self.queries):
            for nc in q.info.negatives:
                nid = schema.type_id(nc.neg_type)
                if nc.before is None:
                    rule = _NegRule("leading", np.array([], dtype=int))
                elif nc.after is None:
                    rule = _NegRule("trailing", np.array([], dtype=int))
                else:
                    bl = np.array(sorted(self.local[schema.type_id(b)]
                                         for b in nc.before), dtype=int)
                    rule = _NegRule("mid", bl)
                self.neg_rules.setdefault(nid, []).append((qi, rule))

        # per-(query,type) predicate/edge-pred lookup
        self._preds = {}
        self._edge_preds = {}
        for qi, q in enumerate(self.queries):
            for tname, ps in q.preds:
                self._preds[(qi, schema.type_id(tname))] = ps
            for tname, eps in q.edge_preds:
                self._edge_preds[(qi, schema.type_id(tname))] = eps

        # queries that share E+ (Def. 4): kleene flag per local type
        self.kleene_queries = {
            el: [qi for qi in range(self.k) if self.kleene_flag[qi, el]]
            for el in range(t)
        }
        # per-local-type query sets, hoisted out of the per-burst plan walk
        self.q_pos = {el: [qi for qi in range(self.k)
                           if self.match_flag[qi, el]] for el in range(t)}
        self.kle_pos = {el: [qi for qi in self.q_pos[el]
                             if self.kleene_flag[qi, el]] for el in range(t)}
        # type ids whose kleene query set is too wide for the dyn-fast
        # signature walk (empty on every shipped workload, so the per-pane
        # gate is one isdisjoint probe instead of a max() genexpr)
        self.kle_big = frozenset(tid for tid, el in self.local.items()
                                 if len(self.kle_pos[el]) >= 60)
        # local types with at least one edge-predicated query (the per-burst
        # edge-mask walk is skipped entirely for the rest)
        self.edge_pred_els = {
            el: any((qi, self.pos_type_ids[el]) in self._edge_preds
                    for qi in self.q_pos[el]) for el in range(t)}
        # sum units resolved to (unit idx, source type id, attr column | None)
        self.sum_unit_cols = [
            (ui, schema.type_id(u[1]),
             None if u[2] is None else schema.attr_col(u[2]))
            for ui, u in enumerate(self.units) if u[0] == "sum"]
        # which queries need the min/max side path
        self.minmax_queries = [qi for qi, q in enumerate(self.queries)
                               if any(u[0] == "minmax" for u in q.units)]

    def match_vec(self, qi: int, type_id: int, attrs: np.ndarray) -> np.ndarray:
        ps = self._preds.get((qi, type_id), ())
        m = np.ones(len(attrs), dtype=bool)
        for p in ps:
            m &= p.eval(attrs, self.schema)
        return m

    def match_stack(self, q_pos: list[int], type_id: int,
                    attrs: np.ndarray) -> np.ndarray:
        """Stacked :meth:`match_vec` for several queries: one ``[nq, n]``
        allocation instead of ``nq`` vectors plus an ``np.stack`` copy.
        Row ``i`` is bitwise ``match_vec(q_pos[i], ...)`` (elementwise
        predicate evaluation into a preallocated row)."""
        m = np.ones((len(q_pos), len(attrs)), dtype=bool)
        for i, qi in enumerate(q_pos):
            for p in self._preds.get((qi, type_id), ()):
                m[i] &= p.eval(attrs, self.schema)
        return m

    def edge_mask(self, qi: int, type_id: int, attrs: np.ndarray) -> np.ndarray | None:
        """[successor, predecessor]-oriented edge-predicate mask, or None."""
        eps = self._edge_preds.get((qi, type_id), ())
        if not eps:
            return None
        b = len(attrs)
        m = np.ones((b, b), dtype=bool)
        for ep in eps:
            col = attrs[:, self.schema.attr_col(ep.attr)]
            m &= ep.eval_pairs(col, col).T
        return m


# --------------------------------------------------------------------------
# statistics (drives the benefit model and the benchmark metrics)
# --------------------------------------------------------------------------


@dataclass
class RunStats:
    events: int = 0
    bursts: int = 0
    shared_bursts: int = 0
    split_bursts: int = 0
    graphlets: int = 0
    shared_graphlets: int = 0
    snapshots_created: int = 0
    snapshots_propagated: int = 0
    propagate_cells: int = 0      # total solved cells (rows x basis cols)
    decisions: int = 0
    panes: int = 0
    windows_emitted: int = 0
    # four-phase wall-clock split (seconds) — the engine times itself so
    # benchmark phase breakdowns need no external profiler
    plan_s: float = 0.0
    execute_s: float = 0.0
    finalize_s: float = 0.0
    fold_s: float = 0.0
    # plan-cache traffic (counted only when a cache is attached)
    plan_cache_hits: int = 0
    plan_cache_misses: int = 0

    # Fields whose totals are invariant under group-disjoint sharding of the
    # stream: a fleet of runtimes processing a partition of the groups
    # produces the same sums as one runtime processing everything.  Wall
    # timers (meaningful only as totals) and plan-cache traffic (each
    # instance has its own cache, so hit/miss splits shift with placement)
    # are excluded — and so are the sharing/snapshot counters: the
    # share-or-split decision operates on the co-resident pane batch, so
    # which groups live together changes the sharing opportunities taken
    # (never the results).
    COUNT_FIELDS: ClassVar[tuple[str, ...]] = (
        "events", "bursts", "decisions", "panes", "windows_emitted")

    def merge(self, o: "RunStats") -> None:
        for f in self.__dataclass_fields__:
            setattr(self, f, getattr(self, f) + getattr(o, f))

    @classmethod
    def merged(cls, parts) -> "RunStats":
        """Fold many instances (e.g. one per shard) into a fleet total."""
        out = cls()
        for p in parts:
            out.merge(p)
        return out

    def counts(self) -> dict[str, int]:
        """The sharding-invariant count fields (see ``COUNT_FIELDS``)."""
        return {f: getattr(self, f) for f in self.COUNT_FIELDS}

    def phase_split(self) -> dict[str, float]:
        """Fractions of measured engine time per phase (sums to ~1)."""
        total = self.plan_s + self.execute_s + self.finalize_s + self.fold_s
        if total <= 0:
            return {"plan": 0.0, "execute": 0.0, "finalize": 0.0, "fold": 0.0}
        return {"plan": self.plan_s / total, "execute": self.execute_s / total,
                "finalize": self.finalize_s / total,
                "fold": self.fold_s / total}


# --------------------------------------------------------------------------
# pane processor (Algorithm 1 over one pane, producing transfer matrices)
# --------------------------------------------------------------------------


@dataclass
class _NegStep:
    """Negation rules that fired for one burst (applied during finalize)."""

    hits: list  # [(query idx, _NegRule)]


@dataclass
class _GroupPlan:
    """One graphlet's planned propagation: masks, adjacency, and job handles.

    Captured during the plan phase; coefficients arrive from the batched
    executor; the finalize phase folds them into the state functionals.
    """

    g: list
    el: int
    type_id: int
    attrs: np.ndarray
    b: int
    mvec: np.ndarray              # [len(g), b]
    epm: list
    shared: bool
    div: np.ndarray               # [b] divergence flags
    div_rows: np.ndarray
    live: np.ndarray
    dead: np.ndarray
    B_local: int
    z_ids: dict
    dense: bool
    em: np.ndarray | None         # in-burst adjacency (None when dense)
    start_q0: bool
    sum_units: list               # [(ui, injection values | None)]
    bi: int = -1                  # index of the source burst within the pane
    rows: list | None = None      # member rows within the burst's mvec stack
    base_c: np.ndarray | None = None  # count-round injection rows (cacheable)
    trivial: bool = False         # non-Kleene: zero adjacency, result == base

    # NOTE: job handles live on the _PendingPane (parallel ``jobs`` list),
    # never on the plan — group plans are immutable after construction so a
    # cached pane shape can be reused zero-copy across panes and micro-batch
    # members.


class _Prologue:
    """Order-independent phase-1 products of one pane: filtered events,
    burst runs, stacked match vectors with their signature byte images, and
    negation hits — everything :meth:`PaneProcessor._plan_finish` consumes
    that does not read mutable planner state.  Built per pane by
    :meth:`PaneProcessor._plan_prologue` or, for a whole micro-batch, in one
    stacked pass by :meth:`PaneProcessor.plan_prologues`."""

    __slots__ = ("ev", "runs", "mv_type", "mv_bytes", "neg_type", "present",
                 "has_edge", "codes", "runs_shape", "sig_mv")

    def __init__(self, ev, runs, mv_type, mv_bytes, neg_type, present,
                 has_edge, codes=None, runs_shape=None, sig_mv=None):
        self.ev = ev
        self.runs = runs
        self.mv_type = mv_type
        self.mv_bytes = mv_bytes
        self.neg_type = neg_type
        self.present = present
        self.has_edge = has_edge
        # per-type packed divergence images (pattern-based policies only):
        # tid -> [n_events] int64 coverage codes, sliced per burst by the
        # dyn-fast walk
        self.codes = codes or {}
        # precomputed ((tid, burst len), ...) signature prefix, shared by
        # every plan-cache key form; None on the unbatched path
        self.runs_shape = runs_shape
        # the match-bit bytes of every live type in ``present`` order —
        # the plan-cache key consumes this tuple as is
        self.sig_mv = sig_mv


class PaneProcessor:
    def __init__(self, ctx: ComponentContext, policy, backend: str = "np",
                 max_local_basis: int = 512, executor=None, plan_cache=None,
                 fold_exec=None, obs=None, comp: int = 0):
        self.ctx = ctx
        self.policy = policy
        self.backend = backend
        self.max_local_basis = max_local_basis
        self.obs = obs
        self.comp = comp
        self.executor = (executor if executor is not None
                         else PaneBatchExecutor(backend=backend))
        self.plan_cache: PanePlanCache | None = plan_cache
        self.fold_exec = fold_exec
        # policy traits probed once (the plan hot path reads them per pane)
        self._policy_static = getattr(policy, "decision_static", False)
        self._policy_pattern = getattr(policy, "pattern_based", False)
        # the PanePlan the most recent plan() hit or created (the fold
        # schedule is cached on it); None when planning uncached
        self._last_host: PanePlan | None = None
        # static sharing policies decide per (type, candidate set) only:
        # their group layout is memoized per local type
        self._static_groups: dict[int, tuple] = {}
        # divergence-image layout per local type (candidate rows, reference
        # row, start-flag diff) and burst-slice -> pattern-multiset memo for
        # the dyn-fast walk; parked on the (long-lived) context so warm
        # sweeps with fresh processors keep their memoized extraction
        if not hasattr(ctx, "kle_layout_memo"):
            ctx.kle_layout_memo = {}
            ctx.pats_memo = {}
            ctx.dyn_pane_memo = {}
            ctx.seg_memo = {}
        self._kle_layout: dict[int, tuple] = ctx.kle_layout_memo
        self._pats_cache: dict[bytes, tuple] = ctx.pats_memo
        # micro-batch segmentation memo: (ktype bytes, pane bounds) ->
        # (per-pane runs, per-type (tid, idx, off) layout)
        self._seg_memo: dict[tuple, tuple] = ctx.seg_memo
        # whole-pane decision-walk memo for the dyn-fast path: (runs shape,
        # per-type divergence-code bytes) -> [(n_lo, n_hi, groups_all, sig_t,
        # decisions, splits)] — valid while the running event count stays in
        # the intersection of the bursts' decision-replay intervals
        self._dyn_pane_memo: dict[tuple, list] = ctx.dyn_pane_memo

    # -- burst segmentation (Def. 10) --

    @staticmethod
    def _segment(type_ids: np.ndarray) -> list[tuple[int, slice]]:
        if len(type_ids) == 0:
            return []
        cut = np.nonzero(np.diff(type_ids))[0] + 1
        bounds = np.concatenate([[0], cut, [len(type_ids)]])
        return [(int(type_ids[bounds[i]]), slice(int(bounds[i]), int(bounds[i + 1])))
                for i in range(len(bounds) - 1)]

    # -- main entry --

    def process(self, pane: EventBatch, stats: RunStats) -> np.ndarray:
        """Process one pane; returns per-query transfer matrices M [k, C, C].

        Single-pane convenience over the deferred phase API: plan the pane,
        run both execute rounds through the shared executor, finalize.
        Micro-batching callers drive the phases via :class:`PaneMicroBatcher`
        instead.
        """
        mb = PaneMicroBatcher(self.executor, k=1, fold_exec=self.fold_exec,
                              obs=self.obs)
        pend = mb.submit(self, pane, stats)
        mb.drain()
        return pend.finalize()

    # -- phase 1: plan --

    def plan(self, pane: EventBatch, stats: RunStats) -> list:
        """Phase 1: produce the pane's ordered step list (timed)."""
        t0 = perf_counter()
        # counts saturate to inf past float64 range (documented overflow
        # semantics) — keep the whole pipeline quiet about it
        with np.errstate(over="ignore", invalid="ignore"):
            steps = self._plan_pane(pane, stats)
        dt = perf_counter() - t0
        stats.plan_s += dt
        obs = self.obs
        if obs is not None:
            obs.pane_phase("plan", t0, dt,
                           key=obs.pane_key(pane) if obs.tracing else None)
        return steps

    def _plan_pane(self, pane: EventBatch, stats: RunStats) -> list:
        return self._plan_finish(pane, self._plan_prologue(pane), stats)

    def _wants_codes(self, el: int) -> bool:
        """Whether the prologue should pack a divergence image for this
        local type (pattern-based policy with a real sharing choice)."""
        return (self._policy_pattern
                and len(self.ctx.kle_pos[el]) >= 2
                and len(self.ctx.kle_pos[el]) < 60)

    def _div_codes(self, el: int, mv: np.ndarray) -> np.ndarray:
        """Packed per-event divergence image: bit ``j`` of an event's code
        marks candidate ``j`` diverging from the reference there (the
        stacked, edge-free twin of :meth:`_divergence_rows`).  Elementwise
        per event, so slices of a concatenated pass equal per-pane calls."""
        ctx = self.ctx
        lay = self._kle_layout.get(el)
        if lay is None:
            q_pos, kle = ctx.q_pos[el], ctx.kle_pos[el]
            ri = q_pos.index(kle[0])
            idx = np.array([q_pos.index(qi) for qi in kle])
            sdiff = ctx.start_flag[kle, el] != ctx.start_flag[kle[0], el]
            lay = self._kle_layout[el] = (
                ri, idx, sdiff if sdiff.any() else None,
                1 << np.arange(len(kle), dtype=np.int64))
        ri, idx, sdiff, bits = lay
        D = mv[idx] != mv[ri]
        if sdiff is not None:
            D[sdiff] |= mv[idx[sdiff]] | mv[ri]
        return bits @ D

    def _plan_prologue(self, pane: EventBatch) -> "_Prologue":
        """The order-independent half of phase 1: event filtering, burst
        segmentation, and the stacked per-(query, type) predicate pass.

        Touches no mutable planner state (``stats``, the benefit model, the
        plan cache), so the micro-batcher may run it for all K panes of a
        flush in one batched pass (:meth:`plan_prologues`) before the
        order-sensitive :meth:`_plan_finish` walks replay in submission
        order.
        """
        ctx = self.ctx
        keep = ctx.relevant_lut[pane.type_id]
        ev = pane.select(np.nonzero(keep)[0])
        runs = self._segment(ev.type_id)
        if not runs:
            return _Prologue(ev, runs, {}, {}, {}, [], False)

        # stacked per-type predicate evaluation: one vectorized pass per
        # (query, type) over *all* of the pane's events of that type, across
        # every burst at once, instead of a Python predicate walk per burst.
        # The transposed byte image of each stack doubles as the signature
        # source: a burst's exact match bits are a contiguous slice of it.
        mv_type: dict[int, np.ndarray] = {}
        mv_bytes: dict[int, bytes] = {}
        neg_type: dict[int, list] = {}
        codes: dict[int, np.ndarray] = {}
        cache = self.plan_cache
        present: list[int] = []
        has_edge = False
        for tid_arr in np.unique(ev.type_id):
            tid = int(tid_arr)
            present.append(tid)
            idx = np.nonzero(ev.type_id == tid)[0]
            attrs_t = ev.attrs[idx]
            if tid in ctx.neg_rules:
                neg_type[tid] = [(qi, rule, ctx.match_vec(qi, tid, attrs_t))
                                 for qi, rule in ctx.neg_rules[tid]]
            el = ctx.local.get(tid)
            if el is not None and ctx.q_pos[el]:
                if ctx.edge_pred_els[el]:
                    has_edge = True
                mv_type[tid] = ctx.match_stack(ctx.q_pos[el], tid, attrs_t)
                if cache is not None:
                    mv_bytes[tid] = np.ascontiguousarray(
                        mv_type[tid].T).tobytes()
                if self._wants_codes(el):
                    codes[tid] = self._div_codes(el, mv_type[tid])
        return _Prologue(ev, runs, mv_type, mv_bytes, neg_type, present,
                         has_edge, codes,
                         sig_mv=(tuple(mv_bytes[t] for t in present
                                       if t in mv_bytes)
                                 if cache is not None else None))

    def _seg_build(self, panes: list[EventBatch]) -> tuple:
        """Cold half of :meth:`plan_prologues`: the full index plan for one
        flush type-shape.  Returns ``(kidx, kb, ktype, perm, runs_per,
        layout, shapes_per)`` where ``kidx`` gathers the kept rows out of
        the pane-major attrs concatenation, ``perm`` gathers them in
        type-major order for the stacked predicate pass, and each layout
        entry carries every ctx-static per-type datum the warm loop reads
        (element id, q_pos, negation rules, edge/code flags, per-pane
        split offsets, type-major slice bounds)."""
        ctx = self.ctx
        type_cat = np.concatenate([p.type_id for p in panes])
        pb = np.cumsum([0] + [len(p) for p in panes])
        keep = ctx.relevant_lut[type_cat]
        kidx = np.nonzero(keep)[0]
        ktype = type_cat[kidx]
        kb = np.concatenate([[0], np.cumsum(keep)])[pb].tolist()
        # one RLE pass with forced cuts at pane boundaries: each pane's
        # runs are the consecutive cut pairs inside its slice
        cut = (np.nonzero(np.diff(ktype))[0] + 1) if len(ktype) else \
            np.zeros(0, dtype=int)
        cuts = np.unique(np.concatenate([cut, kb]))
        pos = np.searchsorted(cuts, kb)  # pane bounds are all in cuts
        cuts_l = cuts.tolist()
        tids_l = (ktype[cuts[:-1]].tolist() if len(ktype) else [])
        runs_per = []
        for i in range(len(panes)):
            base = cuts_l[pos[i]]
            runs_per.append([
                (tids_l[j], slice(cuts_l[j] - base, cuts_l[j + 1] - base))
                for j in range(pos[i], pos[i + 1])])
        layout, perm_parts, lo = [], [], 0
        all_static = True
        for tid in sorted(set(tids_l)):
            idx = np.nonzero(ktype == tid)[0]
            el = ctx.local.get(tid)
            live = el is not None and bool(ctx.q_pos[el])
            qp = ctx.q_pos[el] if live else None
            neg = ctx.neg_rules.get(tid)
            wants = live and self._wants_codes(el)
            stat = None
            if live and not any(ctx._preds.get((qi, tid)) for qi in qp):
                # predicate-free type: the stacked match pass is all-ones —
                # a pure function of the type sequence — so the stack, its
                # signature byte image, and the divergence codes are
                # seg-static (consumers only ever read/slice them)
                mv_cat = np.ones((len(qp), len(idx)), dtype=bool)
                stat = (mv_cat, mv_cat.T.tobytes(), len(qp),
                        self._div_codes(el, mv_cat) if wants else None)
            elif live:
                all_static = False
            if neg is not None:
                all_static = False
            layout.append((tid, np.searchsorted(idx, kb).tolist(), el, live,
                           el is not None and ctx.edge_pred_els[el],
                           neg, qp, wants, lo, lo + len(idx), stat))
            perm_parts.append(kidx[idx])
            lo += len(idx)
        perm = (np.concatenate(perm_parts) if perm_parts
                else np.zeros(0, dtype=np.intp))
        shapes_per = [tuple((tid, sl.stop - sl.start) for tid, sl in rs)
                      for rs in runs_per]
        static_pros = None
        if all_static:
            # every live type is predicate-free and no type carries
            # negation rules: the whole per-pane prologue product except
            # the filtered events themselves is seg-static
            static_pros = []
            for i in range(len(panes)):
                mv_d, mvb_d, codes_d, pres = {}, {}, {}, []
                edge = False
                for (tid, off, el, live, edge_t, neg, qp, wants,
                     lo_t, hi_t, stat) in layout:
                    lo2, hi2 = off[i], off[i + 1]
                    if lo2 == hi2:
                        continue
                    pres.append(tid)
                    if stat is not None:
                        mv_cat, img_b, nq, codes_cat = stat
                        if edge_t:
                            edge = True
                        mv_d[tid] = mv_cat[:, lo2:hi2]
                        mvb_d[tid] = img_b[lo2 * nq:hi2 * nq]
                        if codes_cat is not None:
                            codes_d[tid] = codes_cat[lo2:hi2]
                sig = tuple(mvb_d[t] for t in pres if t in mvb_d)
                static_pros.append((mv_d, mvb_d, codes_d, pres, edge, sig))
        return (kidx, kb, ktype, perm, runs_per, layout, shapes_per,
                static_pros)

    def plan_prologues(self, panes: list[EventBatch]) -> list["_Prologue"]:
        """Batched phase-1 prologue for K panes of one micro-batch flush.

        One ``np.isin`` filter, one run-length segmentation (with forced
        cuts at pane boundaries), and one predicate-stack pass per (query,
        type) run over the *concatenation* of all K panes; per-pane results
        are slices of the stacked arrays.  Predicates evaluate elementwise
        and the byte images are row-major, so every slice — match vectors,
        runs, signature bytes — is bitwise identical to the per-pane
        :meth:`_plan_prologue` output.
        """
        if len(panes) == 1:
            return [self._plan_prologue(panes[0])]
        ctx = self.ctx
        cache = self.plan_cache
        # The whole index plan — keep indices, pane bounds, RLE runs, the
        # per-type layout, and the type-major gather permutation — is a
        # pure function of the pane type *sequences*, the recurrence the
        # plan cache already banks on, so it is memoized on their raw
        # bytes.  A warm flush then does one attrs concatenation plus two
        # gathers before the predicate pass.
        seg_key = tuple(p.type_id.tobytes() for p in panes)
        seg = self._seg_memo.get(seg_key)
        if seg is None:
            if len(self._seg_memo) >= 2048:
                self._seg_memo.clear()
            seg = self._seg_memo[seg_key] = self._seg_build(panes)
        (kidx, kb, ktype, perm, runs_per, layout, shapes_per,
         static_pros) = seg
        raw = np.concatenate([p.attrs for p in panes])
        # each pane's filtered view is a zero-copy row slice of the
        # pane-major gather (panes were validated at construction, so the
        # dataclass re-validation in select() is skipped).  These views
        # are plan-internal: the finish walk reads only ``len`` and
        # ``attrs``, so the time/group columns are never materialized.
        attrs_sel = raw[kidx]
        schema = panes[0].schema
        evs = []
        for i in range(len(panes)):
            ev = object.__new__(EventBatch)
            ev.schema = schema
            ev.type_id = ktype[kb[i]:kb[i + 1]]
            ev.attrs = attrs_sel[kb[i]:kb[i + 1]]
            ev.time = ev.group = ev.seq = None
            evs.append(ev)
        pros = [None] * len(panes)
        if static_pros is not None:
            # fully static flush shape: the attrs gather above is the only
            # content-dependent work left in phase 1's prologue
            for i, ev in enumerate(evs):
                mv_d, mvb_d, codes_d, pres, edge, sig = static_pros[i]
                pros[i] = _Prologue(ev, runs_per[i], mv_d,
                                    mvb_d if cache is not None else {},
                                    {}, pres, edge, codes_d, shapes_per[i],
                                    sig if cache is not None else None)
            return pros
        # stacked predicate pass over each type's concatenated events; the
        # per-pane split points were precomputed into the layout
        attrs_ts = raw[perm]       # type-major rows for the predicate pass
        mv_per: list[dict] = [{} for _ in panes]
        mvb_per: list[dict] = [{} for _ in panes]
        neg_per: list[dict] = [{} for _ in panes]
        codes_per: list[dict] = [{} for _ in panes]
        pres_per: list[list] = [[] for _ in panes]
        sig_per: list[list] = [[] for _ in panes]
        edge_per = [False] * len(panes)
        for tid, off, el, live, edge_t, neg_rules, qp, wants_codes, \
                lo_t, hi_t, stat in layout:
            attrs_t = attrs_ts[lo_t:hi_t]
            neg_cat = ([(qi, rule, ctx.match_vec(qi, tid, attrs_t))
                        for qi, rule in neg_rules]
                       if neg_rules is not None else None)
            codes_cat = None
            if live:
                if stat is not None:
                    mv_cat, img_b, row_b, codes_cat = stat
                    if cache is None:
                        img_b = None
                else:
                    mv_cat = ctx.match_stack(qp, tid, attrs_t)
                    # one byte image for the whole type; per-pane signature
                    # bytes are plain byte-string slices of it (row stride
                    # = query count, C order of the transposed image)
                    img_b = mv_cat.T.tobytes() if cache is not None else None
                    row_b = mv_cat.shape[0] * mv_cat.itemsize
                    if wants_codes:
                        codes_cat = self._div_codes(el, mv_cat)
            for i in range(len(panes)):
                lo, hi = off[i], off[i + 1]
                if lo == hi:
                    continue
                pres_per[i].append(tid)
                if neg_cat is not None:
                    neg_per[i][tid] = [(qi, rule, m[lo:hi])
                                      for qi, rule, m in neg_cat]
                if live:
                    if edge_t:
                        edge_per[i] = True
                    mv_per[i][tid] = mv_cat[:, lo:hi]
                    if img_b is not None:
                        mvb = img_b[lo * row_b:hi * row_b]
                        mvb_per[i][tid] = mvb
                        sig_per[i].append(mvb)
                    if codes_cat is not None:
                        codes_per[i][tid] = codes_cat[lo:hi]
        for i, ev in enumerate(evs):
            pros[i] = _Prologue(ev, runs_per[i], mv_per[i], mvb_per[i],
                                neg_per[i], pres_per[i], edge_per[i],
                                codes_per[i], shapes_per[i],
                                tuple(sig_per[i]) if cache is not None
                                else None)
        return pros

    def _plan_finish(self, pane: EventBatch, pro: "_Prologue",
                     stats: RunStats) -> list:
        """The order-sensitive half of phase 1: stats evolution, sharing
        decisions (the benefit model reads the running event count), plan
        cache traffic, and step construction.  Must run in pane submission
        order."""
        ctx = self.ctx
        self._last_host = None
        obs = self.obs
        audit = obs.audit if obs is not None else None
        pkey = (obs.pane_key(pane)
                if obs is not None and (audit is not None or obs.tracing)
                else None)

        ev = pro.ev
        stats.events += len(ev)
        stats.panes += 1
        runs = pro.runs
        stats.bursts += len(runs)
        if not runs:
            return []
        mv_type = pro.mv_type
        mv_bytes = pro.mv_bytes
        neg_type = pro.neg_type
        present = pro.present
        has_edge = pro.has_edge
        cache = self.plan_cache

        # sharing decisions that never read the divergence structure
        # (AlwaysShare / NeverShare) skip the per-burst divergence pass
        static_policy = self._policy_static

        # whole-pane fast signature: with a static policy, no negation types
        # and no edge predicates in the pane, the structural plan is fully
        # determined by the run-length encoding plus the stacked match bits
        # — the per-burst signature walk is skipped entirely
        fast = (cache is not None and static_policy and not neg_type
                and not has_edge)
        # dynamic-policy fast signature: pattern-based policies (the benefit
        # model reads d_rows only through coverage-pattern counts) get the
        # same whole-pane key, extended with the recomputed sharing decision
        # — the fingerprint pass below reruns the benefit model per pane on
        # the *exact* compressed decision inputs, so a benefit flip lands in
        # a different cache entry instead of freezing the stale decision
        dyn_fast = (cache is not None and not static_policy
                    and self._policy_pattern
                    and not neg_type and not has_edge
                    and ctx.kle_big.isdisjoint(mv_type))
        key: tuple | None = None
        dyn_groups: list | None = None
        rs = pro.runs_shape
        if rs is None and cache is not None:
            rs = tuple((tid, sl.stop - sl.start) for tid, sl in runs)
        sig_mv = pro.sig_mv
        if sig_mv is None and cache is not None:
            sig_mv = tuple(mv_bytes[t] for t in present if t in mv_bytes)
        if fast:
            key = ("F", self.max_local_basis, rs, sig_mv)
            plan = cache.get(key)
            if plan is not None:
                stats.plan_cache_hits += 1
                if obs is not None:
                    obs.cache_event(True, pkey)
                plan.apply_stats(stats)
                self._last_host = plan
                return self._instantiate_fast(plan, runs, ev, mv_type)
            stats.plan_cache_misses += 1
            if obs is not None:
                obs.cache_event(False, pkey)
        elif dyn_fast:
            dyn_groups, key = self._dyn_fast_groups(runs, ev, mv_type,
                                                    mv_bytes, present, stats,
                                                    codes=pro.codes,
                                                    pkey=pkey, audit=audit,
                                                    runs_shape=rs,
                                                    sig_mv=sig_mv)
            plan = cache.get(key)
            if plan is not None:
                stats.plan_cache_hits += 1
                if obs is not None:
                    obs.cache_event(True, pkey)
                plan.apply_stats(stats)
                self._last_host = plan
                return self._instantiate_fast(plan, runs, ev, mv_type)
            stats.plan_cache_misses += 1
            if obs is not None:
                obs.cache_event(False, pkey)
        dec0 = stats.decisions

        # per-burst planning inputs + the exact pane signature.  The
        # signature stores full discriminating bytes (mask-bit slices, the
        # decided groups) — see core/plan_cache.py for why nothing is hashed
        # lossily.
        cursor: dict[int, int] = {}
        plan_bursts: list = []
        key_groups: list = []
        sig: list = [(self.max_local_basis, rs)]
        for ri_, (tid, sl) in enumerate(runs):
            b = sl.stop - sl.start
            c = cursor.get(tid, 0)
            cursor[tid] = c + b

            # negative-type handling (Sec. 5): applies per query with a rule
            hits = None
            if tid in neg_type:
                hits = [(qi, rule) for qi, rule, m in neg_type[tid]
                        if m[c:c + b].any()]
                if not hits:
                    hits = None

            burst = None
            sig_part: tuple | None = None
            el = ctx.local.get(tid)
            if el is not None and ctx.q_pos[el]:
                q_pos = ctx.q_pos[el]
                nq = len(q_pos)
                attrs = ev.attrs[sl]
                mvec = mv_type[tid][:, c:c + b]
                if ctx.edge_pred_els[el]:
                    epm = [ctx.edge_mask(qi, tid, attrs) for qi in q_pos]
                    epm_sig = tuple(
                        None if m is None else np.packbits(m).tobytes()
                        for m in epm)
                else:
                    epm = [None] * nq
                    epm_sig = None

                # sharing decision (Sec. 4): candidates have E+ (Def. 4).
                # Decided fresh on every pane — the benefit model tracks the
                # running event count — and folded into the cache key below.
                # Static policies (decision independent of the burst) reuse
                # their memoized per-type group layout; a dyn-fast miss
                # injects the fingerprint pass's decisions (already counted).
                kle = ctx.kle_pos[el]
                memo = (self._static_groups.get(el) if static_policy
                        else None)
                if dyn_groups is not None:
                    groups = dyn_groups[ri_]
                    groups_sig = None
                elif memo is not None:
                    groups, groups_sig = memo
                    if len(kle) >= 2:
                        stats.decisions += 1
                        if audit is not None:
                            audit.record(pane=pkey, comp=self.comp, el=el,
                                         candidates=kle, decided=groups_sig,
                                         b=b, n=stats.events)
                else:
                    groups = []
                    if len(kle) >= 2:
                        d_rows = (None if static_policy else
                                  self._divergence_rows(q_pos, kle, el,
                                                        mvec, epm))
                        shared_sets = self.policy.decide(
                            ctx=ctx, el=el, candidates=kle, d_rows=d_rows,
                            b=b, n=stats.events, stats=stats)
                        in_shared = set(qq for s in shared_sets for qq in s)
                        groups.extend([s for s in shared_sets
                                       if len(s) >= 2])
                        groups.extend([[qi] for s in shared_sets
                                       if len(s) == 1 for qi in s])
                        groups.extend([[qi] for qi in kle
                                       if qi not in in_shared])
                    else:
                        groups.extend([[qi] for qi in kle])
                    groups.extend([[qi] for qi in q_pos if qi not in kle])
                    groups_sig = tuple(map(tuple, groups))
                    if static_policy:
                        self._static_groups[el] = (groups, groups_sig)
                    if audit is not None and len(kle) >= 2:
                        audit.record(
                            pane=pkey, comp=self.comp, el=el, candidates=kle,
                            decided=groups_sig, b=b, n=stats.events,
                            benefit=getattr(self.policy, "last_benefit",
                                            None),
                            patterns=getattr(self.policy, "last_patterns",
                                             None))
                burst = (tid, el, attrs, b, q_pos, mvec, epm, groups)
                if cache is not None and not fast and not dyn_fast:
                    sig_part = (mv_bytes[tid][c * nq:(c + b) * nq], epm_sig,
                                groups_sig)

            plan_bursts.append((hits, burst))
            if cache is not None and not fast and not dyn_fast:
                sig.append((
                    tid,
                    None if hits is None else tuple(qi for qi, _ in hits),
                    sig_part))
                if audit is not None:
                    key_groups.append(None if burst is None else groups_sig)

        if cache is not None and not fast and not dyn_fast:
            key = tuple(sig)
            if audit is not None:
                audit.note_pane(pkey, tuple(key_groups), comp=self.comp)
            plan = cache.get(key)
            if plan is not None:
                stats.plan_cache_hits += 1
                if obs is not None:
                    obs.cache_event(True, pkey)
                plan.apply_stats(stats)
                self._last_host = plan
                return self._instantiate(plan, plan_bursts)
            stats.plan_cache_misses += 1
            if obs is not None:
                obs.cache_event(False, pkey)
        before = cache.snapshot_stats(stats) if cache is not None else None

        steps = self._build_steps(plan_bursts, stats)

        if cache is not None:
            delta = cache.stat_delta(before, stats)
            if fast:
                # the fast hit skips the per-burst walk, so its sharing
                # decisions replay via the stat delta too (a dyn-fast hit
                # instead reruns the benefit model live, so its decision
                # counters must *not* be replayed)
                delta["decisions"] = stats.decisions - dec0
            zero_copy = (not ctx.sum_unit_cols and all(
                isinstance(s, _NegStep) or len(s.div_rows) == 0
                for s in steps))
            plan = PanePlan(steps=[self._strip(s) for s in steps],
                            stat_delta=delta, zero_copy=zero_copy)
            cache.put(key, plan)
            self._last_host = plan
        return steps

    def _build_steps(self, plan_bursts: list, stats: RunStats) -> list:
        """Construct the structural step list (the cacheable part of phase 1:
        group plans with divergence layout, adjacency, z columns, and
        count-round injection rows)."""
        steps: list = []
        for bi, (hits, burst) in enumerate(plan_bursts):
            if hits:
                steps.append(_NegStep(hits))
            if burst is None:
                continue
            tid, el, attrs, b, q_pos, mvec, epm, groups = burst
            qpos_index = {qi: i for i, qi in enumerate(q_pos)}
            for g in groups:
                if len(g) >= 2:
                    stats.shared_bursts += 1
                    stats.shared_graphlets += 1
                stats.graphlets += 1
                rows = [qpos_index[qi] for qi in g]
                self._plan_group(g, el, tid, attrs, b, mvec[rows],
                                 [epm[i] for i in rows], steps, stats, bi,
                                 rows)
        return steps

    @staticmethod
    def _strip(step):
        """Template form of a step for caching: drop per-pane data (attrs,
        match vectors, edge masks, sum values, job handles); keep the
        structural arrays, the count-round injection rows, and the member
        row indices within the burst's stacked match matrix."""
        if isinstance(step, _NegStep):
            return step
        return replace(step, attrs=None, mvec=None, epm=None, sum_units=())

    def _instantiate(self, plan: PanePlan, plan_bursts: list) -> list:
        """Rehydrate a cached plan against this pane's fresh data: swap in
        the new attribute arrays, match vectors, edge masks and sum-unit
        values; everything structural is reused as-is.  Copies bypass the
        dataclass constructor — this runs per group per pane on the hit
        path."""
        if plan.zero_copy:
            return plan.steps
        steps: list = []
        sum_units_cache: dict[int, list] = {}
        for st in plan.steps:
            if isinstance(st, _NegStep):
                steps.append(st)
                continue
            _, burst = plan_bursts[st.bi]
            tid, el, attrs, b, q_pos, mvec, epm, groups = burst
            gp = object.__new__(_GroupPlan)
            gp.__dict__.update(st.__dict__)
            if len(st.div_rows):
                # per-event snapshot fills read the fresh data; groups
                # without divergence never touch attrs/mvec/epm in finalize
                rows = st.rows
                gp.attrs = attrs
                gp.mvec = mvec[rows]
                gp.epm = [epm[i] for i in rows]
            su = sum_units_cache.get(st.bi)
            if su is None:
                su = sum_units_cache[st.bi] = self._sum_units_for(
                    tid, attrs, b)
            gp.sum_units = su
            steps.append(gp)
        return steps

    def _instantiate_fast(self, plan: PanePlan, runs: list, ev: EventBatch,
                          mv_type: dict) -> list:
        """Rehydrate a fast-keyed plan (static policy, no negation, no edge
        predicates in the pane).  Zero-copy when no step carries per-pane
        data; otherwise only the data-bearing fields are rebuilt."""
        if plan.zero_copy:
            return plan.steps
        cursor: dict[int, int] = {}
        info: list[tuple] = []
        for tid, sl in runs:
            b = sl.stop - sl.start
            c = cursor.get(tid, 0)
            cursor[tid] = c + b
            info.append((tid, sl, c, b))
        steps: list = []
        sum_units_cache: dict[int, list] = {}
        for st in plan.steps:
            tid, sl, c, b = info[st.bi]
            gp = object.__new__(_GroupPlan)
            gp.__dict__.update(st.__dict__)
            if len(st.div_rows):
                gp.attrs = ev.attrs[sl]
                gp.mvec = mv_type[tid][:, c:c + b][st.rows]
                gp.epm = [None] * len(st.rows)
            su = sum_units_cache.get(st.bi)
            if su is None:
                su = sum_units_cache[st.bi] = self._sum_units_for(
                    tid, ev.attrs[sl], b)
            gp.sum_units = su
            steps.append(gp)
        return steps

    def _sum_units_for(self, type_id: int, attrs: np.ndarray, b: int) -> list:
        """Per-burst sum-unit injection values (fresh attribute data)."""
        return [(ui, None if tid != type_id
                 else (np.ones(b) if col is None else attrs[:, col]))
                for ui, tid, col in self.ctx.sum_unit_cols]

    # -- dynamic-policy fast-key fingerprint pass --

    def _dyn_fast_groups(self, runs: list, ev: EventBatch, mv_type: dict,
                         mv_bytes: dict, present: list, stats: RunStats,
                         codes: dict | None = None, pkey=None,
                         audit=None, runs_shape=None,
                         sig_mv: tuple | None = None) -> tuple[list, tuple]:
        """Whole-pane fast key for pattern-based dynamic policies.

        Requires an edge-free, negation-free pane.  One vectorized
        divergence image per type (the stacked twin of
        :meth:`_divergence_rows` without the edge term) is sliced per burst
        into coverage-pattern multisets — the benefit model's decision
        inputs, compressed exactly (see ``optimizer.divergence_patterns``)
        — and the sharing decision is recomputed from them via
        ``policy.decide_patterns``.  The decided groups join the fast
        signature, so zero-copy reuse extends to :class:`~repro.core
        .optimizer.DynamicPolicy` panes while a benefit flip (the running
        event count crossing a cost threshold) misses into a fresh entry.
        Returns (per-run groups for injection into the plan walk, key).

        The whole walk is memoized per (runs shape, per-type divergence-code
        bytes): the sharing decisions are pure functions of the coverage
        patterns, ``b`` and the running event count ``n``, and the policy
        reports the exact ``n`` interval on which each decision replays
        (:attr:`~repro.core.optimizer._PolicyBase.last_interval`).  A warm
        pane whose ``n`` lands inside the recorded intersection skips the
        per-burst loop entirely — one dict probe replaces the decision walk.
        Audit-enabled runs bypass the memo (the audit log wants per-burst
        benefit values, which vary with ``n`` inside an interval).
        """
        ctx = self.ctx
        codes_type = codes
        n_pane = stats.events
        if runs_shape is None:
            runs_shape = tuple((tid, sl.stop - sl.start) for tid, sl in runs)
        if sig_mv is None:
            sig_mv = tuple(mv_bytes[t] for t in present if t in mv_bytes)
        pm_key: tuple | None = None
        if audit is None:
            pm_key = (runs_shape,
                      tuple(a.tobytes() for a in codes_type.values()))
            ent = self._dyn_pane_memo.get(pm_key)
            if ent is not None:
                for lo, hi, groups_all, sig_t, n_dec, n_split in ent:
                    if lo <= n_pane <= hi:
                        stats.decisions += n_dec
                        stats.split_bursts += n_split
                        key = ("FD", self.max_local_basis, runs_shape,
                               sig_mv, sig_t)
                        return groups_all, key
        dec0 = stats.decisions
        split0 = stats.split_bursts
        iv_lo, iv_hi = None, None
        memoable = pm_key is not None
        pats_cache = self._pats_cache
        groups_all: list = []
        sig: list = []
        cursor: dict[int, int] = {}
        t_layout = max(1, ctx.layout.t)
        for tid, sl in runs:
            b = sl.stop - sl.start
            c = cursor.get(tid, 0)
            cursor[tid] = c + b
            el = ctx.local.get(tid)
            if el is None or not ctx.q_pos[el]:
                groups_all.append(None)
                sig.append(None)
                continue
            kle = ctx.kle_pos[el]
            groups: list = []
            pats = None
            if len(kle) >= 2:
                csl = codes_type[tid][c:c + b]
                cb = csl.tobytes()
                pats = pats_cache.get(cb)
                if pats is None:
                    nz = csl[csl != 0]
                    vals, counts = np.unique(nz, return_counts=True)
                    pats = tuple(zip(vals.tolist(), counts.tolist()))
                    if len(pats_cache) >= 8192:
                        pats_cache.clear()
                    pats_cache[cb] = pats
                shared_sets = self.policy.decide_patterns(
                    patterns=pats, candidates=kle, b=b, n=stats.events,
                    t=t_layout, stats=stats)
                iv = self.policy.last_interval
                if iv is None:
                    memoable = False
                else:
                    iv_lo = iv[0] if iv_lo is None else max(iv_lo, iv[0])
                    iv_hi = iv[1] if iv_hi is None else min(iv_hi, iv[1])
                in_shared = set(qq for s in shared_sets for qq in s)
                groups.extend([s for s in shared_sets if len(s) >= 2])
                groups.extend([[qi] for s in shared_sets
                               if len(s) == 1 for qi in s])
                groups.extend([[qi] for qi in kle if qi not in in_shared])
            else:
                groups.extend([[qi] for qi in kle])
            groups.extend([[qi] for qi in ctx.q_pos[el] if qi not in kle])
            groups_all.append(groups)
            sig.append(tuple(map(tuple, groups)))
            if audit is not None and len(kle) >= 2:
                audit.record(
                    pane=pkey, comp=self.comp, el=el, candidates=kle,
                    decided=sig[-1], b=b, n=stats.events,
                    benefit=getattr(self.policy, "last_benefit", None),
                    patterns=pats)
        sig_t = tuple(sig)
        if audit is not None:
            audit.note_pane(pkey, sig_t, comp=self.comp)
        if memoable:
            lo, hi = ((iv_lo, iv_hi) if iv_lo is not None
                      else (0, float("inf")))
            if lo <= hi:
                if len(self._dyn_pane_memo) >= 4096:
                    self._dyn_pane_memo.clear()
                self._dyn_pane_memo.setdefault(pm_key, []).append(
                    (lo, hi, groups_all, sig_t,
                     stats.decisions - dec0, stats.split_bursts - split0))
        key = ("FD", self.max_local_basis, runs_shape, sig_mv, sig_t)
        return groups_all, key

    # -- divergence detection (per-event signature differences) --

    def _divergence_rows(self, q_pos, kle, el, mvec, epm) -> dict[int, np.ndarray]:
        """Per-candidate boolean rows: events where q's signature differs
        from the reference (first candidate).  Drives Thms 4.1/4.2.  One
        broadcast comparison over the stacked match vectors; the (rare)
        edge-mask term falls back to a per-candidate pass."""
        ctx = self.ctx
        ref = kle[0]
        ri = q_pos.index(ref)
        b = mvec.shape[1]
        idx = np.array([q_pos.index(qi) for qi in kle])
        D = mvec[idx] != mvec[ri]                       # [n_kle, b]
        sdiff = ctx.start_flag[kle, el] != ctx.start_flag[ref, el]
        if sdiff.any():
            D[sdiff] |= mvec[idx[sdiff]] | mvec[ri]
        ref_edge = epm[ri]
        for j, qi in enumerate(kle):
            a, bq = ref_edge, epm[q_pos.index(qi)]
            if (a is None) != (bq is None) or (
                    a is not None and bq is not None and not np.array_equal(a, bq)):
                am = np.ones((b, b), dtype=bool) if a is None else a
                bm = np.ones((b, b), dtype=bool) if bq is None else bq
                D[j] |= np.any(np.tril(am != bm, k=-1), axis=1)
        return {qi: D[j] for j, qi in enumerate(kle)}

    # -- group (graphlet) planning --

    def _plan_group(self, g, el, type_id, attrs, b, mvec, epm,
                    steps: list, stats: RunStats, bi: int = -1,
                    rows: list | None = None) -> None:
        ctx = self.ctx
        nu = ctx.nu
        shared = len(g) >= 2
        kleene = all(ctx.kleene_flag[qi, el] for qi in g)
        assert shared is False or kleene, "shared groups must be Kleene (Def. 4)"

        # a non-shared graphlet none of whose events match contributes an
        # exactly-zero update (zero injection rows, zeroed adjacency): skip
        # its jobs and its finalize step entirely
        if not shared and not mvec[0].any():
            return

        # per-event divergence flags within this group: one broadcast
        # comparison against the group reference (member 0)
        if shared:
            div = (mvec != mvec[0]).any(axis=0)
            sflags = ctx.start_flag[g, el]
            sdiff = sflags != sflags[0]
            if sdiff.any():
                div |= mvec[sdiff].any(axis=0) | mvec[0]
            e0 = epm[0]
            for i in range(1, len(g)):
                a, bq = e0, epm[i]
                if (a is None) != (bq is None) or (
                        a is not None and bq is not None and not np.array_equal(a, bq)):
                    am = np.ones((b, b), dtype=bool) if a is None else a
                    bm = np.ones((b, b), dtype=bool) if bq is None else bq
                    div |= np.any(np.tril(am != bm, k=-1), axis=1)
        else:
            div = np.zeros(b, dtype=bool)

        d = int(div.sum())
        n_z = d * nu
        B_local = 1 + nu + n_z
        if B_local > self.max_local_basis and shared:
            # basis would blow up: force split (the optimizer should normally
            # have prevented this; AlwaysShare can reach it)
            for qi in g:
                j = g.index(qi)
                self._plan_group([qi], el, type_id, attrs, b,
                                 mvec[[j]], [epm[j]], steps, stats, bi,
                                 None if rows is None else [rows[j]])
            stats.split_bursts += 1
            return

        live = mvec.all(axis=0) & ~div
        dead = ~mvec.any(axis=0) & ~div

        # local basis: 0 = gate, 1..nu = x_u, nu+1.. = z snapshots
        z_ids = {}
        nxt = 1 + nu
        div_rows = np.nonzero(div)[0]
        for i in div_rows:
            for ui in range(nu):
                z_ids[(int(i), ui)] = nxt
                nxt += 1
        if shared:
            # snapshots are a *shared-execution* artifact (Defs. 8/9); the
            # non-shared path keeps plain per-query aggregates
            stats.snapshots_created += nu + n_z
            stats.snapshots_propagated += B_local

        # dense fast path: no edge predicates and no divergent/dead rows
        # means the in-burst adjacency is exactly strictly-lower all-ones,
        # with the O(b) closed form (beyond-paper; see kernels/ops.py)
        dense = (kleene and epm[0] is None and d == 0 and not dead.any()
                 and b <= DENSE_B_MAX)

        # common in-burst adjacency
        if dense:
            em = None
        else:
            if kleene:
                em = np.tril(np.ones((b, b)), k=-1)
                if epm[0] is not None:
                    em *= np.tril(epm[0], k=-1)
            else:
                em = np.zeros((b, b))
            em[div | dead, :] = 0.0
            if not shared:
                em[~mvec[0], :] = 0.0

        plan = _GroupPlan(
            g=list(g), el=el, type_id=type_id, attrs=attrs, b=b, mvec=mvec,
            epm=epm, shared=shared, div=div, div_rows=div_rows, live=live,
            dead=dead, B_local=B_local, z_ids=z_ids, dense=dense, em=em,
            start_q0=bool(ctx.start_flag[g[0], el]),
            sum_units=self._sum_units_for(type_id, attrs, b), bi=bi,
            rows=rows, trivial=not kleene)
        # injection-row layout is structural: build it at plan time so the
        # plan cache carries it and repeated shapes skip the construction
        plan.base_c = self._count_base(plan)
        steps.append(plan)

    # -- phase 2: execute (jobs to the bucketed batched executor) --

    def submit_execute(self, steps: list, stats: RunStats,
                       round_: int, jobs: list) -> None:
        """Submit one execute round's jobs to the shared executor.

        Round 1 submits every group's count-unit problem; round 2 submits
        the sum-unit problems, whose injection rows read the (flushed)
        count coefficients.  The caller flushes the executor between rounds
        — per pane via :meth:`process`, per micro-batch via
        :class:`PaneMicroBatcher`.  ``jobs`` is the pending pane's handle
        list, parallel to ``steps`` (plans stay immutable: see _GroupPlan).
        """
        ex = self.executor
        if round_ == 1:
            for i, p in enumerate(steps):
                if not isinstance(p, _GroupPlan):
                    continue
                base = self._count_base(p)
                if p.trivial:
                    # non-Kleene graphlet: the in-burst adjacency is all
                    # zeros, so propagation is the identity on the injection
                    # rows — no launch needed
                    cjob = PropagateJob(base, None, result=base)
                else:
                    cjob = ex.submit(base, None if p.dense else p.em)
                jobs[i] = (cjob, {})
                stats.propagate_cells += p.b * p.B_local
        else:
            for i, p in enumerate(steps):
                if not isinstance(p, _GroupPlan):
                    continue
                cjob, sjobs = jobs[i]
                for ui, vals in p.sum_units:
                    base = self._sum_base(p, ui, vals, cjob.result)
                    if p.trivial:
                        sjobs[ui] = PropagateJob(base, None, result=base)
                    else:
                        sjobs[ui] = ex.submit(base,
                                              None if p.dense else p.em)
                    stats.propagate_cells += p.b * p.B_local

    # -- phase 2 helpers: injection rows for the batched launches --

    def _count_base(self, p: _GroupPlan) -> np.ndarray:
        if p.base_c is not None:
            return p.base_c
        base_c = np.zeros((p.b, p.B_local))
        base_c[p.live, 1 + 0] = 1.0               # x_count entry
        if p.start_q0:
            base_c[p.live, 0] = 1.0               # gate entry (start contribution)
        for i in p.div_rows:
            base_c[i, p.z_ids[(int(i), 0)]] = 1.0
        return base_c

    def _sum_base(self, p: _GroupPlan, ui: int, vals,
                  ccoef: np.ndarray) -> np.ndarray:
        # injection shares the mask and includes attr*count coefficients
        base_s = np.zeros((p.b, p.B_local))
        base_s[p.live, 1 + ui] = 1.0
        if vals is not None:
            base_s[p.live] += vals[p.live, None] * ccoef[p.live]
        for i in p.div_rows:
            base_s[i, :] = 0.0
            base_s[i, p.z_ids[(int(i), ui)]] = 1.0
        return base_s

    # -- phase 3: finalize (replay the pane in stream order) --

    def finalize(self, steps: list, stats: RunStats,
                 jobs: list, pane_key=None) -> np.ndarray:
        """Phase 3, sequential reference path: fold executed coefficients
        into the state functionals and assemble the pane's per-query
        transfer matrices M [k, C, C].  ``jobs`` is the pending pane's
        handle list, parallel to ``steps``.

        With a :class:`~repro.core.fold_exec.FoldExecutor` attached the
        micro-batcher folds pending panes through it instead (stacked
        per-shape launches, bitwise identical to this replay); this method
        remains the ``fold_exec=False`` oracle the differential suite pins
        the executor against."""
        t_f = perf_counter()
        ctx = self.ctx
        C = ctx.layout.size
        k = ctx.k
        nu = ctx.nu
        t = len(ctx.pos_type_ids)

        with np.errstate(over="ignore", invalid="ignore"):
            # state functionals over pane-entry channels
            arow = np.zeros((k, nu, t, C))
            if nu and t:
                arow[:, np.arange(nu)[:, None], np.arange(t)[None, :],
                     ctx.a_cols] = 1.0
            rrow = np.zeros((k, nu, C))
            if nu:
                rrow[:, np.arange(nu), ctx.rp_cols] = 1.0
            gaterow = np.zeros((k, C))
            gaterow[:, ctx.layout.GATE] = 1.0

            for i, s in enumerate(steps):
                if isinstance(s, _NegStep):
                    for qi, rule in s.hits:
                        if rule.kind == "leading":
                            gaterow[qi, :] = 0.0
                        elif rule.kind == "trailing":
                            rrow[qi, :, :] = 0.0
                        else:
                            arow[qi, :, rule.before_local, :] = 0.0
                else:
                    cjob, sjobs = jobs[i]
                    self._finalize_group(s, cjob, sjobs, arow, rrow, gaterow)

            # assemble transfer matrices (vectorized over queries)
            M = np.zeros((k, C, C))
            M[:, ctx.layout.CONST, ctx.layout.CONST] = 1.0
            M[:, ctx.layout.GATE, :] = gaterow
            if nu and t:
                M[:, ctx.a_cols.reshape(-1), :] = arow.reshape(k, nu * t, C)
            if nu:
                M[:, ctx.rp_cols, :] = rrow
        dt = perf_counter() - t_f
        stats.finalize_s += dt
        obs = self.obs
        if obs is not None:
            obs.pane_phase("finalize", t_f, dt, key=pane_key)
        return M

    # -- phase 3 helper: one graphlet's coefficients -> state functionals --

    def _finalize_group(self, p: _GroupPlan, cjob, sjobs, arow, rrow,
                        gaterow) -> None:
        ctx = self.ctx
        C = ctx.layout.size
        nu = ctx.nu
        g = p.g
        b = p.b
        el = p.el
        ccoef = cjob.result
        scoefs = {ui: sjobs[ui].result for ui in sjobs}
        z_ids = p.z_ids
        div_rows = p.div_rows

        W = np.zeros((len(g), p.B_local, C))
        W[:, 0] = gaterow[g]
        if nu:
            # one stacked matmul for every member's x_u functionals instead
            # of a matvec per (member, unit): [G,1,1,t] @ [G,nu,t,C]
            W[:, 1:1 + nu] = np.matmul(
                ctx.pt_mask[g, el][:, None, None, :].astype(np.float64),
                arow[g])[:, :, 0, :]

        # event-level snapshot value functionals (Def. 9), ascending order.
        # P[u] caches coef_u @ W[gi]; every snapshot fill is a rank-1 update
        # so *live* rows that reference earlier z columns stay current.
        if len(div_rows):
            coefs = {0: ccoef, **scoefs}
            lower = np.tril(np.ones((b, b), dtype=bool), k=-1)
            for gi, qi in enumerate(g):
                P = {u: coefs[u] @ W[gi] for u in coefs}

                def fill(zcol: int, f: np.ndarray) -> None:
                    W[gi, zcol] = f
                    for u in coefs:
                        col = coefs[u][:, zcol]
                        if col.any():
                            P[u] += np.outer(col, f)

                adj_q = lower.copy()
                if p.epm[gi] is not None:
                    adj_q &= p.epm[gi]
                adj_q &= p.mvec[gi][None, :]
                startq = 1.0 if ctx.start_flag[qi, el] else 0.0
                for i in div_rows:
                    i = int(i)
                    row = adj_q[i].astype(float)
                    if p.mvec[gi][i]:
                        f_c = startq * gaterow[qi] + W[gi, 1 + 0] + row @ P[0]
                    else:
                        f_c = np.zeros(C)
                    fill(z_ids[(i, 0)], f_c)
                    for ui, u in enumerate(ctx.units):
                        if u[0] != "sum":
                            continue
                        _, e_name, attr = u
                        if p.mvec[gi][i]:
                            f_s = W[gi, 1 + ui] + row @ P[ui]
                            if ctx.schema.type_id(e_name) == p.type_id:
                                v = (1.0 if attr is None
                                     else p.attrs[i, ctx.schema.attr_col(attr)])
                                f_s = f_s + v * f_c
                        else:
                            f_s = np.zeros(C)
                        fill(z_ids[(i, ui)], f_s)

        # fold column sums into state functionals: one stacked matmul per
        # graphlet instead of a matvec per (member, unit)
        used = [0] + sorted(scoefs)               # unit rows: count first
        if scoefs:
            S = np.stack([ccoef.sum(axis=0)] +
                         [scoefs[ui].sum(axis=0) for ui in sorted(scoefs)])
        else:
            S = ccoef.sum(axis=0)[None]
        upd = np.matmul(S, W)                     # [len(g), len(used), C]
        for gi, qi in enumerate(g):
            end = ctx.end_flag[qi, el]
            for r, ui in enumerate(used):
                arow[qi, ui, el] += upd[gi, r]
                if end:
                    rrow[qi, ui] += upd[gi, r]


# --------------------------------------------------------------------------
# cross-pane fused execution (micro-batching)
# --------------------------------------------------------------------------


@dataclass
class _PendingPane:
    """A planned pane awaiting execution/finalization in a micro-batch.

    ``jobs`` holds the executor handles parallel to ``steps`` — kept off the
    (possibly cache-shared) plan objects so the same planned shape can be in
    flight for several panes of one micro-batch at once.  ``plan_host`` is
    the :class:`~repro.core.plan_cache.PanePlan` this pane hit or created
    (the fold executor caches its level schedule there)."""

    proc: PaneProcessor
    steps: list
    stats: RunStats
    jobs: list = field(default_factory=list)
    plan_host: object = None
    M: np.ndarray | None = None
    pane_key: tuple | None = None
    pane: EventBatch | None = None    # unplanned payload until drain()

    def finalize(self) -> np.ndarray:
        if self.M is None:
            self.M = self.proc.finalize(self.steps, self.stats, self.jobs,
                                        pane_key=self.pane_key)
        return self.M


class PaneMicroBatcher:
    """Accumulate submitted panes and flush the whole backlog together.

    ``submit`` only queues the pane; planning is deferred to ``drain``,
    which runs phase 1 for the whole micro-batch as one *batched prologue*
    per processor (one stacked event filter / RLE segmentation / predicate
    pass over all K panes — see :meth:`PaneProcessor.plan_prologues`)
    followed by the per-pane decision walks **in submission order** — the
    optimizer's running event count, and hence every sharing decision,
    stays bitwise identical to per-pane planning.  ``drain`` then runs both
    execute rounds for all pending panes through the shared executor — one
    launch per size bucket per K panes — and, when a
    :class:`~repro.core.fold_exec.FoldExecutor` is attached, folds every
    pending pane's finalize backlog with one stacked launch set (one flush =
    one plan + one execute + one fold launch set) and returns the pending
    panes for deferred, in-order consumption.  ``k`` is the micro-batch
    size; ``k=1`` degrades to exact per-pane execution.
    """

    def __init__(self, executor: PaneBatchExecutor, k: int = 1,
                 fold_exec=None, obs=None):
        self.executor = executor
        self.fold_exec = fold_exec
        self.obs = obs
        self.k = max(1, int(k))
        self._pending: list[_PendingPane] = []

    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, proc: PaneProcessor, pane: EventBatch,
               stats: RunStats) -> _PendingPane:
        obs = self.obs
        key = None
        if obs is not None and obs.tracing:
            key = obs.pane_key(pane)
            obs.lifecycle("ingest", key, args={"events": len(pane)})
        pend = _PendingPane(proc, None, stats, jobs=None, pane_key=key,
                            pane=pane)
        self._pending.append(pend)
        return pend

    def ready(self) -> bool:
        return len(self._pending) >= self.k

    def _plan_pending(self, pend: list[_PendingPane]) -> None:
        """Deferred phase 1 for the whole micro-batch: batched prologues
        per processor, then the order-sensitive finish walks in submission
        order."""
        obs = self.obs
        t0 = perf_counter()
        with np.errstate(over="ignore", invalid="ignore"):
            by_proc: dict[int, list[_PendingPane]] = {}
            for p in pend:
                by_proc.setdefault(id(p.proc), []).append(p)
            pros: dict[int, object] = {}
            for plist in by_proc.values():
                proc = plist[0].proc
                for p, pro in zip(plist, proc.plan_prologues(
                        [q.pane for q in plist])):
                    pros[id(p)] = pro
            for p in pend:
                p.steps = p.proc._plan_finish(p.pane, pros[id(p)], p.stats)
                p.plan_host = p.proc._last_host
                p.jobs = [None] * len(p.steps)
        dt = (perf_counter() - t0) / len(pend)
        for p in pend:
            p.stats.plan_s += dt
        if obs is not None:
            if obs.tracing:
                for i, p in enumerate(pend):
                    obs.pane_phase("plan", t0 + i * dt, dt, key=p.pane_key)
            else:
                obs.pane_phase_n("plan", dt, len(pend))

    def drain(self) -> list[_PendingPane]:
        pend, self._pending = self._pending, []
        if not pend:
            return pend
        self._plan_pending(pend)
        ex = self.executor
        obs = self.obs
        sp = (obs.span("flush", args={"panes": len(pend)})
              if obs is not None else NULL_SPAN)
        with sp:
            t0 = perf_counter()
            with np.errstate(over="ignore", invalid="ignore"):
                for p in pend:
                    p.proc.submit_execute(p.steps, p.stats, 1, p.jobs)
                ex.flush()
                for p in pend:
                    p.proc.submit_execute(p.steps, p.stats, 2, p.jobs)
                ex.flush()
            # amortize the fused launch wall time across the micro-batch
            dt = (perf_counter() - t0) / len(pend)
            for p in pend:
                p.stats.execute_s += dt
            if obs is not None:
                if obs.tracing:
                    # the same amortized dt, tiled so pane spans don't overlap
                    for i, p in enumerate(pend):
                        obs.pane_phase("execute", t0 + i * dt, dt,
                                       key=p.pane_key)
                else:
                    obs.pane_phase_n("execute", dt, len(pend))
            fe = self.fold_exec
            if fe is not None:
                fsp = (obs.span("fold_flush", args={"panes": len(pend)})
                       if obs is not None else NULL_SPAN)
                with fsp:
                    t1 = perf_counter()
                    fjobs = [fe.submit(p.proc, p.steps, p.jobs, p.stats,
                                       host=p.plan_host) for p in pend]
                    fe.flush()
                    for p, fj in zip(pend, fjobs):
                        p.M = fj.M
                    dt = (perf_counter() - t1) / len(pend)
                    for p in pend:
                        p.stats.finalize_s += dt
                    if obs is not None:
                        if obs.tracing:
                            for i, p in enumerate(pend):
                                obs.pane_phase("finalize", t1 + i * dt, dt,
                                               key=p.pane_key)
                        else:
                            obs.pane_phase_n("finalize", dt, len(pend))
        return pend


# --------------------------------------------------------------------------
# windowed runtime: panes -> sliding windows -> per-query results
# --------------------------------------------------------------------------


@dataclass
class _Instance:
    start: int
    u: np.ndarray
    events: list = field(default_factory=list)  # retained only for min/max


def fold_panes(Ms: list[np.ndarray], u0: np.ndarray) -> np.ndarray:
    """Replay a window's state from per-pane transfer matrices.

    Applies the panes' transfer matrices to the fresh state ``u0`` in stream
    order — the same ``u @ M.T`` fold :func:`advance_instances` performs
    incrementally, so replaying a window from stored matrices reproduces the
    incremental run.  This is the event-time revision primitive: after a late
    event dirties one pane, only that pane's ``M`` is recomputed and the
    window is re-folded from the stored matrices of the clean panes.
    """
    u = u0
    with np.errstate(over="ignore", invalid="ignore"):
        for M in Ms:
            u = u @ M.T
    return u


def advance_instances(M: np.ndarray, insts: dict[int, "_Instance"]) -> None:
    """Advance every open window instance by one pane: a single [n, C] x
    [C, C] matmul instead of one matvec per instance (the per-pane fold of
    the transfer matrix, vectorized across overlapping windows)."""
    if not insts:
        return
    members = list(insts.values())
    with np.errstate(over="ignore", invalid="ignore"):
        U = np.stack([inst.u for inst in members]) @ M.T
    for i, inst in enumerate(members):
        inst.u = U[i]


class HamletRuntime:
    """Evaluates a workload over a stream, pane by pane (Sec. 2.2 / 3.1).

    ``micro_batch`` sets the cross-pane fusion factor K: planned panes
    accumulate and their propagation backlogs flush together, one launch per
    size bucket per K panes (bitwise identical to ``micro_batch=1``).
    ``plan_cache`` attaches a per-component :class:`PanePlanCache` shared by
    every processor the runtime spawns (see ``core/plan_cache.py``).
    ``obs`` attaches a :class:`repro.obs.Observability` facade: phase spans,
    lifecycle instants, executor metrics and the sharing-decision audit log
    all record through it (None — the default — costs nothing).
    """

    def __init__(self, workload: Workload, policy=None, backend: str = "np",
                 batch_exec: bool = True, shard_slices=None,
                 micro_batch: int = 1, plan_cache: bool = True,
                 plan_cache_size: int = 128, fold_exec: bool = True,
                 obs=None):
        from .optimizer import DynamicPolicy

        self.workload = workload
        self.policy = policy if policy is not None else DynamicPolicy()
        self.backend = backend
        self.pane = pane_size_for(workload.windows)
        self.micro_batch = max(1, int(micro_batch))
        self.components = workload.sharable_components()
        self.ctxs = [ComponentContext(workload.schema,
                                      [workload.atomic[i] for i in comp])
                     for comp in self.components]
        self.plan_caches = [PanePlanCache(plan_cache_size) if plan_cache
                            else None for _ in self.ctxs]
        # one executor for the whole runtime: every pane — shed or admitted,
        # any component — funnels its jobs through the same bucketed batches
        self.executor = PaneBatchExecutor(backend=backend, batched=batch_exec,
                                          shard_slices=shard_slices)
        # one fold executor likewise: finalize backlogs of every pending
        # pane fold as stacked per-shape launches (None = sequential replay)
        self.fold_exec = FoldExecutor(backend=backend) if fold_exec else None
        self.obs = obs
        if obs is not None:
            obs.pane_ticks = self.pane
            self.executor.obs = obs
            if self.fold_exec is not None:
                self.fold_exec.obs = obs
        self.stats = RunStats()
        self._empty_M: list[np.ndarray] | None = None

    def make_processor(self, ci: int) -> PaneProcessor:
        """A processor for component ``ci`` wired to the runtime's shared
        executor, plan cache and observability facade (used by the
        overload / event-time layers)."""
        return PaneProcessor(self.ctxs[ci], self.policy, backend=self.backend,
                             executor=self.executor,
                             plan_cache=self.plan_caches[ci],
                             fold_exec=self.fold_exec, obs=self.obs, comp=ci)

    def plan_cache_stats(self) -> dict:
        """Aggregate plan-cache counters across components."""
        hits = sum(c.hits for c in self.plan_caches if c is not None)
        misses = sum(c.misses for c in self.plan_caches if c is not None)
        return {"hits": hits, "misses": misses,
                "entries": sum(len(c) for c in self.plan_caches
                               if c is not None),
                "hit_rate": hits / (hits + misses) if hits + misses else 0.0}

    def empty_pane_matrices(self) -> list[np.ndarray]:
        """Per-component transfer matrix of an event-free pane (cached).

        Every empty pane folds identically, so the event-time layer stores
        matrices only for panes that saw events and substitutes this one for
        the gaps when replaying a window (see :func:`fold_panes`).
        """
        if self._empty_M is None:
            empty = EventBatch(self.workload.schema, np.array([], np.int32),
                               np.array([], np.int64), None)
            scratch = RunStats()
            # no obs on these processors: the scratch stats never merge into
            # the runtime's, so spans here would break the span/stat match
            self._empty_M = [
                PaneProcessor(self.ctxs[ci], self.policy,
                              backend=self.backend, executor=self.executor,
                              plan_cache=self.plan_caches[ci],
                              fold_exec=self.fold_exec).process(empty,
                                                                scratch)
                for ci in range(len(self.ctxs))]
        return self._empty_M

    def run(self, batch: EventBatch, t_end: int | None = None) -> dict:
        """Process a stream; returns {(query, group, window_start): {agg: val}}.

        Results for user queries with top-level Or/And are combined per
        Sec. 5.  Windows are aligned to multiples of each query's slide,
        starting at 0; only windows fully contained in [0, t_end) emit.
        """
        if t_end is None:
            t_end = int(batch.time.max()) + 1 if len(batch) else 0
        t_end = ((t_end + self.pane - 1) // self.pane) * self.pane

        atomic_results: dict[tuple[int, int, int], dict] = {}
        for group_key, gbatch in batch.partition_by_group().items():
            self._run_partition(gbatch, t_end, group_key, atomic_results)

        return self._combine(atomic_results)

    # -- per group partition --

    def _run_partition(self, batch: EventBatch, t_end: int, group_key: int,
                       out: dict) -> None:
        for ic, (comp, ctx) in enumerate(zip(self.components, self.ctxs)):
            proc = self.make_processor(ic)
            insts: list[dict[int, _Instance]] = [dict() for _ in comp]
            mb = PaneMicroBatcher(self.executor, k=self.micro_batch,
                                  fold_exec=self.fold_exec, obs=self.obs)
            backlog: list[tuple[int, EventBatch, _PendingPane]] = []

            def flush_backlog():
                mb.drain()
                for t0, pane_ev, pend in backlog:
                    self._advance_pane(comp, ctx, insts, t0, pane_ev,
                                       pend.finalize(), t_end, group_key, out)
                backlog.clear()

            for t0, pane_ev in split_panes(batch, self.pane, 0, t_end):
                backlog.append((t0, pane_ev,
                                mb.submit(proc, pane_ev, self.stats)))
                if mb.ready():
                    flush_backlog()
            flush_backlog()

    def _advance_pane(self, comp, ctx, insts, t0: int, pane_ev: EventBatch,
                      M: np.ndarray, t_end: int, group_key: int,
                      out: dict) -> None:
        """Phase 4 (fold): advance window instances by one pane and emit
        closing windows."""
        obs = self.obs
        key = (obs.pane_key(pane_ev)
               if obs is not None and obs.tracing else None)
        fold_t0 = None
        fold_dt = 0.0
        for ci, aqi in enumerate(comp):
            q = self.workload.atomic[aqi]
            # open new instances whose window starts at this pane
            if t0 % q.slide == 0 and t0 + q.within <= t_end:
                insts[ci][t0] = _Instance(t0, ctx.layout.fresh_state())
            needs_minmax = ci in ctx.minmax_queries
            t_fold = perf_counter()
            advance_instances(M[ci], insts[ci])
            d = perf_counter() - t_fold
            self.stats.fold_s += d
            if fold_t0 is None:
                fold_t0 = t_fold
            fold_dt += d
            for w0, inst in list(insts[ci].items()):
                if needs_minmax and len(pane_ev):
                    inst.events.append(pane_ev)
                if w0 + q.within == t0 + self.pane:
                    out[(aqi, group_key, w0)] = self._emit(
                        ctx, ci, q, inst, group_key)
                    del insts[ci][w0]
                    self.stats.windows_emitted += 1
                    if key is not None:
                        obs.lifecycle("emit", key,
                                      args={"w0": w0, "q": aqi})
        if obs is not None and fold_t0 is not None:
            obs.pane_phase("fold", fold_t0, fold_dt, key=key)

    def _emit(self, ctx: ComponentContext, ci: int, q: AtomicQuery,
              inst: _Instance, group_key: int) -> dict:
        from .query import Agg, AggKind

        u = inst.u
        vals: dict[str, float] = {}
        for agg in q.aggs:
            if agg.kind == AggKind.COUNT_STAR:
                vals[repr(agg)] = float(u[ctx.layout.rp_idx(("count",))])
            elif agg.kind == AggKind.COUNT_TYPE:
                vals[repr(agg)] = float(u[ctx.layout.rp_idx(("sum", agg.type_name, None))])
            elif agg.kind == AggKind.SUM:
                vals[repr(agg)] = float(
                    u[ctx.layout.rp_idx(("sum", agg.type_name, agg.attr))])
            elif agg.kind == AggKind.AVG:
                s = u[ctx.layout.rp_idx(("sum", agg.type_name, agg.attr))]
                c = u[ctx.layout.rp_idx(("sum", agg.type_name, None))]
                vals[repr(agg)] = float(s / c) if c else float("nan")
            elif agg.kind in (AggKind.MIN, AggKind.MAX):
                from .minmax import window_minmax

                evs = (EventBatch.concat(inst.events) if inst.events
                       else None)
                vals[repr(agg)] = window_minmax(
                    self.workload.schema, q, evs, agg,
                    run_type_ids=ctx.relevant_type_ids, pane=self.pane)
        return vals

    # -- Or/And combination (Sec. 5) --

    def _combine(self, atomic_results: dict) -> dict:
        return combine_results(self.workload, atomic_results)


def vals_equal(a: dict, b: dict) -> bool:
    """Exact equality of window aggregate dicts, treating NaN == NaN (an
    AVG over zero matches is NaN in both runs and must not read as a
    difference)."""
    import math

    if a.keys() != b.keys():
        return False
    for k, va in a.items():
        vb = b[k]
        if va != vb and not (isinstance(va, float) and isinstance(vb, float)
                             and math.isnan(va) and math.isnan(vb)):
            return False
    return True


def combine_results(workload: Workload, atomic_results: dict) -> dict:
    """Combine atomic sub-query results into user-query results (Sec. 5)."""
    out: dict = {}
    for qname, idxs, comb in workload.combines:
        if comb is None:
            aqi = idxs[0]
            for (ai, gk, w0), vals in atomic_results.items():
                if ai == aqi:
                    out[(qname, gk, w0)] = vals
            continue
        left, right = idxs
        keys = set((gk, w0) for (ai, gk, w0) in atomic_results if ai == left)
        keys |= set((gk, w0) for (ai, gk, w0) in atomic_results if ai == right)
        for gk, w0 in keys:
            lv = atomic_results.get((left, gk, w0), {})
            rv = atomic_results.get((right, gk, w0), {})
            c1 = lv.get("COUNT(*)", 0.0)
            c2 = rv.get("COUNT(*)", 0.0)
            out[(qname, gk, w0)] = {"COUNT(*)": comb.combine_counts(c1, c2)}
    return out
