"""Merged HAMLET query template (paper Sec. 3.1, Figs. 3 & 8).

Each atomic query's FSA view is materialised as boolean matrices over the
schema's type universe, and the whole workload is merged into one template
whose transitions are labelled by the set of queries they hold for.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .events import StreamSchema
from .query import AtomicQuery

__all__ = ["QueryTemplate", "MergedTemplate", "build_templates"]


@dataclass
class QueryTemplate:
    """Matrix view of one atomic query over the type universe (T types).

    pred_type[e2, e1]  True iff e1 in pt(e2, q)   (paper Example 2)
    start[e] / end[e]  start / end types
    match[e]           type appears positively in the pattern
    negative[e]        type appears as a NOT component
    kleene[e]          E+ sub-pattern present (self-loop)
    """

    q: AtomicQuery
    pred_type: np.ndarray
    start: np.ndarray
    end: np.ndarray
    match: np.ndarray
    negative: np.ndarray
    kleene: np.ndarray


@dataclass
class MergedTemplate:
    """The HAMLET query template for a workload component.

    edge_q[k, e2, e1]  transition e1 -> e2 holds for query k
    shared_kleene[e]   list of query indices (into the component) for which
                       ``e+`` is shareable (Def. 4): len > 1 means shareable.
    """

    schema: StreamSchema
    queries: list[AtomicQuery]
    per_query: list[QueryTemplate]
    edge_q: np.ndarray
    shared_kleene: dict[int, list[int]]

    @property
    def n_types(self) -> int:
        return self.schema.n_types

    def type_ids_used(self) -> np.ndarray:
        used = np.zeros(self.schema.n_types, dtype=bool)
        for t in self.per_query:
            used |= t.match | t.negative
        return np.nonzero(used)[0]


def build_template(schema: StreamSchema, q: AtomicQuery) -> QueryTemplate:
    T = schema.n_types
    pred_type = np.zeros((T, T), dtype=bool)
    start = np.zeros(T, dtype=bool)
    end = np.zeros(T, dtype=bool)
    match = np.zeros(T, dtype=bool)
    negative = np.zeros(T, dtype=bool)
    kleene = np.zeros(T, dtype=bool)
    info = q.info
    for a, b in info.edges:
        pred_type[schema.type_id(b), schema.type_id(a)] = True
    for s in info.start:
        start[schema.type_id(s)] = True
    for e in info.end:
        end[schema.type_id(e)] = True
    for t in info.types:
        match[schema.type_id(t)] = True
    for n in info.negatives:
        negative[schema.type_id(n.neg_type)] = True
    for klt in info.kleene_types:
        kleene[schema.type_id(klt)] = True
    return QueryTemplate(q, pred_type, start, end, match, negative, kleene)


def build_templates(schema: StreamSchema, queries: list[AtomicQuery]) -> MergedTemplate:
    per_query = [build_template(schema, q) for q in queries]
    T = schema.n_types
    k = len(queries)
    edge_q = np.zeros((k, T, T), dtype=bool)
    for i, t in enumerate(per_query):
        edge_q[i] = t.pred_type
    shared: dict[int, list[int]] = {}
    for e in range(T):
        qs = [i for i, t in enumerate(per_query) if t.kleene[e]]
        if qs:
            shared[e] = qs
    return MergedTemplate(schema, list(queries), per_query, edge_q, shared)
