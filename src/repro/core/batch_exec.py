"""Pane-batch executor: ragged propagation jobs -> few bucketed launches.

The engine's plan phase walks every burst in a pane and *submits* its
propagation problems here instead of solving them inline; ``flush`` then
executes the backlog with one launch per size bucket:

* **dense jobs** (``mask is None``: strictly-lower all-ones adjacency) share
  a constant basis width per component, so they bucket by
  ``(next_pow2(b), d)`` with zero-row padding — padding is exact for the
  dense closed form — and run as one ``propagate_dense_batched`` call;
* **masked jobs** bucket by exact ``(b, d)`` (stacking needs equal shapes,
  and exact shapes keep each slice bitwise identical to the per-burst call)
  and run as one ``propagate_batched`` call per bucket;
* tiny masked jobs (``b <= 24`` on the numpy backend) keep the exact
  row-by-row oracle per item, matching the per-burst path bit for bit.

``batched=False`` degrades to the legacy one-launch-per-burst execution —
the differential tests assert the two modes agree bitwise.

``shard_slices`` is the pane-batch sharding hook: a callable mapping a
bucket's batch size to a list of slices (e.g.
``distributed.sharding.pane_bucket_shards``); each sub-batch is launched
separately so buckets can be split across devices/hosts.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..kernels import ops

__all__ = ["PropagateJob", "PaneBatchExecutor"]

# numpy-backend threshold below which the exact row-loop oracle beats the
# doubling GEMMs for a single burst (mirrors ops.propagate_batched)
_FAST_MIN_B = 25
_DENSE_B_MAX = ops.DENSE_B_MAX


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


@dataclass
class PropagateJob:
    """One propagation problem: ``mask is None`` marks a dense burst."""

    base: np.ndarray              # [b, d]
    mask: np.ndarray | None       # [b, b] strictly-lower adjacency
    result: np.ndarray | None = None


class PaneBatchExecutor:
    def __init__(self, backend: str = "np", batched: bool = True,
                 shard_slices=None):
        self.backend = backend
        self.batched = batched
        self.shard_slices = shard_slices
        self._pending: list[PropagateJob] = []
        self.jobs = 0
        self.launches = 0

    def submit(self, base: np.ndarray,
               mask: np.ndarray | None = None) -> PropagateJob:
        job = PropagateJob(np.asarray(base), mask)
        self._pending.append(job)
        self.jobs += 1
        return job

    # -- execution --

    def flush(self) -> None:
        jobs, self._pending = self._pending, []
        if not jobs:
            return
        if not self.batched:
            for j in jobs:
                self.launches += 1
                if j.mask is None:
                    j.result = np.asarray(
                        ops.propagate_dense(j.base, backend=self.backend))
                else:
                    j.result = np.asarray(
                        ops.propagate(j.base, j.mask, backend=self.backend))
            return
        dense = [j for j in jobs if j.mask is None
                 and j.base.shape[0] <= _DENSE_B_MAX]
        masked = [j for j in jobs if j.mask is not None]
        # oversize "dense" jobs fall back to an explicit all-ones mask
        for j in jobs:
            if j.mask is None and j.base.shape[0] > _DENSE_B_MAX:
                b = j.base.shape[0]
                j.mask = np.tril(np.ones((b, b)), k=-1)
                masked.append(j)
        self._flush_dense(dense)
        self._flush_masked(masked)

    def _slices(self, nb: int) -> list[slice]:
        if self.shard_slices is None:
            return [slice(0, nb)]
        return list(self.shard_slices(nb))

    def _flush_dense(self, jobs: list[PropagateJob]) -> None:
        buckets: dict[tuple, list[PropagateJob]] = {}
        for j in jobs:
            b, d = j.base.shape
            buckets.setdefault((_next_pow2(b), d, j.base.dtype), []).append(j)
        for (bp, d, dtype), bucket in buckets.items():
            stacked = np.zeros((len(bucket), bp, d), dtype=dtype)
            for i, j in enumerate(bucket):
                stacked[i, : j.base.shape[0]] = j.base
            out = np.empty_like(stacked)
            for sl in self._slices(len(bucket)):
                self.launches += 1
                out[sl] = np.asarray(ops.propagate_dense_batched(
                    stacked[sl], backend=self.backend))
            for i, j in enumerate(bucket):
                j.result = out[i, : j.base.shape[0]]

    def _flush_masked(self, jobs: list[PropagateJob]) -> None:
        from ..kernels import ref

        buckets: dict[tuple, list[PropagateJob]] = {}
        for j in jobs:
            buckets.setdefault(j.base.shape + (j.base.dtype,), []).append(j)
        for (b, d, _dtype), bucket in buckets.items():
            base = np.stack([j.base for j in bucket])
            mask = np.stack([j.mask for j in bucket])
            out = np.empty_like(base)
            small = self.backend == "np" and b < _FAST_MIN_B
            for sl in self._slices(len(bucket)):
                self.launches += 1
                if small:
                    # stacked row-loop oracle: b row steps for the whole
                    # bucket, each slice bitwise equal to the per-burst call
                    out[sl] = ref.numpy_prefix_propagate_batched(base[sl],
                                                                 mask[sl])
                else:
                    out[sl] = np.asarray(ops.propagate_batched(
                        base[sl], mask[sl], backend=self.backend))
            for i, j in enumerate(bucket):
                j.result = out[i]
