"""Pane-batch executor: ragged propagation jobs -> few bucketed launches.

The engine's plan phase walks every burst in a pane and *submits* its
propagation problems here instead of solving them inline; ``flush`` then
executes the backlog with one launch per size bucket:

* **dense jobs** (``mask is None``: strictly-lower all-ones adjacency) share
  a constant basis width per component, so they bucket by
  ``(next_pow2(b), d)`` with zero-row padding — padding is exact for the
  dense closed form — and run as one ``propagate_dense_batched`` call;
* **masked jobs** bucket by exact ``(b, d)`` (stacking needs equal shapes,
  and exact shapes keep each slice bitwise identical to the per-burst call)
  and run as one ``propagate_batched`` call per bucket;
* tiny masked jobs (``b <= 24`` on the numpy backend) keep the exact
  row-by-row oracle per item, matching the per-burst path bit for bit.

``batched=False`` degrades to the legacy one-launch-per-burst execution —
the differential tests assert the two modes agree bitwise.

``shard_slices`` is the pane-batch sharding hook: a callable mapping a
bucket's batch size to a list of slices (e.g.
``distributed.sharding.pane_bucket_shards``); each sub-batch is launched
separately so buckets can be split across devices/hosts.

Residency rules (cross-pane micro-batching support):

* **numpy backend** — the stacked *input* staging arrays are reused across
  flushes (one buffer per bucket shape, grown to the high-water batch size),
  so a steady-state stream stops allocating per pane.  Outputs are always
  freshly allocated: job results are views into them and must survive later
  flushes.
* **jax/pallas backends** — every bucket of a flush is launched before any
  result is pulled back; the whole flush then syncs with **one**
  ``ops.device_get_all`` call, keeping bucket outputs device-resident for
  the duration of the flush instead of round-tripping through
  ``np.asarray`` per bucket.  Host staging is *not* reused here: device
  transfers may be asynchronous, so inputs get fresh buffers.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..kernels import ops
from ..obs.metrics import OCCUPANCY_BUCKETS

__all__ = ["PropagateJob", "PaneBatchExecutor"]

# numpy-backend threshold below which the exact row-loop oracle beats the
# doubling GEMMs for a single burst (mirrors ops.propagate_batched)
_FAST_MIN_B = 25
_DENSE_B_MAX = ops.DENSE_B_MAX


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


@dataclass
class PropagateJob:
    """One propagation problem: ``mask is None`` marks a dense burst."""

    base: np.ndarray              # [b, d]
    mask: np.ndarray | None       # [b, b] strictly-lower adjacency
    result: np.ndarray | None = None


class PaneBatchExecutor:
    def __init__(self, backend: str = "np", batched: bool = True,
                 shard_slices=None, obs=None):
        self.backend = backend
        self.batched = batched
        self.shard_slices = shard_slices
        self.obs = obs
        self._pending: list[PropagateJob] = []
        # reusable host staging for stacked inputs, keyed by (kind, b, d,
        # dtype) and grown to the high-water bucket size (numpy backend only;
        # see the module docstring's residency rules)
        self._staging: dict[tuple, np.ndarray] = {}
        self.jobs = 0
        self.launches = 0
        self.flushes = 0

    def submit(self, base: np.ndarray,
               mask: np.ndarray | None = None) -> PropagateJob:
        job = PropagateJob(np.asarray(base), mask)
        self._pending.append(job)
        self.jobs += 1
        return job

    # -- execution --

    def flush(self) -> None:
        jobs, self._pending = self._pending, []
        if not jobs:
            return
        self.flushes += 1
        l0 = self.launches
        if not self.batched:
            for j in jobs:
                self.launches += 1
                if j.mask is None:
                    j.result = np.asarray(
                        ops.propagate_dense(j.base, backend=self.backend))
                else:
                    j.result = np.asarray(
                        ops.propagate(j.base, j.mask, backend=self.backend))
            return
        dense = [j for j in jobs if j.mask is None
                 and j.base.shape[0] <= _DENSE_B_MAX]
        masked = [j for j in jobs if j.mask is not None]
        # oversize "dense" jobs fall back to an explicit all-ones mask
        for j in jobs:
            if j.mask is None and j.base.shape[0] > _DENSE_B_MAX:
                b = j.base.shape[0]
                j.mask = np.tril(np.ones((b, b)), k=-1)
                masked.append(j)
        # launch every bucket, then resolve the whole flush with one host
        # sync (device backends stay device-resident until here)
        launched = self._launch_dense(dense) + self._launch_masked(masked)
        outs = ops.device_get_all([o for _, _, _, o in launched])
        full: dict[int, np.ndarray] = {}
        for (bucket, shape, sl, _), host in zip(launched, outs):
            arr = full.get(id(bucket))
            if arr is None:
                arr = full[id(bucket)] = np.empty(shape, dtype=host.dtype)
            arr[sl] = host
        done: set[int] = set()
        for bucket, _, _, _ in launched:
            if id(bucket) in done:
                continue
            done.add(id(bucket))
            arr = full[id(bucket)]
            for i, j in enumerate(bucket):
                j.result = arr[i, : j.base.shape[0]]
        if self.obs is not None:
            self.obs.observe("batch_exec.launches_per_flush",
                             self.launches - l0, OCCUPANCY_BUCKETS)

    def _slices(self, nb: int) -> list[slice]:
        if self.shard_slices is None:
            return [slice(0, nb)]
        return list(self.shard_slices(nb))

    def _stage(self, kind: str, nb: int, item_shape: tuple,
               dtype) -> np.ndarray:
        """A reusable stacked staging buffer (numpy backend only)."""
        if self.backend != "np":
            return np.empty((nb,) + item_shape, dtype=dtype)
        key = (kind,) + item_shape + (np.dtype(dtype),)
        buf = self._staging.get(key)
        if buf is None or buf.shape[0] < nb:
            buf = np.empty((nb,) + item_shape, dtype=dtype)
            self._staging[key] = buf
        return buf[:nb]

    def _launch_dense(self, jobs: list[PropagateJob]) -> list:
        buckets: dict[tuple, list[PropagateJob]] = {}
        for j in jobs:
            b, d = j.base.shape
            buckets.setdefault((_next_pow2(b), d, j.base.dtype), []).append(j)
        launched = []
        for (bp, d, dtype), bucket in buckets.items():
            nb = len(bucket)
            if self.obs is not None:
                self.obs.observe("batch_exec.bucket_occupancy", nb,
                                 OCCUPANCY_BUCKETS)
            stacked = self._stage("dense", nb, (bp, d), dtype)
            for i, j in enumerate(bucket):
                bj = j.base.shape[0]
                stacked[i, :bj] = j.base
                stacked[i, bj:] = 0.0
            for sl in self._slices(nb):
                self.launches += 1
                launched.append((bucket, (nb, bp, d), sl,
                                 ops.propagate_dense_batched(
                                     stacked[sl], backend=self.backend)))
        return launched

    def _launch_masked(self, jobs: list[PropagateJob]) -> list:
        from ..kernels import ref

        buckets: dict[tuple, list[PropagateJob]] = {}
        for j in jobs:
            buckets.setdefault(j.base.shape + (j.base.dtype,), []).append(j)
        launched = []
        for (b, d, dtype), bucket in buckets.items():
            nb = len(bucket)
            if self.obs is not None:
                self.obs.observe("batch_exec.bucket_occupancy", nb,
                                 OCCUPANCY_BUCKETS)
            base = self._stage("mbase", nb, (b, d), dtype)
            mask = self._stage("mmask", nb, (b, b), bucket[0].mask.dtype)
            for i, j in enumerate(bucket):
                base[i] = j.base
                mask[i] = j.mask
            small = self.backend == "np" and b < _FAST_MIN_B
            for sl in self._slices(nb):
                self.launches += 1
                if small:
                    # stacked row-loop oracle: b row steps for the whole
                    # bucket, each slice bitwise equal to the per-burst call
                    out = ref.numpy_prefix_propagate_batched(base[sl],
                                                             mask[sl])
                else:
                    out = ops.propagate_batched(base[sl], mask[sl],
                                                backend=self.backend)
                launched.append((bucket, (nb, b, d), sl, out))
        return launched
