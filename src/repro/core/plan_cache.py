"""Pane-plan memoization: bursty streams repeat pane *shapes*.

Under bursty arrival the expensive part of planning a pane — burst
segmentation, divergence layout, in-burst adjacency construction, the
event-level snapshot (z) column layout, and the count-round injection rows —
depends only on the pane's *shape*: the type run-length structure, the
per-burst per-query predicate/edge-mask bits, the negation hits, and the
sharing decision the optimizer took.  None of it reads attribute values
beyond the predicate outcomes.  Bursty workloads therefore re-plan the same
shape over and over; this module caches the structural plan so a repeated
shape skips phase-1 group construction entirely and only swaps in the fresh
attribute/value data.

Key design (exactness over speed):

* The signature stores the *full* discriminating bytes — packed predicate
  match bits, packed edge-mask bits, negation-hit query ids, and the
  optimizer's decided groups — never a lossy hash, so a cache hit is
  *provably* the identical plan and the engine's bitwise differential
  guarantee survives memoization.
* The sharing decision is part of the key, not the cached value: the
  optimizer runs fresh on every pane (its benefit model depends on the
  running event count), and a flipped share/no-share choice simply misses
  into a new entry.  Plan reuse can therefore never freeze the sharing
  decision.
* Entries are LRU-evicted beyond ``max_entries``; cached group plans are
  stripped of per-pane data (attributes, match vectors, job handles) so an
  entry holds only the structural arrays.

The cache is shared per (component, runtime): every :class:`PaneProcessor`
the runtime spawns — service epochs, overload group drivers, event-time
group processors — consults the same cache, so a shape learned on one group
partition is reused on all of them.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field

__all__ = ["PanePlan", "PanePlanCache", "PLAN_STAT_FIELDS"]

# RunStats counters whose increments happen inside the cached (phase-1 group
# construction) region; replayed on every hit so the stats stream — and
# everything keyed off it, like the optimizer's running event count — evolves
# identically whether or not the cache is enabled.
PLAN_STAT_FIELDS = ("graphlets", "shared_bursts", "shared_graphlets",
                    "split_bursts", "snapshots_created",
                    "snapshots_propagated")


@dataclass
class PanePlan:
    """One cached structural plan: the step templates plus the stat delta
    the skipped planning code would have produced.

    ``zero_copy`` marks a plan none of whose steps carry per-pane data (no
    divergent rows, no sum-unit injection values, no negation steps): the
    cached step list is then reused *as is* on a hit — job handles live on
    the pending pane, so the shared plan objects are never written.

    ``fold_schedule`` memoizes the fold executor's level/bucket schedule
    (``core/fold_exec.py``) for this plan's step list — structural like the
    steps themselves, filled in lazily on the first fold, so warm panes skip
    fold planning entirely."""

    steps: list
    stat_delta: dict = field(default_factory=dict)
    zero_copy: bool = False
    fold_schedule: object = None

    def apply_stats(self, stats) -> None:
        for f, v in self.stat_delta.items():
            setattr(stats, f, getattr(stats, f) + v)


class PanePlanCache:
    """Bounded LRU of :class:`PanePlan` keyed by exact pane signatures."""

    def __init__(self, max_entries: int = 128):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = int(max_entries)
        self._entries: "OrderedDict[tuple, PanePlan]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: tuple) -> PanePlan | None:
        plan = self._entries.get(key)
        if plan is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return plan

    def put(self, key: tuple, plan: PanePlan) -> None:
        self._entries[key] = plan
        self._entries.move_to_end(key)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._entries.clear()

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def snapshot_stats(self, stats) -> dict:
        return {f: getattr(stats, f) for f in PLAN_STAT_FIELDS}

    @staticmethod
    def stat_delta(before: dict, stats) -> dict:
        # zero deltas are dropped: apply_stats replays the dict on every
        # cache hit, and most fields don't move on a typical pane
        return {f: d for f, v in before.items()
                if (d := getattr(stats, f) - v) != 0}
