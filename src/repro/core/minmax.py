"""MIN/MAX side path for the windowed runtime.

MIN/MAX are idempotent, not linear, so they do not ride the snapshot algebra.
Per Def. 5 they are only shareable between identical aggregates anyway; the
runtime retains the window's events for queries that request them and runs a
GRETA-style idempotent propagation at window close (see baselines/greta.py).
"""

from __future__ import annotations

from .events import EventBatch, StreamSchema
from .query import Agg, AtomicQuery

__all__ = ["window_minmax"]


def window_minmax(schema: StreamSchema, q: AtomicQuery, ev: EventBatch | None,
                  agg: Agg, run_type_ids: list[int] | None = None,
                  pane: int | None = None) -> float:
    if ev is None or len(ev) == 0:
        return float("nan")
    from .baselines.greta import window_eval_greta

    sub_q_aggs = window_eval_greta(schema, q, ev, run_type_ids, pane=pane)
    return sub_q_aggs[repr(agg)]
