"""Streaming service wrapper: out-of-order arrival handling and dynamic
workload changes.

The paper assumes in-order arrival and a static workload, citing standard
techniques for both relaxations (Sec. 2.1 [11,26,27,41] and [24,48]).  This
module supplies those substrate pieces:

* ``OutOfOrderBuffer`` — bounded-lateness reordering: events are released in
  timestamp order once the watermark (max seen time − lateness) passes them;
  stragglers inside the bound merge correctly, later ones are counted and
  dropped.
* ``HamletService`` — incremental execution in *epochs* (the LCM of all
  windows/slides).  Because sliding windows span any boundary, each epoch is
  evaluated over a replayed history tail of ``max(within)`` and only the
  windows **closing** inside the epoch are emitted — bounded re-processing
  (overlap factor ≤ 1 + max(within)/epoch), exact results.  Query add/remove
  takes effect at the next epoch boundary (plan migration at epoch
  granularity, after [48]).

Passing an :class:`repro.overload.OverloadConfig` opts the service into load
shedding at its natural (epoch) granularity: released events are shed by the
configured policy before entering history, the PID controller is fed the
measured epoch-processing latency (``slo_ms`` is therefore a per-*epoch*
target here; the pane-granular loop lives in ``repro.overload.runtime``), and
every shed event is charged to the error accountant.  The state is exposed as
``service.overload``.

Passing an :class:`repro.eventtime.EventTimeConfig` replaces the fixed-bound
``OutOfOrderBuffer`` with the event-time layer's policy-driven
:class:`~repro.eventtime.ReorderBuffer` *and* opens the revision path: a
straggler behind the already-emitted frontier but inside the lateness horizon
is merged into the retained history tail and every emitted window it touches
is re-evaluated — value changes append retract/amend records to
``service.revisions`` and update ``service.results`` in place (``feed`` keeps
returning only first-time emissions).  Stragglers beyond the horizon are
expired: counted in ``service.expired_late`` and, when overload is attached,
charged to the error accountant so the shedding bounds survive disorder.
History retention is widened from ``max(within)`` to ``max(within) +
horizon`` to make that replay exact.  (The pane-granular speculative path —
emit optimistically, revise from stored pane matrices — lives in
``repro.eventtime.revision``.)

This wrapper is single-instance: one runtime, one plan cache, one epoch
clock.  The multi-tenant tier above it lives in ``repro.shardsvc`` — a
router partitions tenants (contiguous group ranges) across N shard workers
via a deterministic consistent-hash placement table, admission control is
hoisted to the router (with every error accountant merged into one fleet
certificate), and fleet-level finality is negotiated by the aligned-epoch
watermark protocol, which excludes lagging shards instead of waiting on
them.  The sharded service's contract is differential: under
``none``/``global_fixed`` admission an N-shard run is a permutation-stable
bitwise match of the 1-shard run on the same stream (``tests/
test_shardsvc.py``), so everything documented here about single-instance
semantics carries over shard-by-shard.
"""

from __future__ import annotations

import math
import time

import numpy as np

from .engine import HamletRuntime, RunStats, vals_equal
from .events import EventBatch
from .query import Query, Workload

__all__ = ["OutOfOrderBuffer", "HamletService", "ServiceOverloadState"]


class ServiceOverloadState:
    """Overload machinery attached to a :class:`HamletService`."""

    def __init__(self, workload: Workload, config):
        from ..overload.accountant import ErrorAccountant
        from ..overload.controller import LatencyController
        from ..overload.shedding import make_shedder

        self.config = config
        self.controller = LatencyController.from_config(config)
        self.accountant = ErrorAccountant(workload)
        self.shedder = make_shedder(
            config.shed_policy, workload, seed=config.seed,
            min_burst_keep=config.min_burst_keep,
            benefit_model=config.benefit_model)
        self.shed_events = 0

    def rebind(self, workload: Workload) -> None:
        """Refresh the workload-derived pieces after query add/remove;
        controller state and accounting history survive the migration."""
        from ..overload.shedding import make_shedder

        self.shedder = make_shedder(
            self.config.shed_policy, workload, seed=self.config.seed,
            min_burst_keep=self.config.min_burst_keep,
            benefit_model=self.config.benefit_model)
        self.accountant.migrate(workload)

    def shed(self, batch: EventBatch) -> EventBatch:
        """Shed from a released batch, pane by pane.

        The batch may span several panes (the service drains at epoch
        granularity), but burst segmentation — and the per-burst witness the
        accountant's multiplicative bound relies on — is pane-scoped in the
        engine, so the plan must be too: a run spanning two panes is two
        engine bursts, and a witness in the first says nothing about the
        second."""
        if self.shedder is None or not len(batch):
            return batch
        ratio = self.controller.shed_ratio
        if ratio <= 0.0:
            return batch
        pane = self.accountant.pane
        kept: list[EventBatch] = []
        for t0 in range(int(batch.time.min()) // pane * pane,
                        int(batch.time.max()) + 1, pane):
            chunk = batch.time_slice(t0, t0 + pane)
            if not len(chunk):
                continue
            keep_n = math.floor(len(chunk) * (1.0 - ratio) + 1e-9)
            if keep_n >= len(chunk):
                kept.append(chunk)
                continue
            plan = self.shedder.plan(chunk, keep_n)
            self.accountant.record(chunk.select(plan.shed),
                                   witnessed=plan.witnessed)
            self.shed_events += plan.n_shed
            kept.append(chunk.select(plan.keep))
        return EventBatch.concat(kept) if kept else batch.select(
            np.array([], dtype=np.int64))


class OutOfOrderBuffer:
    """Bounded-lateness reordering buffer (accepts arbitrary arrival order)."""

    def __init__(self, schema, lateness: int):
        self.schema = schema
        self.lateness = int(lateness)
        self._held: list[tuple[int, int, int, np.ndarray, int]] = []
        self._arrival = 0
        self._released_upto = -(1 << 62)
        self.dropped_late = 0

    def feed_arrays(self, type_id, time, attrs=None, group=None) -> EventBatch:
        n = len(type_id)
        attrs = (np.zeros((n, max(1, len(self.schema.attrs))))
                 if attrs is None else np.asarray(attrs))
        group = np.zeros(n, np.int64) if group is None else np.asarray(group)
        for i in range(n):
            t = int(time[i])
            if t < self._released_upto:
                self.dropped_late += 1
                continue
            self._held.append((t, self._arrival, int(type_id[i]),
                               attrs[i].copy(), int(group[i])))
            self._arrival += 1
        if not self._held:
            return self._empty()
        watermark = max(t for t, *_ in self._held) - self.lateness
        return self._release(watermark)

    def feed(self, batch: EventBatch) -> EventBatch:
        return self.feed_arrays(batch.type_id, batch.time, batch.attrs,
                                batch.group)

    def flush(self) -> EventBatch:
        return self._release(1 << 62)

    def _release(self, watermark: int) -> EventBatch:
        out = sorted([e for e in self._held if e[0] <= watermark])
        self._held = [e for e in self._held if e[0] > watermark]
        if not out:
            return self._empty()
        # events with time == the last released tick may still arrive (e.g.
        # duplicate timestamps split across feeds); only strictly older
        # arrivals are late
        self._released_upto = max(self._released_upto, out[-1][0])
        return EventBatch(
            self.schema,
            np.array([e[2] for e in out], np.int32),
            np.array([e[0] for e in out], np.int64),
            np.stack([e[3] for e in out]),
            np.array([e[4] for e in out], np.int64),
        )

    def _empty(self) -> EventBatch:
        return EventBatch(self.schema, np.array([], np.int32),
                          np.array([], np.int64), None)


class HamletService:
    """Incremental HAMLET with dynamic workload changes at epoch boundaries.

    ``micro_batch`` / ``plan_cache`` / ``fold_exec`` pass through to the
    replay :class:`HamletRuntime` (cross-pane fused launches, pane-plan
    memoization, the stacked finalize/fold executor — see
    ``core/engine.py``); the runtime is reused while the workload is
    unchanged so the plan caches stay warm across epochs.  ``obs`` attaches
    a :class:`repro.obs.Observability` facade: it is threaded into the
    replay runtime (pane spans, metrics, sharing audit) and each epoch
    replay additionally gets an ``epoch`` span on the engine track."""

    def __init__(self, schema, queries: list[Query], policy=None,
                 lateness: int = 0, sharable_mode: str = "units",
                 overload=None, batch_exec: bool = True, eventtime=None,
                 micro_batch: int = 1, plan_cache: bool = True,
                 fold_exec: bool = True, obs=None):
        from .events import pane_size_for

        self.schema = schema
        self.obs = obs
        self.sharable_mode = sharable_mode
        self.policy = policy
        self.batch_exec = batch_exec
        self.micro_batch = max(1, int(micro_batch))
        self.plan_cache = plan_cache
        self.fold_exec = fold_exec
        # the replay runtime is reused while the workload is unchanged, so
        # the per-component plan caches (and the executor's staging buffers)
        # stay warm across epochs; query add/remove rebuilds it
        self._rt: HamletRuntime | None = None
        self._rt_stale = True
        self._queries: dict[str, Query] = {q.name: q for q in queries}
        self._pending_add: dict[str, Query] = {}
        self._pending_remove: set[str] = set()
        self.eventtime = eventtime
        if eventtime is None:
            self._ooo = OutOfOrderBuffer(schema, lateness)
            self._reorder = None
        else:
            from ..eventtime.reorder import ReorderBuffer
            from ..eventtime.watermark import make_watermark

            # pane granularity is fixed at construction, like the
            # accountant's (a migrated workload keeps the original sealing
            # grid; it stays sound because sealing only ever under-promises)
            pane = pane_size_for([(q.within, q.slide)
                                  for q in queries] or [(1, 1)])
            self._ooo = None
            self._reorder = ReorderBuffer(
                schema, pane, make_watermark(eventtime),
                lateness_horizon=eventtime.lateness_horizon)
        self.revisions: list = []                # retract/amend records
        self._rev_seen = 0                       # revisions already charged
        self._revno: dict = {}                   # window key -> revision no
        # when each query became active (epoch time): revision must never
        # resurrect windows that closed before a query existed
        self._query_since: dict[str, int] = {q.name: 0 for q in queries}
        self.expired_late = 0
        self._events: EventBatch | None = None   # history tail
        self._t_done = 0                         # epochs emitted up to here
        self.results: dict = {}
        self.stats = RunStats()
        self._refresh_derived()
        self.overload = (None if overload is None else
                         ServiceOverloadState(self._workload(), overload))

    def _workload(self) -> Workload:
        return Workload(self.schema, list(self._queries.values()),
                        sharable_mode=self.sharable_mode)

    def _refresh_derived(self) -> None:
        self._epoch_len = 1
        self._max_within = 1
        for q in self._queries.values():
            self._epoch_len = math.lcm(self._epoch_len, q.within, q.slide)
            self._max_within = max(self._max_within, q.within)

    # -- dynamic workload (takes effect at the next epoch boundary) --

    def add_query(self, q: Query) -> None:
        self._pending_add[q.name] = q

    def remove_query(self, name: str) -> None:
        self._pending_remove.add(name)

    def _apply_pending(self) -> None:
        if not (self._pending_add or self._pending_remove):
            return
        for name in self._pending_remove:
            self._queries.pop(name, None)
            self._pending_add.pop(name, None)
            self._query_since.pop(name, None)
        for name, q in self._pending_add.items():
            if name not in self._queries:
                self._query_since[name] = self._t_done
            self._queries[name] = q
        self._pending_add.clear()
        self._pending_remove.clear()
        self._refresh_derived()
        self._rt_stale = True
        if self.overload is not None:
            self.overload.rebind(self._workload())

    # -- streaming --

    def feed(self, batch: EventBatch) -> dict:
        if self._reorder is not None:
            return self._feed_eventtime(batch)
        ready = self._ooo.feed(batch)
        if self.overload is not None:
            ready = self.overload.shed(ready)
        self._append(ready)
        return self._drain(final=False)

    def close(self) -> dict:
        if self._reorder is not None:
            res = self._reorder.flush()
            self._absorb_sealed(res)
            return self._drain(final=True)
        self._append(self._ooo.flush())
        return self._drain(final=True)

    def heartbeat(self, group: int, t: int) -> dict:
        """Group liveness signal (event-time mode with the group_heartbeat
        watermark policy); may seal panes and emit windows."""
        if self._reorder is None:
            return {}
        self._absorb_sealed(self._reorder.heartbeat(group, t))
        return self._drain(final=False)

    def _feed_eventtime(self, batch: EventBatch) -> dict:
        res = self._reorder.push(batch)
        self._absorb_sealed(res)
        if res.late is not None and len(res.late):
            self.revise(res.late)
        return self._drain(final=False)

    def _absorb_sealed(self, res) -> None:
        if res.expired is not None and len(res.expired):
            self._expire(res.expired)
        ready = [sp.events for sp in res.sealed if len(sp.events)]
        if not ready:
            return
        released = EventBatch.concat(ready)
        if self.overload is not None:
            released = self.overload.shed(released)
        self._append(released)

    def _expire(self, batch: EventBatch) -> None:
        self.expired_late += len(batch)
        if self.overload is not None:
            self.overload.accountant.record(batch, witnessed=False, late=True)

    @property
    def _horizon(self) -> int:
        if self.eventtime is None:
            return 0
        h = self.eventtime.lateness_horizon
        # retention is widened by the horizon (see _run_epoch), so any
        # configured depth replays exactly; None (unbounded in the config's
        # contract) defaults to max(within) here to keep retention finite
        return self._max_within if h is None else h

    # -- revision (event-time mode) --

    def revise(self, late: EventBatch) -> list:
        """Fold stragglers that arrived behind the emitted frontier into the
        retained history and re-evaluate every emitted window they touch.

        Events inside the lateness horizon are merged (by time, provenance
        ties by ``seq``); affected windows are re-run over the retained tail
        with the epoch replay arithmetic, and every value change appends a
        ``retract`` + ``amend`` record pair to ``self.revisions`` and
        updates ``self.results``.  Events behind the horizon are expired
        (counted; charged to the overload accountant when attached).
        Returns the new records."""
        from ..eventtime.revision import EmissionRecord

        if not len(late):
            return []
        bound = self._t_done - self._horizon
        old_mask = late.time < bound
        if old_mask.any():
            self._expire(late.select(np.nonzero(old_mask)[0]))
            late = late.select(np.nonzero(~old_mask)[0])
        if not len(late):
            return []
        self._events = (late if self._events is None
                        else EventBatch.merge([self._events, late]))

        # replay the affected region: only windows that actually contain a
        # straggler (per group), were already emitted (close <= t_done), and
        # belong to a query that existed when they closed
        t_from = int(late.time.min())
        L = self._epoch_len
        shift = max(0, (t_from - self._max_within) // L * L)
        end = self._t_done
        if end <= shift:
            return []
        res = self._replay(shift, end)
        late_by_group = {int(g): b.time
                         for g, b in late.partition_by_group().items()}

        records: list = []
        for (qn, gk, w0), vals in res.items():
            q = self._queries.get(qn)
            if q is None:
                continue
            close_t = w0 + shift + q.within
            if not (t_from < close_t <= end):
                continue        # unaffected or not yet emitted
            if close_t <= self._query_since.get(qn, 0):
                continue        # window predates the query
            lt = late_by_group.get(int(gk))
            if lt is None or not ((lt >= w0 + shift) & (lt < close_t)).any():
                continue        # no straggler landed inside this window
            key = (qn, gk, w0 + shift)
            old = self.results.get(key)
            if old is None:
                # a straggler made this window's group visible for the
                # first time: a late first emission, not an amendment
                records.append(EmissionRecord("emit", qn, gk, w0 + shift,
                                              vals, 0))
            elif vals_equal(old, vals):
                continue
            else:
                rev = self._revno.get(key, 0) + 1
                self._revno[key] = rev
                records.append(EmissionRecord("retract", qn, gk,
                                              w0 + shift, old, rev - 1))
                records.append(EmissionRecord("amend", qn, gk, w0 + shift,
                                              vals, rev))
            self.results[key] = vals
        self.revisions.extend(records)
        return records

    def _append(self, batch: EventBatch) -> None:
        if not len(batch):
            return
        self._events = (batch if self._events is None
                        else EventBatch.concat([self._events, batch]))

    def _drain(self, final: bool) -> dict:
        new: dict = {}
        while self._events is not None and len(self._events):
            horizon = int(self._events.time.max())
            end = self._t_done + self._epoch_len
            if horizon < end and not final:
                break
            if horizon < self._t_done and final:
                break
            new.update(self._run_epoch(end))
            if final and (self._events is None or
                          not len(self._events) or
                          int(self._events.time.max()) < self._t_done):
                break
        return new

    def _replay(self, shift: int, end: int) -> dict:
        """Run the current workload over retained history in [shift, end),
        window starts re-aligned by ``shift`` (a multiple of the epoch) —
        the one replay primitive shared by epoch emission and revision, so
        their arithmetic cannot drift apart."""
        ev = self._events
        sel = np.nonzero((ev.time >= shift) & (ev.time < end))[0]
        sub = ev.select(sel)
        shifted = EventBatch(self.schema, sub.type_id, sub.time - shift,
                             sub.attrs, sub.group)
        rt = self._runtime()
        res = rt.run(shifted, t_end=end - shift)
        self.stats.merge(rt.stats)
        return res

    def _runtime(self) -> HamletRuntime:
        """The replay runtime, rebuilt only after a workload migration; its
        stats are reset per replay (the service merges them itself)."""
        if self._rt is None or self._rt_stale:
            self._rt = HamletRuntime(self._workload(), policy=self.policy,
                                     batch_exec=self.batch_exec,
                                     micro_batch=self.micro_batch,
                                     plan_cache=self.plan_cache,
                                     fold_exec=self.fold_exec,
                                     obs=self.obs)
            self._rt_stale = False
        self._rt.stats = RunStats()
        return self._rt

    def _run_epoch(self, end: int) -> dict:
        t_start = time.perf_counter()
        L = self._epoch_len
        # replay shift: a multiple of L (window starts stay slide-aligned)
        k_hist = math.ceil(self._max_within / L)
        shift = max(0, (end // L - 1 - k_hist)) * L
        res = self._replay(shift, end)

        # emit only windows that close inside this epoch
        out: dict = {}
        for (qn, gk, w0), vals in res.items():
            q = self._queries.get(qn)
            if q is None:
                continue
            close_t = w0 + shift + q.within
            if self._t_done < close_t <= end:
                out[(qn, gk, w0 + shift)] = vals
        self.results.update(out)
        if self.obs is not None and self.obs.tracing:
            self.obs.tracer.complete(
                "epoch", t_start, time.perf_counter() - t_start,
                cat="service", args={"end": end, "emitted": len(out)})

        # retire history older than any future window — or, in event-time
        # mode, any still-revisable emitted window — needs
        keep_from = end - self._max_within - self._horizon
        ev = self._events
        keep = np.nonzero(ev.time >= keep_from)[0]
        self._events = ev.select(keep) if len(keep) else None
        self._t_done = end
        self._apply_pending()
        if self.overload is not None:
            # disorder-aware admission control: besides epoch latency, feed
            # the controller the revision load this epoch — retract/amend
            # records per window emitted — so a revision storm under heavy
            # disorder raises the shed ratio (see overload/controller.py)
            n_rev = len(self.revisions) - self._rev_seen
            self._rev_seen = len(self.revisions)
            rev_load = n_rev / max(1, len(out))
            self.overload.controller.update(
                (time.perf_counter() - t_start) * 1e3,
                revision_load=rev_load)
        return out
