"""Stacked, cache-aware finalize/fold executor (phases 3-4 of the pipeline).

``PaneProcessor.finalize`` historically replayed a pane group by group: per
graphlet a Python-level coefficient fold (``W`` build, event-snapshot fills,
``S @ W``) against the running state functionals.  With planning memoized and
execute launches fused (PR 4) that per-graphlet Python became the dominant
warm-pane cost.  This module lifts the replay out of the engine into a
:class:`FoldExecutor` that mirrors ``batch_exec.PaneBatchExecutor``: it
buckets same-shape graphlets — across a pane *and* across every pane of a
micro-batch flush — and folds each bucket with one stacked matmul set.

Correctness model (what may and may not be reordered)
-----------------------------------------------------
A group's fold reads the state rows of its member queries (``gaterow[g]``,
``arow[g]`` — the x_u functionals are built from the *current* running
aggregates) and accumulates into the same rows; negation steps zero rows of
the same arrays.  Steps touching **disjoint** query sets therefore commute
bitwise, while two steps sharing a query never do (successive graphlets of
one query form a genuine linear recurrence through ``arow``).  The executor
makes that precise with a *level schedule*: walking the pane's step list in
stream order, each step's level is ``1 + max(level of any earlier step
sharing a query)``.  Every per-query chain (negation gates included) stays
strictly ordered across levels; within a level all steps are query-disjoint
by construction, so stacking them is a pure batching of independent slices.
Panes are independent (each folds from a fresh state), so level ``L`` of
every pending pane lands in the same round — a flush of K panes folds its
whole backlog in ``max_levels`` rounds, one stacked launch per shape bucket
``(B_local, d, b)`` per round; without divergent rows the coefficients are
read only through their column sums, so ``d == 0`` graphlets of *different*
burst lengths share one launch.

Bitwise identity with the sequential replay is preserved the same way the
execute phase preserves it (``kernels/ref.py``): every stacked operation is
the *stacked twin* of the per-group numpy call — batched ``np.matmul`` whose
slices run the identical per-slice GEMM, stacked axis-1 column sums whose
slices run the identical axis-0 reduction, boolean masks, and ``np.where``
selects of exactly-zero lanes.  The event-snapshot fill loop (rank-1 ``P``
updates per divergent row) advances all bucket members one divergent row at
a time; members are independent, so interleaving them is a no-op, and the
per-row arithmetic keeps the sequential operand order.

Cache tiers (warm panes skip fold planning entirely)
----------------------------------------------------
* the per-plan **level schedule** — step levels, negation split points,
  per-level shape buckets with member index arrays — is cached on the
  :class:`~repro.core.plan_cache.PanePlan` next to the step list;
* the **flush plan** — the merged per-round buckets of a whole (ctx,
  K-pane schedule combination), with flat gather/scatter indices into the
  stacked state, pre-summed ``S`` rows for trivial graphlets (their count
  coefficients *are* the cached injection rows), and a flush-global
  batched-by-burst-length layout for the dynamic ``S`` fills — lives in a
  bounded LRU on the executor.

A warm steady stream therefore pays, per round: one ``take`` of the state
rows, two batched matmuls, one fancy-indexed scatter — plus a handful of
flush-wide stacked column sums.

Window folds (phase 4) ride the same executor: :meth:`FoldExecutor
.fold_windows` is the batched twin of :func:`repro.core.engine.fold_panes`,
bucketing window chains by length and folding each bucket through
``kernels.ops.fold_stacked`` with one host sync for the whole batch — the
event-time revision path uses it to re-fold a revision storm's dirty windows
as one stacked launch set.
"""

from __future__ import annotations

import itertools
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from ..kernels import ops
from ..obs.metrics import OCCUPANCY_BUCKETS

__all__ = ["FoldExecutor", "FoldJob", "FoldSchedule", "build_fold_schedule"]

_sched_serial = itertools.count()


def _is_group(step) -> bool:
    # duck-typed to avoid an import cycle with engine.py: group plans carry
    # ``g``; negation steps carry ``hits``
    return hasattr(step, "g")


# --------------------------------------------------------------------------
# fold schedule: levels + per-level shape buckets (structural, cacheable)
# --------------------------------------------------------------------------


@dataclass
class _BucketTpl:
    """Same-shape graphlets of one plan at one level, with the member-level
    structural arrays the stacked fold needs (all plan-cacheable)."""

    b: int                 # exact burst length (0 for d == 0: ragged bucket)
    B_local: int
    d: int
    steps: list            # step indices into the pane's step list
    ng: int                # number of groups
    q: np.ndarray          # [Nm] member query ids
    gof: np.ndarray        # [Nm] member -> group ordinal within this bucket
    el: np.ndarray         # [Nm] member local-type indices
    ptm: np.ndarray        # [Nm, t] float64 pt_mask rows
    start: np.ndarray      # [Nm] float64 start-flag (the f_c gate term)
    end: np.ndarray        # [Nm] bool end-flag (rrow rows)
    div: np.ndarray | None  # [ng, d] divergent row indices (None when d==0)


@dataclass
class FoldSchedule:
    """Cached fold plan of one pane: levels, negation split points, and the
    per-level shape buckets.  ``serial`` identifies the schedule in the
    executor's flush-plan cache (ids are unsafe across plan-cache
    evictions)."""

    n_levels: int
    used: tuple            # unit indices folded per group: (0, *sum units)
    neg: list              # per level: [(step idx, hits)]
    buckets: list          # per level: [ _BucketTpl ]
    serial: int = field(default_factory=lambda: next(_sched_serial))


def _levelize(steps: list) -> list[int]:
    """Per-step fold level: ``1 + max(level of any earlier step sharing a
    query)`` — every per-query chain is serialized across levels, and steps
    within a level are query-disjoint (their folds commute bitwise)."""
    cur: dict[int, int] = {}
    levels: list[int] = []
    for s in steps:
        qs = s.g if _is_group(s) else [qi for qi, _ in s.hits]
        lv = 0
        for q in qs:
            c = cur.get(q, 0)
            if c > lv:
                lv = c
        levels.append(lv)
        for q in qs:
            cur[q] = lv + 1
    return levels


def build_fold_schedule(ctx, steps: list) -> FoldSchedule:
    """Derive the structural fold schedule for one pane's step list."""
    levels = _levelize(steps)
    n_levels = (max(levels) + 1) if levels else 0
    used = tuple([0] + [ui for ui, _, _ in ctx.sum_unit_cols])
    neg: list[list] = [[] for _ in range(n_levels)]
    raw: list[dict] = [{} for _ in range(n_levels)]
    for i, (s, lv) in enumerate(zip(steps, levels)):
        if not _is_group(s):
            neg[lv].append((i, s.hits))
            continue
        # without divergent rows the fold reads the coefficients only
        # through their per-group column sums, so graphlets of *different*
        # burst lengths stack into one launch; the snapshot-fill path
        # (d > 0) carries per-event arrays and needs the exact length
        raw[lv].setdefault(
            (s.B_local, s.b if len(s.div_rows) else 0), []).append(i)
    buckets: list[list[_BucketTpl]] = []
    for lv in range(n_levels):
        out = []
        for (B_local, b), idxs in raw[lv].items():
            q_parts, gof_parts, el_parts, ptm_parts = [], [], [], []
            start_parts, end_parts, div_parts = [], [], []
            d = None
            for go, i in enumerate(idxs):
                s = steps[i]
                g = np.asarray(s.g, dtype=int)
                q_parts.append(g)
                gof_parts.append(np.full(len(g), go, dtype=int))
                el_parts.append(np.full(len(g), s.el, dtype=int))
                ptm_parts.append(ctx.pt_mask[g, s.el].astype(np.float64))
                start_parts.append(
                    ctx.start_flag[g, s.el].astype(np.float64))
                end_parts.append(ctx.end_flag[g, s.el])
                dr = np.asarray(s.div_rows, dtype=int)
                if d is None:
                    d = len(dr)
                div_parts.append(dr)
            out.append(_BucketTpl(
                b=b, B_local=B_local, d=int(d), steps=idxs, ng=len(idxs),
                q=np.concatenate(q_parts),
                gof=np.concatenate(gof_parts),
                el=np.concatenate(el_parts),
                ptm=np.ascontiguousarray(np.concatenate(ptm_parts)),
                start=np.concatenate(start_parts),
                end=np.concatenate(end_parts),
                div=(np.stack(div_parts) if d else None)))
        buckets.append(out)
    return FoldSchedule(n_levels=n_levels, used=used, neg=neg,
                        buckets=buckets)


# --------------------------------------------------------------------------
# executor
# --------------------------------------------------------------------------


@dataclass
class FoldJob:
    """One pending (pane, component) finalize; ``M`` is set by ``flush``."""

    proc: object           # PaneProcessor (supplies ctx + legacy fallback)
    steps: list
    jobs: list             # executor handles parallel to ``steps``
    stats: object
    host: object = None    # PanePlan carrying the cached schedule, or None
    M: np.ndarray | None = None


def _state0(ctx, J: int) -> np.ndarray:
    """Fresh fused pane-entry state ``Z [J, k, R, C]`` (row layout: ``0 =
    gate``, ``1 + u*t + ty = arow[u, ty]``, ``1 + nu*t + u = rrow[u]``)."""
    k, nu = ctx.k, ctx.nu
    t, C = len(ctx.pos_type_ids), ctx.layout.size
    R = 1 + nu * t + nu
    Z = np.zeros((J, k, R, C))
    Z[:, :, 0, ctx.layout.GATE] = 1.0
    if nu and t:
        Z[:, :, 1 + np.arange(nu * t), ctx.a_cols.reshape(-1)] = 1.0
    if nu:
        Z[:, :, 1 + nu * t + np.arange(nu), ctx.rp_cols] = 1.0
    return Z


class _CtxState:
    """Stacked running state of every pending job sharing one component
    context, fused into one array ``Z [J, k, R, C]`` — one gather serves a
    whole bucket's ``W`` build (see :func:`_state0` for the row layout)."""

    def __init__(self, ctx, jobs: list[FoldJob], Z: np.ndarray | None = None):
        self.ctx = ctx
        self.jobs = jobs
        nu = ctx.nu
        t, C = len(ctx.pos_type_ids), ctx.layout.size
        self.nu, self.t, self.C = nu, t, C
        self.R = 1 + nu * t + nu
        if Z is None:
            Z = _state0(ctx, len(jobs))
        self.Z = Z
        self.Z2 = Z.reshape(len(jobs) * ctx.k, self.R, C)
        self.Zf = Z.reshape(len(jobs) * ctx.k * self.R, C)

    def apply_neg(self, row: int, hits) -> None:
        nu, t = self.nu, self.t
        for qi, rule in hits:
            if rule.kind == "leading":
                self.Z[row, qi, 0, :] = 0.0
            elif rule.kind == "trailing":
                self.Z[row, qi, 1 + nu * t:, :] = 0.0
            else:
                rows = (1 + np.arange(nu)[:, None] * t
                        + rule.before_local[None, :]).ravel()
                self.Z[row, qi, rows, :] = 0.0

    def assemble(self) -> np.ndarray:
        ctx = self.ctx
        J, k, nu = len(self.jobs), ctx.k, self.nu
        t, C = self.t, self.C
        M = np.zeros((J, k, C, C))
        M[:, :, ctx.layout.CONST, ctx.layout.CONST] = 1.0
        M[:, :, ctx.layout.GATE, :] = self.Z[:, :, 0]
        if nu and t:
            M[:, :, ctx.a_cols.reshape(-1), :] = self.Z[:, :, 1:1 + nu * t]
        if nu:
            M[:, :, ctx.rp_cols, :] = self.Z[:, :, 1 + nu * t:]
        return M


@dataclass
class _MergedBucket:
    """One flush-round stacked launch: same-shape graphlets of one level,
    concatenated across every pending pane of the flush.  Everything here
    except the coefficient arrays is structural, so the whole object is
    cached per (ctx, schedule combination) — see ``FoldExecutor._plan``."""

    B_local: int
    b: int                 # exact burst length (0 for d == 0: ragged)
    d: int
    used: tuple
    gof: np.ndarray        # [Nm] member -> group ordinal (bucket-local)
    gof_g: np.ndarray      # [Nm] member -> global S row (d == 0 fast path)
    ptm: np.ndarray        # [Nm, t] pt_mask rows (float64)
    start: np.ndarray      # [Nm] start flags (float64; d > 0 only)
    flat_gq: np.ndarray    # [Nm] state-row gather (into Z2)
    flat_sc: np.ndarray    # [Nm * n_used] arow scatter (into Zf)
    flat_er: tuple | None  # (rrow scatter rows, upd row mask) or None
    group_refs: list       # [(state row, step idx)] per group, in order
    div_g: np.ndarray | None      # [Ng, d] (d > 0 only)
    W_buf: np.ndarray | None = None  # reused [Nm, B_local, C] (d == 0)


@dataclass
class _Round:
    negs: list             # [(state row, hits)]
    buckets: list          # [_MergedBucket]


@dataclass
class _ScanProgram:
    """Device-resident operand set executing a whole *scannable* flush plan
    as one ``jax.lax.scan`` launch (see :func:`repro.kernels.ops
    .fold_rounds_scan` for the operand semantics).  Everything here but the
    per-flush ``S`` block is structural, so it is built once per flush plan
    and stays on device across flushes."""

    Z0: object             # [J*k*R + 1, C] fresh state + scratch row
    PTM: object            # [rounds, NMAX, t]
    GQ: object             # [rounds, NMAX, R]
    SIDX: object           # [rounds, NMAX, n_used]
    SC: object             # [rounds, NMAX * n_used]
    ER: object             # [rounds, NMAX * n_used]
    nu: int
    t: int
    n_used: int
    J: int
    k: int
    R: int
    C: int


@dataclass
class _FlushPlan:
    """Cached merged fold plan of one (ctx, K-pane schedule combination).

    ``s_flat`` holds one ``[n_used, 1 + nu]`` row block per d == 0 graphlet
    of the whole flush; rows of trivial graphlets are pre-summed at build
    time (their count coefficients are the plan-cached injection rows), the
    rest are rewritten each flush by ``s_fill`` — one stacked column sum per
    distinct burst length across *all* rounds.

    A *scannable* plan (every round: no negation steps, exactly one d == 0
    bucket) additionally carries a compiled execution form: ``scan`` (device
    backends — the whole flush as one ``lax.scan`` launch) or ``fast``
    (numpy — the fused host round loop with one flush-wide ``S`` gather)."""

    rounds: list           # [_Round]
    s_flat: np.ndarray | None
    s_fill: list           # [(global ordinals, [(state row, step idx)])]
    scan: _ScanProgram | None = None
    fast: list | None = None      # [(merged bucket, S_all row offset)]
    fast_cat: np.ndarray | None = None   # concatenated gof_g of all rounds
    # fused form of ``s_fill``: (segment refs [(row, step, unit)], segment
    # start offsets, flat ordinals) — one concatenate + one reduceat per
    # flush instead of one stack + sum per distinct burst length
    s_fill_cat: tuple | None = None


class FoldExecutor:
    """Bucketed stacked finalize/fold for the pane pipeline.

    ``submit`` queues one (pane, component) finalize; ``flush`` folds the
    whole backlog level by level, one stacked launch set per shape bucket
    per round, and deposits each job's transfer matrices on ``job.M``.
    Results are bitwise identical to the sequential
    :meth:`PaneProcessor.finalize` replay (pinned by
    ``tests/test_fold_exec.py``).
    """

    def __init__(self, backend: str = "np", flush_plan_cache: int = 64,
                 obs=None):
        self.backend = backend
        self.flush_plan_cache = int(flush_plan_cache)
        self.obs = obs
        self._pending: list[FoldJob] = []
        self._plans: "OrderedDict[tuple, _FlushPlan]" = OrderedDict()
        self.flushes = 0
        self.launches = 0         # stacked group-fold launches (buckets)
        self.window_folds = 0     # stacked window-chain launches (buckets)
        # flush-plan LRU traffic (the RunStats plan-cache counters' twin)
        self.plan_hits = 0
        self.plan_misses = 0
        self.plan_evictions = 0

    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, proc, steps: list, jobs: list, stats,
               host=None) -> FoldJob:
        job = FoldJob(proc=proc, steps=steps, jobs=jobs, stats=stats,
                      host=host)
        self._pending.append(job)
        return job

    # -- schedule resolution (plan-cache aware) --

    @staticmethod
    def _schedule_for(job: FoldJob) -> FoldSchedule:
        host = job.host
        if host is not None and getattr(host, "fold_schedule", None) is not None:
            return host.fold_schedule
        sched = build_fold_schedule(job.proc.ctx, job.steps)
        if host is not None:
            host.fold_schedule = sched
        return sched

    # -- phase 3: the stacked finalize --

    def flush(self) -> None:
        jobs, self._pending = self._pending, []
        if not jobs:
            return
        self.flushes += 1
        l0 = self.launches
        with np.errstate(over="ignore", invalid="ignore"):
            self._flush(jobs)
        if self.obs is not None:
            self.obs.observe("fold_exec.launches_per_flush",
                             self.launches - l0, OCCUPANCY_BUCKETS)

    def _flush(self, jobs: list[FoldJob]) -> None:
        # group pending jobs by component context; each ctx group holds a
        # stacked state and its own merged flush plan
        by_ctx: dict[int, list[FoldJob]] = {}
        ctx_of: dict[int, object] = {}
        for j in jobs:
            cid = id(j.proc.ctx)
            by_ctx.setdefault(cid, []).append(j)
            ctx_of[cid] = j.proc.ctx

        for cid, cjobs in by_ctx.items():
            ctx = ctx_of[cid]
            fp = self._plan(cid, cjobs)
            # flush-global dynamic S fills: one stacked column sum per
            # distinct burst length across every round of the flush —
            # bitwise equal per slice to the per-group ``coef.sum(axis=0)``
            S_flat = fp.s_flat
            if fp.s_fill_cat is not None:
                # one gather + one segmented column sum for every dynamic
                # fill of the flush; each reduceat segment adds the same
                # rows in the same order as the per-group ``sum(axis=1)``
                refs, starts, ords = fp.s_fill_cat
                jb = cjobs
                cat = np.concatenate(
                    [jb[row].jobs[si][0].result if u == 0
                     else jb[row].jobs[si][1][u].result
                     for row, si, u in refs])
                S_flat[ords] = np.add.reduceat(cat, starts, axis=0)
            if fp.scan is not None:
                # device-resident warm path: the whole fold chain is one
                # lax.scan launch and one host sync, independent of depth
                st = self._run_scan(ctx, cjobs, fp, S_flat)
            else:
                st = _CtxState(ctx, cjobs)
                if fp.fast is not None:
                    self._run_fast(st, fp, S_flat)
                else:
                    for rd in fp.rounds:
                        for row, hits in rd.negs:
                            st.apply_neg(row, hits)
                        for mb in rd.buckets:
                            if mb.d:
                                self._fold_bucket_div(st, mb, cjobs)
                            else:
                                self._fold_bucket_fast(st, mb, S_flat)
            MJ = st.assemble()
            for row, j in enumerate(cjobs):
                j.M = MJ[row].copy()

    # -- flush-plan construction (cached per schedule combination) --

    def _plan(self, cid: int, cjobs: list[FoldJob]) -> _FlushPlan:
        scheds = [self._schedule_for(j) for j in cjobs]
        key = (cid,) + tuple(sc.serial for sc in scheds)
        fp = self._plans.get(key)
        if fp is not None:
            self.plan_hits += 1
            if self.obs is not None:
                self.obs.count("fold_exec.flush_plan.hits")
            self._plans.move_to_end(key)
            return fp
        self.plan_misses += 1
        if self.obs is not None:
            self.obs.count("fold_exec.flush_plan.misses")
        fp = self._build_plan(cjobs, scheds)
        self._plans[key] = fp
        while len(self._plans) > self.flush_plan_cache:
            self._plans.popitem(last=False)
            self.plan_evictions += 1
            if self.obs is not None:
                self.obs.count("fold_exec.flush_plan.evictions")
        return fp

    def _build_plan(self, cjobs: list[FoldJob],
                    scheds: list[FoldSchedule]) -> _FlushPlan:
        ctx = cjobs[0].proc.ctx
        n_levels = max((sc.n_levels for sc in scheds), default=0)
        rounds: list[_Round] = []
        s_rows: list = []        # per global d==0 group: None | static row
        s_dyn: dict[int, list] = {}   # burst length -> [(ord, ref)]
        for lv in range(n_levels):
            negs: list = []
            merged: dict[tuple, list] = {}
            for row, sc in enumerate(scheds):
                if lv >= sc.n_levels:
                    continue
                negs.extend((row, hits) for _i, hits in sc.neg[lv])
                for tpl in sc.buckets[lv]:
                    merged.setdefault(
                        (tpl.B_local, tpl.b if tpl.d else 0),
                        []).append((row, tpl, sc.used))
            rounds.append(_Round(
                negs=negs,
                buckets=[self._merge_bucket(ctx, cjobs, parts, s_rows, s_dyn)
                         for parts in merged.values()]))
        used = scheds[0].used if scheds else (0,)
        n_used = len(used)
        s_flat = None
        s_fill: list = []
        if s_rows:
            # flat [G * n_used, 1 + nu] layout: row g*n_used + pos holds
            # group g's column sums for used[pos]
            s_flat = np.empty((len(s_rows) * n_used, 1 + ctx.nu))
            for go, row in enumerate(s_rows):
                if row is not None:
                    s_flat[go * n_used:(go + 1) * n_used] = row
            # group the dynamic fills by (burst length, unit): each becomes
            # one flush-wide stacked column sum
            fill_refs: list = []
            fill_ords: list = []
            fill_lens: list = []
            for b, entries in s_dyn.items():
                ords = np.asarray([o for o, _ in entries], dtype=int)
                refs = [r for _, r in entries]
                for pos, u in enumerate(used):
                    s_fill.append((ords * n_used + pos, refs, u))
                    fill_refs.extend((row, si, u) for row, si in refs)
                    fill_ords.append(ords * n_used + pos)
                    fill_lens.extend([b] * len(refs))
        fp = _FlushPlan(rounds=rounds, s_flat=s_flat, s_fill=s_fill)
        if s_fill:
            starts = np.zeros(len(fill_lens), dtype=np.intp)
            np.cumsum(fill_lens[:-1], out=starts[1:])
            fp.s_fill_cat = (fill_refs, starts, np.concatenate(fill_ords))
        if self._scannable(ctx, fp):
            if self.backend != "np":
                fp.scan = self._build_scan(ctx, len(cjobs), fp)
            else:
                self._build_fast(fp)
        return fp

    @staticmethod
    def _scannable(ctx, fp: _FlushPlan) -> bool:
        """True when every round is exactly one d == 0 bucket and no
        negation steps — the shape :func:`ops.fold_rounds_scan` (and the
        fused numpy round loop) compiles to a single uniform program."""
        nu, t = ctx.nu, len(ctx.pos_type_ids)
        if not fp.rounds or fp.s_flat is None or not nu or not t:
            return False
        for rd in fp.rounds:
            if rd.negs or len(rd.buckets) != 1:
                return False
            mb = rd.buckets[0]
            if mb.d or mb.B_local != 1 + nu:
                return False
        return True

    def _build_scan(self, ctx, J: int, fp: _FlushPlan) -> _ScanProgram:
        """Pad every round's gather/scatter operands to a common lane count
        and park them on device.  Padded lanes read the scratch state row
        and the zero ``S`` row and scatter back to the scratch row, so any
        NaN/inf they produce (0 * inf from overflow-regime garbage) never
        reaches a real state row."""
        import jax

        nu, t, C = ctx.nu, len(ctx.pos_type_ids), ctx.layout.size
        k = ctx.k
        R = 1 + nu * t + nu
        n_used = len(fp.rounds[0].buckets[0].used)
        scratch = J * k * R
        n_s = fp.s_flat.shape[0]       # the appended zero S row's index
        nr = len(fp.rounds)
        nmax = max(len(rd.buckets[0].flat_gq) for rd in fp.rounds)
        GQ = np.full((nr, nmax, R), scratch, dtype=np.int32)
        PTM = np.zeros((nr, nmax, t))
        SIDX = np.full((nr, nmax, n_used), n_s, dtype=np.int32)
        SC = np.full((nr, nmax * n_used), scratch, dtype=np.int32)
        ER = np.full((nr, nmax * n_used), scratch, dtype=np.int32)
        ar = np.arange(R, dtype=np.int32)
        for r, rd in enumerate(fp.rounds):
            mb = rd.buckets[0]
            nm = len(mb.flat_gq)
            GQ[r, :nm] = mb.flat_gq[:, None].astype(np.int32) * R + ar
            PTM[r, :nm] = mb.ptm
            SIDX[r, :nm] = mb.gof_g.reshape(nm, n_used)
            SC[r, :nm * n_used] = mb.flat_sc
            if mb.flat_er is not None:
                rows, em = mb.flat_er
                if em is None:
                    ER[r, :nm * n_used] = rows
                else:
                    ER[r, :nm * n_used][em] = rows
        Z0 = np.concatenate([_state0(ctx, J).reshape(-1, C),
                             np.zeros((1, C))])
        dp = jax.device_put
        return _ScanProgram(Z0=dp(Z0), PTM=dp(PTM), GQ=dp(GQ),
                            SIDX=dp(SIDX), SC=dp(SC), ER=dp(ER),
                            nu=nu, t=t, n_used=n_used, J=J, k=k, R=R, C=C)

    @staticmethod
    def _build_fast(fp: _FlushPlan) -> None:
        """Numpy twin of the scan program: precompute each round's offset
        into one flush-wide ``S`` gather so the hot loop runs without
        per-round ``take`` calls or bucket dispatch."""
        rounds, off = [], 0
        for rd in fp.rounds:
            mb = rd.buckets[0]
            rounds.append((mb, off))
            off += len(mb.gof_g)
        fp.fast = rounds
        fp.fast_cat = np.concatenate([mb.gof_g for mb, _ in rounds])

    def _merge_bucket(self, ctx, cjobs: list[FoldJob], parts: list,
                      s_rows: list, s_dyn: dict) -> _MergedBucket:
        _row0, tpl0, used = parts[0]
        n_used = len(used)
        k, nu, t = ctx.k, ctx.nu, len(ctx.pos_type_ids)
        R = 1 + nu * t + nu
        jm_p, q_p, gof_p, el_p, ptm_p, start_p, end_p, div_p = \
            [], [], [], [], [], [], [], []
        group_refs: list = []
        g_off = 0
        for row, tpl, _ in parts:
            nm = len(tpl.q)
            jm_p.append(np.full(nm, row, dtype=int))
            q_p.append(tpl.q)
            gof_p.append(tpl.gof + g_off)
            el_p.append(tpl.el)
            ptm_p.append(tpl.ptm)
            start_p.append(tpl.start)
            end_p.append(tpl.end)
            if tpl.d:
                div_p.append(tpl.div)
            group_refs.extend((row, si) for si in tpl.steps)
            g_off += tpl.ng
        jm = np.concatenate(jm_p)
        q = np.concatenate(q_p)
        gof = np.concatenate(gof_p)
        el = np.concatenate(el_p)
        end = np.concatenate(end_p)
        u_arr = np.asarray(used, dtype=int)
        nm = len(q)
        # flat scatter indices into the fused state (member-major,
        # used-unit-minor — the accumulation order of the sequential replay)
        sqr = np.repeat(jm * k + q, n_used) * R
        su = np.tile(u_arr, nm)
        flat_sc = sqr + 1 + su * t + np.repeat(el, n_used)
        em = np.repeat(end, n_used)
        # em=None marks the common all-ends bucket (e.g. every member of a
        # Kleene end-type graphlet): the scatter reuses ``upd`` unsliced
        flat_er = None
        if em.any():
            flat_er = (sqr[em] + 1 + nu * t + su[em],
                       None if em.all() else em)

        # global S rows for the d == 0 fast path: trivial graphlets' count
        # coefficients are their cached injection rows, so their column sums
        # are pre-summed at build time; the rest register a dynamic fill.
        # ``gof_g`` expands to the member-by-unit row indices of ``s_flat``
        gof_g = gof
        if not tpl0.d:
            base = len(s_rows)
            gof_g = ((gof + base)[:, None] * n_used
                     + np.arange(n_used)).ravel()
            for go, (row, si) in enumerate(group_refs):
                step = cjobs[row].steps[si]
                if step.trivial and n_used == 1:
                    s_rows.append(step.base_c.sum(axis=0)[None])
                else:
                    s_rows.append(None)
                    s_dyn.setdefault(step.b, []).append(
                        (base + go, (row, si)))
        return _MergedBucket(
            B_local=tpl0.B_local, b=tpl0.b, d=tpl0.d, used=used,
            gof=gof, gof_g=gof_g,
            ptm=np.ascontiguousarray(np.concatenate(ptm_p)),
            start=np.concatenate(start_p),
            flat_gq=jm * k + q, flat_sc=flat_sc, flat_er=flat_er,
            group_refs=group_refs,
            div_g=(np.concatenate(div_p, axis=0) if div_p else None))

    # -- compiled execution forms for scannable plans --

    def _run_scan(self, ctx, cjobs: list[FoldJob], fp: _FlushPlan,
                  S_flat: np.ndarray) -> _CtxState:
        """Run the whole flush as one device launch + one host sync.

        Only the per-flush ``S`` block crosses to the device; every index
        operand and the fresh state live there already.  Counts as a single
        stacked launch however deep the fold chain is."""
        sp = fp.scan
        self.launches += 1
        if self.obs is not None:
            self.obs.count("fold_exec.scan_launches")
            self.obs.observe("fold_exec.bucket_occupancy",
                             max(len(rd.buckets[0].flat_gq)
                                 for rd in fp.rounds), OCCUPANCY_BUCKETS)
        S_pad = np.concatenate([S_flat, np.zeros((1, S_flat.shape[1]))])
        Zf = ops.fold_rounds_scan(sp.Z0, S_pad, sp.PTM, sp.GQ, sp.SIDX,
                                  sp.SC, sp.ER, nu=sp.nu, t=sp.t,
                                  n_used=sp.n_used)
        Z = np.asarray(Zf)[:-1].reshape(sp.J, sp.k, sp.R, sp.C)
        return _CtxState(ctx, cjobs, Z=Z)

    def _run_fast(self, st: _CtxState, fp: _FlushPlan,
                  S_flat: np.ndarray) -> None:
        """Fused host round loop for scannable plans: one flush-wide ``S``
        gather, then per round the same three stacked ops as
        :meth:`_fold_bucket_fast` (bitwise identical — each round's ``S``
        slice holds the very rows the per-round ``take`` would copy)."""
        nu, t, C = st.nu, st.t, st.C
        Z2, Zf = st.Z2, st.Zf
        obs = self.obs
        nut = 1 + nu * t
        S_all = S_flat.take(fp.fast_cat, axis=0)
        for mb, off in fp.fast:
            self.launches += 1
            flat_gq = mb.flat_gq
            nm = len(flat_gq)
            if obs is not None:
                obs.observe("fold_exec.bucket_occupancy", nm,
                            OCCUPANCY_BUCKETS)
            n_used = len(mb.used)
            zm = Z2.take(flat_gq, axis=0)
            W = mb.W_buf
            if W is None:
                W = mb.W_buf = np.empty((nm, mb.B_local, C))
            W[:, 0] = zm[:, 0]
            W[:, 1:1 + nu] = np.matmul(
                mb.ptm[:, None, None, :],
                zm[:, 1:nut].reshape(nm, nu, t, C))[:, :, 0, :]
            S_m = S_all[off:off + nm * n_used].reshape(nm, n_used,
                                                       mb.B_local)
            upd = np.matmul(S_m, W).reshape(nm * n_used, C)
            Zf[mb.flat_sc] += upd
            if mb.flat_er is not None:
                rows, em = mb.flat_er
                Zf[rows] += upd if em is None else upd[em]

    # -- the two bucket kernels --

    def _fold_bucket_fast(self, st: _CtxState, mb: _MergedBucket,
                          S_flat: np.ndarray) -> None:
        """d == 0: no event-level snapshots — the fold reads coefficients
        only through their column sums (already seeded in ``S_flat``), so
        one gather, two batched matmuls and one scatter fold the bucket."""
        self.launches += 1
        if self.obs is not None:
            self.obs.observe("fold_exec.bucket_occupancy", len(mb.flat_gq),
                             OCCUPANCY_BUCKETS)
        nu, t, C = st.nu, st.t, st.C
        n_used = len(mb.used)
        zm = st.Z2.take(mb.flat_gq, axis=0)        # [Nm, R, C]
        nm = len(mb.flat_gq)
        W = mb.W_buf
        if W is None:
            # d == 0 means B_local == 1 + nu: every row is overwritten
            # below, so the buffer needs no zeroing and is reused
            W = mb.W_buf = np.empty((nm, mb.B_local, C))
        W[:, 0] = zm[:, 0]
        if nu:
            W[:, 1:1 + nu] = np.matmul(
                mb.ptm[:, None, None, :],
                zm[:, 1:1 + nu * t].reshape(nm, nu, t, C))[:, :, 0, :]
        S_m = S_flat.take(mb.gof_g, axis=0).reshape(nm, n_used, mb.B_local)
        upd = np.matmul(S_m, W).reshape(nm * n_used, C)
        # level construction guarantees the scatter targets are distinct:
        # plain fancy-indexed accumulation, no np.add.at needed
        st.Zf[mb.flat_sc] += upd
        if mb.flat_er is not None:
            rows, em = mb.flat_er
            st.Zf[rows] += upd if em is None else upd[em]

    def _fold_bucket_div(self, st: _CtxState, mb: _MergedBucket,
                         cjobs: list[FoldJob]) -> None:
        """d > 0: event-level snapshot fills — exact burst length per
        bucket, per-event arrays stacked across members."""
        self.launches += 1
        if self.obs is not None:
            self.obs.observe("fold_exec.bucket_occupancy", len(mb.flat_gq),
                             OCCUPANCY_BUCKETS)
        nu, t, C = st.nu, st.t, st.C
        used, n_used = mb.used, len(mb.used)

        # fetch per-group coefficients and seed S with the per-group column
        # sums, in group order
        coef_stacks: dict[int, list] = {u: [] for u in used}
        S_rows: list[np.ndarray] = []
        steps_g = []
        for row, si in mb.group_refs:
            cjob, sjobs = cjobs[row].jobs[si]
            steps_g.append(cjobs[row].steps[si])
            coefs = {0: cjob.result}
            for ui in used[1:]:
                coefs[ui] = sjobs[ui].result
            for u in used:
                coef_stacks[u].append(coefs[u])
            if n_used > 1:
                S_rows.append(np.stack(
                    [coefs[0].sum(axis=0)]
                    + [coefs[ui].sum(axis=0) for ui in used[1:]]))
            else:
                S_rows.append(coefs[0].sum(axis=0)[None])

        zm = st.Z2.take(mb.flat_gq, axis=0)
        nm = len(mb.flat_gq)
        gate_m = zm[:, 0]
        W = np.zeros((nm, mb.B_local, C))
        W[:, 0] = gate_m
        if nu:
            W[:, 1:1 + nu] = np.matmul(
                mb.ptm[:, None, None, :],
                zm[:, 1:1 + nu * t].reshape(nm, nu, t, C))[:, :, 0, :]

        self._fill_snapshots(st.ctx, W, gate_m, used, mb.b, mb.d,
                             div_g=mb.div_g, gof=mb.gof, steps_g=steps_g,
                             coef_stacks=coef_stacks, start_m=mb.start)

        S_m = np.stack(S_rows)[mb.gof]
        upd = np.matmul(S_m, W).reshape(nm * n_used, C)
        st.Zf[mb.flat_sc] += upd
        if mb.flat_er is not None:
            rows, em = mb.flat_er
            st.Zf[rows] += upd if em is None else upd[em]

    def _fill_snapshots(self, ctx, W, gate_m, used, b, d, *, div_g, gof,
                        steps_g, coef_stacks, start_m) -> None:
        """Stacked twin of the event-snapshot fill loop: all bucket members
        advance one divergent row per iteration; ``P[u]`` carries the rank-1
        updates exactly as the sequential replay does."""
        nu, C = ctx.nu, ctx.layout.size
        nm = len(gof)
        mv_m = np.stack([s.mvec[i] for s, i in self._members(steps_g, gof)])
        adj = np.repeat(np.tril(np.ones((b, b), dtype=bool), k=-1)[None],
                        nm, axis=0)
        for m, (s, i) in enumerate(self._members(steps_g, gof)):
            e = s.epm[i]
            if e is not None:
                adj[m] &= e
        adj &= mv_m[:, None, :]

        coef_m = {u: np.stack(coef_stacks[u])[gof] for u in used}
        P = {u: np.matmul(coef_m[u], W) for u in used}

        # per-(group, div row, sum unit) injection values from the fresh
        # attribute data (v term; None when the unit's type differs)
        n_sum = len(used) - 1
        ng = len(steps_g)
        if n_sum:
            vhas = np.zeros((ng, n_sum), dtype=bool)
            vv = np.zeros((ng, d, n_sum))
            for g, s in enumerate(steps_g):
                su = dict(s.sum_units)
                for pos, ui in enumerate(used[1:]):
                    vals = su[ui]
                    if vals is not None:
                        vhas[g, pos] = True
                        vv[g, :, pos] = vals[div_g[g]]
            vh_m = vhas[gof]
            vv_m = vv[gof]

        ar = np.arange(nm)
        for r in range(d):
            i_m = div_g[gof, r]
            rowf = adj[ar, i_m].astype(float)
            mfl = mv_m[ar, i_m]
            zc = 1 + nu + r * nu
            f_c = (start_m[:, None] * gate_m + W[:, 1]
                   + np.matmul(rowf[:, None, :], P[0])[:, 0])
            f_c = np.where(mfl[:, None], f_c, 0.0)
            self._fill(W, P, coef_m, used, zc, f_c)
            for pos, ui in enumerate(used[1:]):
                f_s = (W[:, 1 + ui]
                       + np.matmul(rowf[:, None, :], P[ui])[:, 0])
                hasv = vh_m[:, pos]
                if hasv.any():
                    f_s[hasv] = (f_s[hasv]
                                 + vv_m[hasv, r, pos, None] * f_c[hasv])
                f_s = np.where(mfl[:, None], f_s, 0.0)
                self._fill(W, P, coef_m, used, zc + ui, f_s)

    @staticmethod
    def _members(steps_g, gof):
        """Iterate (group step, member row within the step) in member order."""
        seen: dict[int, int] = {}
        for g in gof:
            g = int(g)
            i = seen.get(g, 0)
            seen[g] = i + 1
            yield steps_g[g], i

    @staticmethod
    def _fill(W, P, coef_m, used, zcol: int, f: np.ndarray) -> None:
        W[:, zcol] = f
        for u in used:
            col = coef_m[u][:, :, zcol]
            sel = col.any(axis=1)
            if sel.any():
                P[u][sel] += col[sel][:, :, None] * f[sel][:, None, :]

    # -- phase 4: stacked window folds (fold_panes moved behind the executor)

    def fold_windows(self, folds: list) -> list[np.ndarray]:
        """Batched twin of :func:`repro.core.engine.fold_panes`.

        ``folds`` is a list of ``(u0, [M, ...])`` window chains; returns the
        folded state per chain, each bitwise equal to the per-window fold.
        Chains bucket by (length, width) and fold through
        ``ops.fold_stacked`` — one launch set per bucket, one host sync for
        the whole batch on device backends.
        """
        out: list = [None] * len(folds)
        buckets: dict[tuple, list[int]] = {}
        for i, (u0, Ms) in enumerate(folds):
            if not len(Ms):
                out[i] = u0
                continue
            buckets.setdefault((len(Ms), len(u0)), []).append(i)
        raw: list[tuple[list[int], object]] = []
        for idxs in buckets.values():
            self.window_folds += 1
            U0 = np.stack([folds[i][0] for i in idxs])
            Mstack = np.stack([np.stack(folds[i][1]) for i in idxs])
            raw.append((idxs, ops.fold_stacked(U0, Mstack,
                                               backend=self.backend)))
        for (idxs, _u), host in zip(raw,
                                    ops.device_get_all([u for _, u in raw])):
            for r, i in enumerate(idxs):
                out[i] = host[r]
        return out
