"""Event stream data model.

Events are kept in struct-of-arrays form (``EventBatch``) so that panes can be
processed as dense tensors on the accelerator: integer type ids, integer
timestamps (ticks), a float attribute matrix, and an integer group key.

The paper's executor partitions the stream (i) by the values of the grouping
attributes and (ii) into panes whose size is the gcd of all window sizes and
slides (Sec. 3.1).  Both operations live here.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "StreamSchema",
    "EventBatch",
    "pane_size_for",
    "split_panes",
]


@dataclass(frozen=True)
class StreamSchema:
    """Names of event types and attributes for a stream.

    ``types[i]`` has type id ``i``; ``attrs[j]`` is column ``j`` of
    ``EventBatch.attrs``.
    """

    types: tuple[str, ...]
    attrs: tuple[str, ...] = ()

    def __post_init__(self) -> None:
        if len(set(self.types)) != len(self.types):
            raise ValueError("duplicate event type names")
        if len(set(self.attrs)) != len(self.attrs):
            raise ValueError("duplicate attribute names")

    @property
    def n_types(self) -> int:
        return len(self.types)

    def type_id(self, name: str) -> int:
        try:
            return self.types.index(name)
        except ValueError:
            raise KeyError(f"unknown event type {name!r}; have {self.types}") from None

    def attr_col(self, name: str) -> int:
        try:
            return self.attrs.index(name)
        except ValueError:
            raise KeyError(f"unknown attribute {name!r}; have {self.attrs}") from None


@dataclass
class EventBatch:
    """A time-ordered batch of events (one group partition, any time span).

    type_id : int32[n]      index into schema.types
    time    : int64[n]      non-decreasing timestamps in ticks
    attrs   : float64[n, a] attribute values (column per schema.attrs entry)
    group   : int64[n]      group partition key (constant within a partition)
    seq     : int64[n]|None provenance: producer sequence / original arrival
                            index.  Optional; carried so that out-of-order
                            streams can be merged back into a *total* order
                            (ties on ``time`` break by ``seq``, see
                            :meth:`merge`).  The engine ignores it.

    Direct construction still requires time order; real traces with
    disordered arrival go through :meth:`from_unsorted`.
    """

    schema: StreamSchema
    type_id: np.ndarray
    time: np.ndarray
    attrs: np.ndarray
    group: np.ndarray = field(default=None)  # type: ignore[assignment]
    seq: np.ndarray | None = field(default=None)

    def __post_init__(self) -> None:
        n = len(self.type_id)
        self.type_id = np.asarray(self.type_id, dtype=np.int32)
        self.time = np.asarray(self.time, dtype=np.int64)
        n_attrs = max(1, len(self.schema.attrs))
        if self.attrs is None or np.size(self.attrs) == 0:
            self.attrs = np.zeros((n, n_attrs), dtype=np.float64)
        else:
            self.attrs = np.asarray(self.attrs, dtype=np.float64).reshape(n, -1)
        if self.group is None:
            self.group = np.zeros(n, dtype=np.int64)
        self.group = np.asarray(self.group, dtype=np.int64)
        if len(self.time) != n or len(self.attrs) != n or len(self.group) != n:
            raise ValueError("EventBatch arrays must share their leading dim")
        if self.seq is not None:
            self.seq = np.asarray(self.seq, dtype=np.int64)
            if len(self.seq) != n:
                raise ValueError("EventBatch arrays must share their leading dim")
        if n > 1 and np.any(np.diff(self.time) < 0):
            raise ValueError("events must be time-ordered "
                             "(use EventBatch.from_unsorted for raw traces)")

    def __len__(self) -> int:
        return len(self.type_id)

    def attr(self, name: str) -> np.ndarray:
        return self.attrs[:, self.schema.attr_col(name)]

    def select(self, idx: np.ndarray) -> "EventBatch":
        return EventBatch(
            schema=self.schema,
            type_id=self.type_id[idx],
            time=self.time[idx],
            attrs=self.attrs[idx],
            group=self.group[idx],
            seq=None if self.seq is None else self.seq[idx],
        )

    def time_slice(self, t0: int, t1: int) -> "EventBatch":
        """Events with t0 <= time < t1 (events are time sorted)."""
        lo = int(np.searchsorted(self.time, t0, side="left"))
        hi = int(np.searchsorted(self.time, t1, side="left"))
        return self.select(np.arange(lo, hi))

    @staticmethod
    def from_unsorted(schema: StreamSchema, type_id, time, attrs=None,
                      group=None, seq=None) -> "EventBatch":
        """Build a batch from arrays in *arrival* order (any time order).

        Events are stable-sorted by timestamp, so equal-timestamp events keep
        their relative arrival order.  ``seq`` records provenance: when not
        given, it is stamped with the original arrival index (position in the
        input arrays), so callers can always recover where a sorted event came
        from; producers that stamp their own sequence ids pass them through.
        """
        time = np.asarray(time, dtype=np.int64)
        n = len(time)
        seq = (np.arange(n, dtype=np.int64) if seq is None
               else np.asarray(seq, dtype=np.int64))
        order = np.argsort(time, kind="stable")
        if attrs is not None and np.size(attrs) == 0:
            attrs = None
        attrs = None if attrs is None else np.asarray(
            attrs, dtype=np.float64).reshape(n, -1)
        return EventBatch(
            schema=schema,
            type_id=np.asarray(type_id, dtype=np.int32)[order],
            time=time[order],
            attrs=None if attrs is None else attrs[order],
            group=(None if group is None
                   else np.asarray(group, dtype=np.int64)[order]),
            seq=seq[order],
        )

    @staticmethod
    def concat(batches: list["EventBatch"]) -> "EventBatch":
        if not batches:
            raise ValueError("need at least one batch")
        schema = batches[0].schema
        # provenance only survives when every part carries it; a partial
        # concat would silently misorder merge() ties
        seqs = [b.seq for b in batches]
        return EventBatch(
            schema=schema,
            type_id=np.concatenate([b.type_id for b in batches]),
            time=np.concatenate([b.time for b in batches]),
            attrs=np.concatenate([b.attrs for b in batches]),
            group=np.concatenate([b.group for b in batches]),
            seq=(np.concatenate(seqs) if all(s is not None for s in seqs)
                 else None),
        )

    @staticmethod
    def merge(batches: list["EventBatch"]) -> "EventBatch":
        """Merge time-sorted batches into one total order.

        Unlike :meth:`concat`, the inputs need not be globally ordered
        relative to each other.  Ties on ``time`` break by ``seq`` when
        every batch carries it (the producer's total order), else by batch
        order then position (stable) — the contract the event-time layer
        relies on to reconstruct the original stream from disordered
        arrivals.
        """
        if not batches:
            raise ValueError("need at least one batch")
        time = np.concatenate([b.time for b in batches])
        seqs = [b.seq for b in batches]
        seq = (np.concatenate(seqs) if all(s is not None for s in seqs)
               else None)
        if seq is not None:
            order = np.lexsort((seq, time))
        else:
            order = np.argsort(time, kind="stable")
        return EventBatch(
            schema=batches[0].schema,
            type_id=np.concatenate([b.type_id for b in batches])[order],
            time=time[order],
            attrs=np.concatenate([b.attrs for b in batches])[order],
            group=np.concatenate([b.group for b in batches])[order],
            seq=None if seq is None else seq[order],
        )

    def partition_by_group(self) -> dict[int, "EventBatch"]:
        out: dict[int, EventBatch] = {}
        for g in np.unique(self.group):
            out[int(g)] = self.select(np.nonzero(self.group == g)[0])
        return out


def pane_size_for(windows: list[tuple[int, int]]) -> int:
    """gcd of all window sizes and slides (Sec. 3.1)."""
    vals: list[int] = []
    for within, slide in windows:
        if within <= 0 or slide <= 0:
            raise ValueError("window/slide must be positive")
        vals.extend([within, slide])
    g = 0
    for v in vals:
        g = math.gcd(g, v)
    return max(1, g)


def split_panes(batch: EventBatch, pane: int, t_start: int, t_end: int):
    """Yield ``(pane_start_time, EventBatch)`` for [t_start, t_end) in steps."""
    for t0 in range(t_start, t_end, pane):
        yield t0, batch.time_slice(t0, t0 + pane)
