"""Dynamic sharing benefit model (paper Sec. 4.1, Defs. 11 & 12).

The technical report prints two variants of the model; its worked examples
(Eq. 8-10, Fig. 6) follow the Def. 11 form with the type count ``t``, so that
is the default (``benefit_v1``).  ``benefit_v2`` adds the ``log2(g)`` graphlet
index-probe terms of Def. 12.

All quantities are per burst of ``b`` events of type E (Def. 10):
    b    events in the burst
    n    events against which new intermediate aggregates propagate
    s_c  snapshots created from this burst
    s_p  snapshots propagated through the graphlet
    k    queries in Q_E
    g    events in the (shared) graphlet
    t    event types per query (v1) / p predecessor types per type (v2)
"""

from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = ["BurstCost", "shared_cost_v1", "nonshared_cost_v1", "benefit_v1",
           "shared_cost_v2", "nonshared_cost_v2", "benefit_v2"]


@dataclass(frozen=True)
class BurstCost:
    shared: float
    nonshared: float

    @property
    def benefit(self) -> float:
        return self.nonshared - self.shared


# ---- Def. 11 (Eq. 6): the variant behind the paper's worked examples ----

def shared_cost_v1(b: int, n: int, s_p: int, s_c: int, k: int, g: int, t: int) -> float:
    return b * n * s_p + s_c * k * g * t


def nonshared_cost_v1(b: int, n: int, k: int) -> float:
    return k * b * n


def benefit_v1(b: int, n: int, s_p: int, s_c: int, k: int, g: int, t: int) -> BurstCost:
    return BurstCost(shared_cost_v1(b, n, s_p, s_c, k, g, t),
                     nonshared_cost_v1(b, n, k))


# ---- Def. 12 (Eq. 7): adds log2(g) graphlet index probes ----

def shared_cost_v2(b: int, n: int, s_p: int, s_c: int, k: int, g: int, p: int) -> float:
    lg = math.log2(g) if g > 1 else 0.0
    return s_c * k * g * p + b * (lg + n * s_p)


def nonshared_cost_v2(b: int, n: int, k: int, g: int) -> float:
    lg = math.log2(g) if g > 1 else 0.0
    return k * b * (lg + n)


def benefit_v2(b: int, n: int, s_p: int, s_c: int, k: int, g: int, p: int) -> BurstCost:
    return BurstCost(shared_cost_v2(b, n, s_p, s_c, k, g, p),
                     nonshared_cost_v2(b, n, k, g))
