"""Event trend aggregation queries (paper Def. 2) and workloads.

A query has: RETURN aggregates, PATTERN (Kleene pattern), WHERE predicates,
GROUP-BY attributes, WITHIN/SLIDE window.  Predicates come in two flavours:

* per-event predicates (``Pred``) keyed by event type — e.g. ``R.type = Pool``
  becomes ``{"Request": [Pred("rtype", "==", POOL)]}``;
* same-type *edge* predicates (``EdgePred``) between an event and its
  predecessor inside a Kleene run — the mechanism behind the paper's
  event-level snapshots (Def. 9 / Fig. 5(c)).

Cross-event equality constraints such as ``[driver, rider]`` are realised by
stream partitioning (Sec. 3.1): the executor partitions by the group-by and
equality attributes, so trends never span partitions.
"""

from __future__ import annotations

import operator
from dataclasses import dataclass, field

import numpy as np

from .events import StreamSchema
from .pattern import And, Or, Pattern, PatternInfo, analyze

__all__ = [
    "Pred", "EdgePred", "Agg", "AggKind",
    "count_star", "count_type", "agg_sum", "agg_avg", "agg_min", "agg_max",
    "Query", "AtomicQuery", "Workload",
]

_OPS = {
    "<": operator.lt, "<=": operator.le, ">": operator.gt,
    ">=": operator.ge, "==": operator.eq, "!=": operator.ne,
}


@dataclass(frozen=True)
class Pred:
    """Per-event predicate ``attr OP value``."""

    attr: str
    op: str
    value: float

    def eval(self, attrs: np.ndarray, schema: StreamSchema) -> np.ndarray:
        col = attrs[:, schema.attr_col(self.attr)]
        return _OPS[self.op](col, self.value)


@dataclass(frozen=True)
class EdgePred:
    """Edge predicate between a predecessor j and successor i of one type:
    ``pred.attr OP succ.attr`` must hold for the edge (j, i) to exist."""

    attr: str
    op: str

    def eval_pairs(self, pred_vals: np.ndarray, succ_vals: np.ndarray) -> np.ndarray:
        """[n_pred, n_succ] boolean mask."""
        return _OPS[self.op](pred_vals[:, None], succ_vals[None, :])


class AggKind:
    COUNT_STAR = "COUNT(*)"
    COUNT_TYPE = "COUNT(E)"
    SUM = "SUM"
    MIN = "MIN"
    MAX = "MAX"
    AVG = "AVG"


@dataclass(frozen=True)
class Agg:
    kind: str
    type_name: str | None = None
    attr: str | None = None

    def __repr__(self) -> str:
        if self.kind == AggKind.COUNT_STAR:
            return "COUNT(*)"
        if self.kind == AggKind.COUNT_TYPE:
            return f"COUNT({self.type_name})"
        return f"{self.kind}({self.type_name}.{self.attr})"

    def units(self) -> frozenset[tuple]:
        """Linear propagation units this aggregate needs.

        ``("count",)`` is the trend-count unit (Eq. 1); ``("sum", E, attr)``
        accumulates attr over type-E events in trends; MIN/MAX use a separate
        idempotent path."""
        if self.kind == AggKind.COUNT_STAR:
            return frozenset({("count",)})
        if self.kind == AggKind.COUNT_TYPE:
            return frozenset({("count",), ("sum", self.type_name, None)})
        if self.kind == AggKind.SUM:
            return frozenset({("count",), ("sum", self.type_name, self.attr)})
        if self.kind == AggKind.AVG:
            return frozenset({("count",), ("sum", self.type_name, self.attr),
                              ("sum", self.type_name, None)})
        if self.kind in (AggKind.MIN, AggKind.MAX):
            return frozenset({("count",), ("minmax", self.kind, self.type_name, self.attr)})
        raise ValueError(self.kind)


def count_star() -> Agg:
    return Agg(AggKind.COUNT_STAR)


def count_type(type_name: str) -> Agg:
    return Agg(AggKind.COUNT_TYPE, type_name)


def agg_sum(type_name: str, attr: str) -> Agg:
    return Agg(AggKind.SUM, type_name, attr)


def agg_avg(type_name: str, attr: str) -> Agg:
    return Agg(AggKind.AVG, type_name, attr)


def agg_min(type_name: str, attr: str) -> Agg:
    return Agg(AggKind.MIN, type_name, attr)


def agg_max(type_name: str, attr: str) -> Agg:
    return Agg(AggKind.MAX, type_name, attr)


@dataclass(frozen=True)
class AtomicQuery:
    """A query whose pattern is Or/And-free: directly executable."""

    name: str
    pattern: Pattern
    info: PatternInfo
    aggs: tuple[Agg, ...]
    preds: tuple[tuple[str, tuple[Pred, ...]], ...]  # (type_name -> preds), hashable
    edge_preds: tuple[tuple[str, tuple[EdgePred, ...]], ...]
    within: int
    slide: int
    group_by: tuple[str, ...]

    def preds_for(self, type_name: str) -> tuple[Pred, ...]:
        for t, ps in self.preds:
            if t == type_name:
                return ps
        return ()

    def edge_preds_for(self, type_name: str) -> tuple[EdgePred, ...]:
        for t, ps in self.edge_preds:
            if t == type_name:
                return ps
        return ()

    @property
    def units(self) -> tuple[tuple, ...]:
        out: set[tuple] = set()
        for a in self.aggs:
            out |= a.units()
        # deterministic order: count first, then sums, then minmax
        return tuple(sorted(out, key=lambda u: (u[0] != "count",
                                                tuple(str(x) for x in u))))


@dataclass(frozen=True)
class Query:
    """User-facing query; ``expand()`` resolves top-level Or/And (Sec. 5)."""

    name: str
    pattern: Pattern
    aggs: tuple[Agg, ...] = (Agg(AggKind.COUNT_STAR),)
    preds: dict | None = None            # type_name -> list[Pred]
    edge_preds: dict | None = None       # type_name -> list[EdgePred]
    within: int = 10
    slide: int = 10
    group_by: tuple[str, ...] = ()

    def _freeze_preds(self) -> tuple:
        d = self.preds or {}
        return tuple(sorted((t, tuple(ps)) for t, ps in d.items()))

    def _freeze_edge_preds(self) -> tuple:
        d = self.edge_preds or {}
        return tuple(sorted((t, tuple(ps)) for t, ps in d.items()))

    def _atomic(self, name: str, pattern: Pattern) -> AtomicQuery:
        return AtomicQuery(
            name=name,
            pattern=pattern,
            info=analyze(pattern),
            aggs=tuple(self.aggs),
            preds=self._freeze_preds(),
            edge_preds=self._freeze_edge_preds(),
            within=self.within,
            slide=self.slide,
            group_by=tuple(self.group_by),
        )

    def expand(self) -> tuple[list[AtomicQuery], "_Combine | None"]:
        """Atomic sub-queries plus the result-combination rule (Sec. 5).

        Disjunction:  COUNT(P1 v P2) = C1' + C2' + C12 where Ci' excludes
        doubly-matched trends.  Conjunction: pairs formula.  ``C12`` (trends
        matched by both) is supported when the sub-patterns' positive type
        sets are disjoint (then C12 = 0) or the patterns are identical
        (C12 = C1); the general intersection pattern is out of scope, as in
        the paper which defines it only abstractly.
        """
        p = self.pattern
        if isinstance(p, (Or, And)):
            left, right = p.left, p.right
            li, ri = analyze(left), analyze(right)
            if left == right:
                mode = "identical"
            elif not (li.types & ri.types):
                mode = "disjoint"
            else:
                raise NotImplementedError(
                    "Or/And over overlapping, non-identical patterns needs the "
                    "intersection pattern P_{1,2}, which the paper defines only "
                    "abstractly; use disjoint or identical sub-patterns"
                )
            q1 = self._atomic(self.name + "/L", left)
            q2 = self._atomic(self.name + "/R", right)
            return [q1, q2], _Combine("or" if isinstance(p, Or) else "and", mode)
        return [self._atomic(self.name, p)], None


@dataclass(frozen=True)
class _Combine:
    op: str       # "or" | "and"
    mode: str     # "disjoint" | "identical"

    def combine_counts(self, c1: float, c2: float) -> float:
        if self.mode == "identical":
            c12, c1x, c2x = c1, 0.0, 0.0
        else:
            c12, c1x, c2x = 0.0, c1, c2
        if self.op == "or":
            return c1x + c2x + c12
        # conjunction (Sec. 5): pairs of distinct trends
        return c1x * c2x + c1x * c12 + c2x * c12 + c12 * (c12 - 1) / 2


def _units_compatible(q1: AtomicQuery, q2: AtomicQuery) -> bool:
    """Permissive Def. 5 aggregate rule: queries share the units they have in
    common; the trend-count unit is common to every aggregate, so aggregation
    functions never block sharing under ``mode='units'``."""
    return True


def _paper_aggs_compatible(q1: AtomicQuery, q2: AtomicQuery) -> bool:
    """Strict Def. 5: COUNT(*)/MIN/MAX only share with the same aggregate;
    AVG shares with SUM / COUNT(E) over the same type+attr."""

    def norm(aggs: tuple[Agg, ...]) -> set:
        out = set()
        for a in aggs:
            if a.kind == AggKind.AVG:
                out.add((AggKind.SUM, a.type_name, a.attr))
                out.add((AggKind.COUNT_TYPE, a.type_name, None))
            else:
                out.add((a.kind, a.type_name, a.attr))
        return out

    return bool(norm(q1.aggs) & norm(q2.aggs))


class Workload:
    """A static workload of trend aggregation queries over one stream schema."""

    def __init__(self, schema: StreamSchema, queries: list[Query],
                 sharable_mode: str = "units"):
        self.schema = schema
        self.queries = list(queries)
        self.sharable_mode = sharable_mode
        self.atomic: list[AtomicQuery] = []
        self.combines: list[tuple[str, list[int], _Combine | None]] = []
        for q in self.queries:
            subs, comb = q.expand()
            idxs = []
            for sq in subs:
                idxs.append(len(self.atomic))
                self.atomic.append(sq)
            self.combines.append((q.name, idxs, comb))
        self._validate()

    def _validate(self) -> None:
        for q in self.atomic:
            for t in q.info.types | {n.neg_type for n in q.info.negatives}:
                self.schema.type_id(t)  # raises on unknown
            for _, ps in q.preds:
                for p in ps:
                    self.schema.attr_col(p.attr)

    # ---- sharing structure (Defs. 4 & 5) ----

    def sharable_kleene(self, e_type: str) -> list[int]:
        """Indices of atomic queries for which ``e_type+`` is shareable."""
        return [i for i, q in enumerate(self.atomic) if e_type in q.info.kleene_types]

    def queries_sharable(self, i: int, j: int) -> bool:
        q1, q2 = self.atomic[i], self.atomic[j]
        if not (q1.info.kleene_types & q2.info.kleene_types):
            return False
        if tuple(q1.group_by) != tuple(q2.group_by):
            return False
        if self.sharable_mode == "paper" and not _paper_aggs_compatible(q1, q2):
            return False
        return True  # sliding windows over one stream always overlap

    def sharable_components(self) -> list[list[int]]:
        """Connected components of the sharable relation: each component is
        processed by one executor context."""
        n = len(self.atomic)
        parent = list(range(n))

        def find(x: int) -> int:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for i in range(n):
            for j in range(i + 1, n):
                if self.queries_sharable(i, j):
                    parent[find(i)] = find(j)
        comps: dict[int, list[int]] = {}
        for i in range(n):
            comps.setdefault(find(i), []).append(i)
        return sorted(comps.values())

    @property
    def windows(self) -> list[tuple[int, int]]:
        return [(q.within, q.slide) for q in self.atomic]
