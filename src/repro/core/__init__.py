"""HAMLET core: the paper's contribution — shared online event trend
aggregation with dynamic sharing decisions — implemented in JAX."""
