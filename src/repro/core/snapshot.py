"""Snapshot-basis bookkeeping for shared trend aggregation (paper Sec. 3.3).

Intermediate trend aggregates inside a pane are maintained as *linear
expressions* over a basis of snapshots.  Each basis entry carries, per query,
a *value functional*: a row vector over the pane-entry state channels that
yields the snapshot's value for that query when applied to the query's state
vector ``u`` (see DESIGN.md §2 and engine.py).

Channels of the per-(query, window-instance) state vector ``u``:

    0: const      always 1
    1: gate       1 until a leading-NOT negative match (then 0)
    A(u, E)       running sum, per linear unit u and positive type E, of the
                  unit's intermediate aggregates over matched type-E events
                  (the paper's ``sum(G_E', q)`` inputs to Eq. 4)
    Rp(u)         pending final aggregates (Eq. 2), reset by trailing NOT

Basis entries are the paper's snapshots: graphlet-level ``x`` entries
(Def. 8), event-level ``z`` entries (Def. 9), and a gate entry used for start
contributions.
"""

from __future__ import annotations

import numpy as np

__all__ = ["ChannelLayout", "PaneBasis"]


class ChannelLayout:
    """Index layout of the state vector for one sharable component."""

    CONST = 0
    GATE = 1

    def __init__(self, units: list[tuple], type_ids: list[int]):
        self.units = list(units)          # linear units: ("count",) first, then sums
        self.type_ids = list(type_ids)    # component positive type ids (schema ids)
        self.n_units = len(self.units)
        self.t = len(self.type_ids)
        self._type_pos = {e: i for i, e in enumerate(self.type_ids)}
        self._unit_pos = {u: i for i, u in enumerate(self.units)}
        self.size = 2 + self.n_units * self.t + self.n_units

    def a_idx(self, unit: tuple, type_id: int) -> int:
        return 2 + self._unit_pos[unit] * self.t + self._type_pos[type_id]

    def rp_idx(self, unit: tuple) -> int:
        return 2 + self.n_units * self.t + self._unit_pos[unit]

    def unit_index(self, unit: tuple) -> int:
        return self._unit_pos[unit]

    def fresh_state(self) -> np.ndarray:
        u = np.zeros(self.size)
        u[self.CONST] = 1.0
        u[self.GATE] = 1.0
        return u


class PaneBasis:
    """Per-pane snapshot basis with per-query value functionals.

    ``W[q]`` is a [max_basis, C] matrix; row ``j`` is snapshot ``j``'s value
    functional for query ``q``.  ``coef_row @ W[q] @ u[q]`` resolves a
    coefficient row to the query's scalar value.
    """

    def __init__(self, n_queries: int, n_channels: int, max_basis: int = 192):
        self.k = n_queries
        self.C = n_channels
        self.max_basis = max_basis
        self.W = np.zeros((n_queries, max_basis, n_channels))
        self.B = 0
        self.n_graphlet_snapshots = 0
        self.n_event_snapshots = 0

    def room_for(self, n: int) -> bool:
        return self.B + n <= self.max_basis

    def alloc(self, kind: str) -> int:
        if self.B >= self.max_basis:
            raise RuntimeError("snapshot basis overflow; optimizer should have split")
        idx = self.B
        self.B += 1
        if kind == "graphlet":
            self.n_graphlet_snapshots += 1
        elif kind == "event":
            self.n_event_snapshots += 1
        return idx

    def set_value(self, q: int, idx: int, functional: np.ndarray) -> None:
        self.W[q, idx, :] = functional

    def w(self, q: int) -> np.ndarray:
        """Active [B, C] functional matrix for query q."""
        return self.W[q, : self.B, :]
