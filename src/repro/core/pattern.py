"""Kleene pattern AST and FSA-template derivation (paper Defs. 1, Sec. 3.1, Sec. 5).

A pattern is one of::

    E               (event type)
    P+              Kleene(P)
    SEQ(P1, .., Pn) Seq(...)
    NOT P           Not(P)         -- only as a component of a Seq
    P1 OR  P2       Or(...)        -- top level only; handled per Sec. 5
    P1 AND P2       And(...)       -- top level only; handled per Sec. 5

``analyze()`` turns a (negation-free, Or/And-free) pattern into the
finite-state-automaton view used throughout the paper: start/end types and the
predecessor-type edge set (Fig. 3, Fig. 8), plus negation constraints for
``Not`` components.
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = [
    "Pattern", "EventType", "Kleene", "Seq", "Not", "Or", "And",
    "NegConstraint", "PatternInfo", "analyze",
]


class Pattern:
    """Base class; use the subclasses below."""

    def __add__(self, other: "Pattern") -> "Seq":  # convenience: A + B == SEQ(A, B)
        return Seq(self, other)


@dataclass(frozen=True)
class EventType(Pattern):
    name: str

    def __repr__(self) -> str:
        return self.name


@dataclass(frozen=True)
class Kleene(Pattern):
    inner: Pattern

    def __repr__(self) -> str:
        return f"({self.inner!r})+"


@dataclass(frozen=True)
class Seq(Pattern):
    parts: tuple[Pattern, ...]

    def __init__(self, *parts: Pattern):
        object.__setattr__(self, "parts", tuple(parts))

    def __repr__(self) -> str:
        return "SEQ(" + ", ".join(repr(p) for p in self.parts) + ")"


@dataclass(frozen=True)
class Not(Pattern):
    inner: Pattern

    def __repr__(self) -> str:
        return f"NOT {self.inner!r}"


@dataclass(frozen=True)
class Or(Pattern):
    left: Pattern
    right: Pattern

    def __repr__(self) -> str:
        return f"({self.left!r} OR {self.right!r})"


@dataclass(frozen=True)
class And(Pattern):
    left: Pattern
    right: Pattern

    def __repr__(self) -> str:
        return f"({self.left!r} AND {self.right!r})"


@dataclass(frozen=True)
class NegConstraint:
    """NOT ``neg_type`` between ``before`` and ``after`` (paper Sec. 5).

    A matched negative event e_n disallows connections from matches of types
    ``before`` earlier than e_n to matches of types ``after`` later than e_n.
    ``before is None``  -> window start (leading NOT): trends may not *start*
    after e_n.  ``after is None`` -> window end (trailing NOT): trends may not
    *end* before e_n.
    """

    neg_type: str
    before: frozenset[str] | None
    after: frozenset[str] | None


@dataclass
class PatternInfo:
    """FSA-template view of a (positive part of a) pattern."""

    start: frozenset[str]
    end: frozenset[str]
    edges: frozenset[tuple[str, str]]  # (predecessor type, successor type)
    types: frozenset[str]              # positive types
    negatives: tuple[NegConstraint, ...] = field(default_factory=tuple)
    kleene_types: frozenset[str] = frozenset()  # E with a self-loop via Kleene E+

    def pred_types(self, e: str) -> frozenset[str]:
        """pt(E, q): predecessor types of E (paper Example 2)."""
        return frozenset(a for (a, b) in self.edges if b == e)


def _analyze_positive(p: Pattern) -> PatternInfo:
    if isinstance(p, EventType):
        return PatternInfo(
            start=frozenset({p.name}),
            end=frozenset({p.name}),
            edges=frozenset(),
            types=frozenset({p.name}),
        )
    if isinstance(p, Kleene):
        inner = _analyze_positive(p.inner)
        loop = frozenset((e, s) for e in inner.end for s in inner.start)
        kle = inner.kleene_types
        if isinstance(p.inner, EventType):
            kle = kle | {p.inner.name}
        return PatternInfo(
            start=inner.start,
            end=inner.end,
            edges=inner.edges | loop,
            types=inner.types,
            negatives=inner.negatives,
            kleene_types=kle,
        )
    if isinstance(p, Seq):
        if not p.parts:
            raise ValueError("empty SEQ")
        start: frozenset[str] | None = None
        frontier: frozenset[str] | None = None  # end types of the previous positive part
        edges: set[tuple[str, str]] = set()
        types: set[str] = set()
        negatives: list[NegConstraint] = []
        kleene: set[str] = set()
        pending_negs: list[str] = []  # NOT types awaiting the next positive part
        for part in p.parts:
            if isinstance(part, Not):
                if not isinstance(part.inner, EventType):
                    raise ValueError("NOT supports a single event type")
                pending_negs.append(part.inner.name)
                continue
            info = _analyze_positive(part)
            if info.types & types:
                raise ValueError(
                    f"event type(s) {sorted(info.types & types)} appear more than "
                    "once in one pattern; the type-keyed template requires each "
                    "type to appear once (paper Sec. 3.1)"
                )
            if start is None:
                start = info.start
                if pending_negs:  # leading NOT
                    for nt in pending_negs:
                        negatives.append(NegConstraint(nt, None, info.start))
                    pending_negs = []
            else:
                assert frontier is not None
                edges.update((a, b) for a in frontier for b in info.start)
                for nt in pending_negs:
                    negatives.append(NegConstraint(nt, frontier, info.start))
                pending_negs = []
            edges.update(info.edges)
            types.update(info.types)
            negatives.extend(info.negatives)
            kleene.update(info.kleene_types)
            frontier = info.end
        if start is None:
            raise ValueError("SEQ needs at least one positive part")
        assert frontier is not None
        for nt in pending_negs:  # trailing NOT
            negatives.append(NegConstraint(nt, frontier, None))
        return PatternInfo(
            start=start,
            end=frontier,
            edges=frozenset(edges),
            types=frozenset(types),
            negatives=tuple(negatives),
            kleene_types=frozenset(kleene),
        )
    if isinstance(p, (Or, And, Not)):
        raise ValueError(
            f"{type(p).__name__} is handled at the workload level (Sec. 5); "
            "call Query.expand() instead of analyze()"
        )
    raise TypeError(f"not a pattern: {p!r}")


def analyze(p: Pattern) -> PatternInfo:
    """FSA-template info for a pattern without top-level Or/And."""
    info = _analyze_positive(p)
    neg_types = {n.neg_type for n in info.negatives}
    if neg_types & info.types:
        raise ValueError("a type cannot be both positive and negative in one pattern")
    return info
