"""Error accountant: per-query aggregate error bounds under shedding.

Shedding changes results; this module tracks *which* guarantees survive.
Every shed event is bucketed per (atomic query, group key, pane) into three
classes relative to that query — Kleene-type, pattern-completing
(non-Kleene positive), negation-type — from which two bounds follow for the
trend-count aggregates of a window:

* **Subset guarantee** (lower bound): if no negation-type event of query q was
  shed, every trend counted by the shedded run exists in the unshedded run, so
  ``emitted <= true`` for COUNT/SUM of non-negative attributes.  (Dropping a
  positive event only removes trends; dropping a NOT event can fabricate
  them.)
* **Multiplicative upper bound** (factor-3 lemma): when a shed Kleene event e
  was a burst *suffix* with a kept same-burst witness e' (``witnessed`` shed
  plans certify this), every trend containing e maps to a trend without it —
  ``T -> T \\ {e}`` when that is still a match, else ``T -> T \\ {e} + {e'}``
  (e' precedes e, so e' inherits every backward adjacency of e).  The map is
  at most 2-to-1 onto trends without e, hence ``N <= 3 * N_without`` per
  removal, and over a window where ``s`` Kleene-type events of q were shed:

      true <= 3**s * emitted        (and true = 0 whenever emitted = 0)

  The lemma needs removal/substitution to preserve trend-hood, so ``tight``
  additionally requires: no pattern-completing or negation event of q shed in
  the window, no edge predicates (they make within-burst adjacency
  non-transitive), and no per-event predicates on q's Kleene types (the
  witness might fail them).  A 2**s bound without the witness condition is
  *unsound*: a shed event can be the sole Kleene witness of arbitrarily many
  trends.
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass

import numpy as np

from ..core.events import EventBatch, pane_size_for
from ..core.query import Workload

__all__ = ["WindowBound", "QueryErrorReport", "ErrorAccountant",
           "merge_error_reports"]

_KLE, _CRIT, _NEG, _WIT = 0, 1, 2, 3


@dataclass(frozen=True)
class WindowBound:
    """Shed exposure of one (query, group, window)."""

    shed_kleene: int
    shed_critical: int
    shed_negative: int
    tight: bool      # the 3**s multiplicative bound applies

    def count_upper_bound(self, emitted: float) -> float:
        """Upper bound on the true trend count given the emitted one."""
        if not self.tight:
            return float("inf")
        if emitted <= 0:
            return 0.0
        return 3.0 ** self.shed_kleene * emitted


@dataclass(frozen=True)
class QueryErrorReport:
    query: str
    shed_kleene: int
    shed_critical: int
    shed_negative: int
    cells_affected: int      # (group, pane) buckets with any relevant shed
    subset_guarantee: bool   # emitted results are lower bounds on the truth


def merge_error_reports(reports) -> dict[str, "QueryErrorReport"]:
    """Fleet-level certificate from per-instance ``report()`` dicts.

    Shed-class counts sum; the subset guarantee is the conjunction (one
    instance shedding a negation event of q withdraws the global lower
    bound).  ``cells_affected`` also sums — exact when the instances
    partition the group space (the sharded service: groups are disjoint per
    shard, router cells cover events no shard ever saw), an upper bound on
    distinct cells otherwise.  For exact per-window ``3^s`` bounds merge the
    accountants themselves (:meth:`ErrorAccountant.merged`)."""
    out: dict[str, QueryErrorReport] = {}
    for rep in reports:
        for name, r in rep.items():
            prev = out.get(name)
            if prev is None:
                out[name] = r
            else:
                out[name] = QueryErrorReport(
                    query=name,
                    shed_kleene=prev.shed_kleene + r.shed_kleene,
                    shed_critical=prev.shed_critical + r.shed_critical,
                    shed_negative=prev.shed_negative + r.shed_negative,
                    cells_affected=prev.cells_affected + r.cells_affected,
                    subset_guarantee=prev.subset_guarantee
                    and r.subset_guarantee)
    return out


class ErrorAccountant:
    def __init__(self, workload: Workload, pane: int | None = None):
        self.pane = int(pane) if pane else pane_size_for(workload.windows)
        # (aqi, group, pane_t0) -> [kleene, critical, negative, witnessed]
        self._shed: dict[tuple[int, int, int], list[int]] = {}
        self._tainted: set[int] = set()
        self.total_shed = 0
        self.late_events = 0
        self._bind(workload)

    def _bind(self, workload: Workload) -> None:
        self.workload = workload
        schema = workload.schema
        self._cls: list[tuple[frozenset, frozenset, frozenset]] = []
        self._boundable: list[bool] = []
        self._by_name: dict[str, int] = {}
        for aqi, q in enumerate(workload.atomic):
            kle = frozenset(schema.type_id(t) for t in q.info.kleene_types)
            crit = frozenset(schema.type_id(t) for t in q.info.types) - kle
            neg = frozenset(schema.type_id(nc.neg_type)
                            for nc in q.info.negatives)
            self._cls.append((kle, crit, neg))
            self._boundable.append(
                not q.edge_preds
                and all(not q.preds_for(t) for t in q.info.kleene_types))
            self._by_name[q.name] = aqi

    def migrate(self, workload: Workload) -> None:
        """Rebind to a changed workload (query add/remove at a plan
        migration).  History of surviving queries is remapped by name.
        Queries *new* to this workload are permanently tainted: events shed
        before the query existed were never classified for it, so neither
        the subset guarantee nor the multiplicative bound can be certified
        for any of its windows.  The pane bucketing is fixed at construction
        (changing it would orphan recorded cells); it stays sound for new
        window geometries because window coverage only ever over-counts."""
        old_names = {aqi: name for name, aqi in self._by_name.items()}
        tainted_names = {old_names[aqi] for aqi in self._tainted}
        self._bind(workload)
        remap = {old_aqi: self._by_name[name]
                 for old_aqi, name in old_names.items()
                 if name in self._by_name}
        self._shed = {(remap[aqi], gk, t0): cell
                      for (aqi, gk, t0), cell in self._shed.items()
                      if aqi in remap}
        self._tainted = {self._by_name[n] for n in tainted_names
                         if n in self._by_name}
        if self.total_shed:
            survivors = set(remap.values())
            self._tainted |= set(range(len(workload.atomic))) - survivors

    def record(self, shed: EventBatch, witnessed: bool = False,
               late: bool = False) -> None:
        """Account a batch of shed events (any time span; bucketed per pane).

        ``witnessed``: the shed plan certified suffix-only Kleene shedding
        with a kept witness per trimmed burst (see module docstring).

        ``late``: the events were not chosen by a shed plan but arrived past
        the lateness horizon of the event-time layer (or behind an
        order-assuming pane loop) and were dropped for it.  They are charged
        exactly like unwitnessed shed events — an un-folded event corrupts
        results the same way however it was lost — which keeps the subset /
        ``3^s`` bookkeeping sound under disorder: any window a late Kleene
        event would have landed in loses its ``tight`` certificate, and late
        negation events withdraw the subset guarantee."""
        if not len(shed):
            return
        self.total_shed += len(shed)
        if late:
            self.late_events += len(shed)
        pane_t0 = (shed.time // self.pane) * self.pane
        for aqi, (kle, crit, neg) in enumerate(self._cls):
            for ci, tset in ((_KLE, kle), (_CRIT, crit), (_NEG, neg)):
                if not tset:
                    continue
                mask = np.isin(shed.type_id, list(tset))
                if not mask.any():
                    continue
                counts = Counter(zip(shed.group[mask].tolist(),
                                     pane_t0[mask].tolist()))
                for (gk, t0), c in counts.items():
                    cell = self._shed.setdefault((aqi, int(gk), int(t0)),
                                                 [0, 0, 0, 1])
                    cell[ci] += c
                    cell[_WIT] &= int(witnessed)

    @classmethod
    def merged(cls, accountants) -> "ErrorAccountant":
        """Cell-exact union of several accountants over the same workload.

        The sharded service runs one accountant per shard plus one at the
        router (admission-time shedding); the global certificate is their
        union: per-cell counts sum, the witness bit ANDs, taints union.
        ``window_bound`` / ``report`` on the result are then exactly what a
        single accountant observing every shed event would have produced —
        one global subset guarantee and one ``3^s`` bound per window."""
        accountants = list(accountants)
        if not accountants:
            raise ValueError("need at least one accountant")
        first = accountants[0]
        out = cls(first.workload, pane=first.pane)
        for acc in accountants:
            if acc.pane != out.pane:
                raise ValueError("accountants disagree on pane bucketing")
            out.total_shed += acc.total_shed
            out.late_events += acc.late_events
            out._tainted |= acc._tainted
            for key, cell in acc._shed.items():
                dst = out._shed.setdefault(key, [0, 0, 0, 1])
                for ci in (_KLE, _CRIT, _NEG):
                    dst[ci] += cell[ci]
                dst[_WIT] &= cell[_WIT]
        return out

    # -- queries --

    def window_bound(self, query: str, group: int, w0: int) -> WindowBound:
        """Bound for the window of ``query`` (atomic name) starting at w0."""
        aqi = self._by_name[query]
        within = self.workload.atomic[aqi].within
        kle = crit = neg = 0
        witnessed = True
        for t0 in range(w0 - w0 % self.pane, w0 + within, self.pane):
            cell = self._shed.get((aqi, int(group), t0))
            if cell:
                kle += cell[_KLE]
                crit += cell[_CRIT]
                neg += cell[_NEG]
                witnessed &= bool(cell[_WIT])
        tight = (crit == 0 and neg == 0 and witnessed
                 and self._boundable[aqi] and aqi not in self._tainted)
        return WindowBound(kle, crit, neg, tight)

    def report(self) -> dict[str, QueryErrorReport]:
        out: dict[str, QueryErrorReport] = {}
        for name, aqi in self._by_name.items():
            kle = crit = neg = cells = 0
            for (qa, _gk, _t0), cell in self._shed.items():
                if qa != aqi or not any(cell[:_WIT]):
                    continue
                cells += 1
                kle += cell[_KLE]
                crit += cell[_CRIT]
                neg += cell[_NEG]
            out[name] = QueryErrorReport(
                query=name, shed_kleene=kle, shed_critical=crit,
                shed_negative=neg, cells_affected=cells,
                subset_guarantee=neg == 0 and aqi not in self._tainted)
        return out
