"""Configuration for the bounded-latency overload runtime.

One dataclass gathers every knob of the overload subsystem so callers
(`OverloadRuntime`, `HamletService`, the launch CLI, benchmarks) opt in with a
single object.  The SLO is expressed on *pane* processing latency for the
runtime (epoch latency for the service, which drains at epoch granularity).
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["OverloadConfig"]


@dataclass
class OverloadConfig:
    """Opt-in overload handling: admission control + shedding + SLO control.

    slo_ms             latency target the controller steers towards
    shed_policy        "none" | "drop_tail" | "random" | "benefit_weighted"
    pane_budget_events hard per-pane admission cap (events); None = uncapped.
                       This is the feed-forward part of admission control: it
                       bounds per-pane work even before the controller reacts.
    queue_capacity     ingress queue bound (events); arrivals beyond it are
                       dropped at ingress and counted
    high_watermark     queue fill fraction above which the queue stops
                       accepting (backpressure asserted)
    low_watermark      fill fraction below which it resumes accepting
    kp / ki / kd       PID gains on the relative latency error
                       ``(latency - slo) / slo``.  Keep the loop gain
                       ``(kp + ki) * overload_factor`` below ~1: the plant
                       gain scales with offered load, and a hot discrete
                       loop limit-cycles between shedding nothing and
                       everything
    kr                 gain on the *revision load* (disorder-aware admission
                       control): under out-of-order arrival the event-time
                       layer re-plans panes and re-folds emitted windows;
                       that work competes with fresh panes for the same
                       budget, so the controller treats the revision rate
                       (revisions per emitted window, fed by the caller) as
                       a second cost axis — a revision storm raises the shed
                       ratio even while pane latency still looks healthy.
                       0 disables the axis.
    max_shed           ceiling on the controller's shed ratio
    micro_batch        cross-pane fusion factor K: admitted panes accumulate
                       and execute as one fused launch set per K panes (the
                       controller then observes amortized per-pane time once
                       per micro-batch); 1 = exact per-pane control loop
    plan_cache         enable the engine's pane-plan memoization (see
                       ``core/plan_cache.py``)
    fold_exec          enable the stacked finalize/fold executor (see
                       ``core/fold_exec.py``); off = the sequential
                       per-graphlet replay (bitwise-identical results)
    fixed_shed         if set, bypass the controller and shed this constant
                       fraction (used for equal-ratio policy comparisons)
    min_burst_keep     fraction of each Kleene burst the benefit-weighted
                       policy protects in its primary shed phase (>= 1 event),
                       so ``E+`` patterns keep at least a match per burst
    benefit_model      "v1" | "v2" — which Def. 11/12 cost model weights bursts
    seed               rng seed for the random policy
    tick_seconds       maps stream ticks to wall seconds; when set, latency is
                       end-to-end (queueing backlog included), not just the
                       pane processing time
    pipeline_flush     run each micro-batch flush (plan -> execute ->
                       finalize -> fold) on a dedicated single worker thread
                       instead of inline: while flush N executes, the caller
                       thread keeps polling, admitting and shedding the
                       panes of flush N+1 (the host-side half of the
                       pipeline).  Flushes stay strictly FIFO on the one
                       worker, so results are identical to inline execution
                       whenever shed decisions are (``none``/``fixed_shed``
                       — with the live PID loop the controller observes a
                       flush one step later, the same class of trade as
                       ``micro_batch``).  Call ``shutdown()`` (or
                       ``results()``, which drains) before discarding the
                       runtime.
    """

    slo_ms: float = 50.0
    shed_policy: str = "benefit_weighted"
    pane_budget_events: int | None = None
    queue_capacity: int = 1 << 16
    high_watermark: float = 0.75
    low_watermark: float = 0.5
    kp: float = 0.1
    ki: float = 0.05
    kd: float = 0.0
    kr: float = 0.0
    max_shed: float = 0.98
    fixed_shed: float | None = None
    micro_batch: int = 1
    plan_cache: bool = True
    fold_exec: bool = True
    min_burst_keep: float = 0.25
    benefit_model: str = "v1"
    seed: int = 0
    tick_seconds: float | None = None
    pipeline_flush: bool = False

    def __post_init__(self) -> None:
        if self.shed_policy not in ("none", "drop_tail", "random",
                                    "benefit_weighted"):
            raise ValueError(f"unknown shed_policy {self.shed_policy!r}")
        if not (0.0 <= self.low_watermark <= self.high_watermark <= 1.0):
            raise ValueError("need 0 <= low_watermark <= high_watermark <= 1")
        if self.fixed_shed is not None and not (0.0 <= self.fixed_shed < 1.0):
            raise ValueError("fixed_shed must be in [0, 1)")
        if self.micro_batch < 1:
            raise ValueError("micro_batch must be >= 1")
        if self.kr < 0.0:
            raise ValueError("kr must be >= 0")
