"""Configuration for the bounded-latency overload runtime.

One dataclass gathers every knob of the overload subsystem so callers
(`OverloadRuntime`, `HamletService`, the launch CLI, benchmarks) opt in with a
single object.  The SLO is expressed on *pane* processing latency for the
runtime (epoch latency for the service, which drains at epoch granularity).
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["OverloadConfig"]


@dataclass
class OverloadConfig:
    """Opt-in overload handling: admission control + shedding + SLO control.

    slo_ms             latency target the controller steers towards
    shed_policy        "none" | "drop_tail" | "random" | "benefit_weighted"
    pane_budget_events hard per-pane admission cap (events); None = uncapped.
                       This is the feed-forward part of admission control: it
                       bounds per-pane work even before the controller reacts.
    queue_capacity     ingress queue bound (events); arrivals beyond it are
                       dropped at ingress and counted
    high_watermark     queue fill fraction above which the queue stops
                       accepting (backpressure asserted)
    low_watermark      fill fraction below which it resumes accepting
    kp / ki / kd       PID gains on the relative latency error
                       ``(latency - slo) / slo``.  Keep the loop gain
                       ``(kp + ki) * overload_factor`` below ~1: the plant
                       gain scales with offered load, and a hot discrete
                       loop limit-cycles between shedding nothing and
                       everything
    max_shed           ceiling on the controller's shed ratio
    fixed_shed         if set, bypass the controller and shed this constant
                       fraction (used for equal-ratio policy comparisons)
    min_burst_keep     fraction of each Kleene burst the benefit-weighted
                       policy protects in its primary shed phase (>= 1 event),
                       so ``E+`` patterns keep at least a match per burst
    benefit_model      "v1" | "v2" — which Def. 11/12 cost model weights bursts
    seed               rng seed for the random policy
    tick_seconds       maps stream ticks to wall seconds; when set, latency is
                       end-to-end (queueing backlog included), not just the
                       pane processing time
    """

    slo_ms: float = 50.0
    shed_policy: str = "benefit_weighted"
    pane_budget_events: int | None = None
    queue_capacity: int = 1 << 16
    high_watermark: float = 0.75
    low_watermark: float = 0.5
    kp: float = 0.1
    ki: float = 0.05
    kd: float = 0.0
    max_shed: float = 0.98
    fixed_shed: float | None = None
    min_burst_keep: float = 0.25
    benefit_model: str = "v1"
    seed: int = 0
    tick_seconds: float | None = None

    def __post_init__(self) -> None:
        if self.shed_policy not in ("none", "drop_tail", "random",
                                    "benefit_weighted"):
            raise ValueError(f"unknown shed_policy {self.shed_policy!r}")
        if not (0.0 <= self.low_watermark <= self.high_watermark <= 1.0):
            raise ValueError("need 0 <= low_watermark <= high_watermark <= 1")
        if self.fixed_shed is not None and not (0.0 <= self.fixed_shed < 1.0):
            raise ValueError("fixed_shed must be in [0, 1)")
