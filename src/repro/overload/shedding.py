"""Load-shedding policies: which events to drop when a pane is over budget.

``drop_tail`` and ``random`` are the classic baselines.  ``benefit_weighted``
is pattern-aware: it classifies event types against the workload (negation
types, pattern-completing non-Kleene types, Kleene types, irrelevant types)
and sheds in an order that protects result quality:

1. events no query matches (free sheds);
2. Kleene-burst *suffixes*, lowest sharing benefit first — trimming a suffix
   keeps the remaining burst contiguous so graphlet snapshots and the
   prefix-propagation stay valid, and the per-burst shed order is ranked by
   the Def. 11 benefit model (``core/benefit.py``): types whose bursts profit
   most from shared execution are kept longest.  At least
   ``min_burst_keep`` of each burst survives this phase so ``E+`` still has a
   witness per burst;
3. pattern-completing (non-Kleene positive) events, newest first, interleaved
   proportionally with the protected remainder of Kleene bursts — a trend
   needs a head *and* a Kleene witness, so under extreme pressure both
   classes must degrade together rather than one being wiped out first;
4. negation-type events, last of all — dropping one can create *false*
   matches for ``NOT`` queries, which destroys the subset guarantee the error
   accountant certifies.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from ..core import benefit as B
from ..core.events import EventBatch
from ..core.query import Workload

__all__ = ["ShedPlan", "TypeProfile", "DropTail", "RandomShed",
           "BenefitWeighted", "make_shedder"]


@dataclass(frozen=True)
class ShedPlan:
    """Sorted index partitions of one pane: ``keep`` survives, ``shed`` drops.

    ``witnessed`` certifies that every Kleene burst that lost events (a) lost
    only a *suffix* and (b) retains at least one kept event — the structural
    precondition of the error accountant's multiplicative count bound.
    """

    keep: np.ndarray
    shed: np.ndarray
    witnessed: bool = False

    @property
    def n_keep(self) -> int:
        return len(self.keep)

    @property
    def n_shed(self) -> int:
        return len(self.shed)


def _keep_all(n: int) -> ShedPlan:
    return ShedPlan(np.arange(n), np.array([], dtype=np.int64), witnessed=True)


def _plan_from_shed(n: int, shed_idx, witnessed: bool = False) -> ShedPlan:
    shed = np.sort(np.asarray(shed_idx, dtype=np.int64))
    keep = np.setdiff1d(np.arange(n), shed, assume_unique=True)
    return ShedPlan(keep, shed, witnessed=witnessed)


def _merge_proportional(a: list[int], b: list[int]) -> list[int]:
    """Interleave so every prefix holds ~|a|:|b| of each list (both classes
    deplete at the same relative rate)."""
    out: list[int] = []
    ia = ib = 0
    while ia < len(a) or ib < len(b):
        if ib >= len(b) or (ia < len(a) and ia * len(b) <= ib * len(a)):
            out.append(a[ia])
            ia += 1
        else:
            out.append(b[ib])
            ib += 1
    return out


class TypeProfile:
    """Pattern-aware classification of a workload's event types.

    Each type id lands in exactly one class, by maximum protection need:
    ``negative`` > ``critical`` (positive non-Kleene for some query) >
    ``kleene`` (Kleene-only) > ``irrelevant`` (matched by no query).
    """

    def __init__(self, workload: Workload):
        schema = workload.schema
        kleene_q: dict[int, int] = {}    # type id -> #queries sharing E+
        types_of: dict[int, int] = {}    # type id -> max |types| over its queries
        critical: set[int] = set()
        negative: set[int] = set()
        for q in workload.atomic:
            for t in q.info.types:
                tid = schema.type_id(t)
                if t in q.info.kleene_types:
                    kleene_q[tid] = kleene_q.get(tid, 0) + 1
                    types_of[tid] = max(types_of.get(tid, 1), len(q.info.types))
                else:
                    critical.add(tid)
            for nc in q.info.negatives:
                negative.add(schema.type_id(nc.neg_type))
        self.negative = frozenset(negative)
        self.critical = frozenset(critical - negative)
        self.kleene = frozenset(set(kleene_q) - critical - negative)
        self.irrelevant = frozenset(
            set(range(schema.n_types)) - self.negative - self.critical
            - self.kleene)
        self.kleene_sharers = {tid: kleene_q.get(tid, 1) for tid in self.kleene}
        self.kleene_types_per_q = {tid: types_of.get(tid, 1)
                                   for tid in self.kleene}


class _Policy:
    def plan(self, pane: EventBatch, keep_n: int) -> ShedPlan:
        raise NotImplementedError


class DropTail(_Policy):
    """Keep the oldest ``keep_n`` events; shed the pane's tail."""

    def plan(self, pane, keep_n):
        n = len(pane)
        if keep_n >= n:
            return _keep_all(n)
        return ShedPlan(np.arange(keep_n), np.arange(keep_n, n))


class RandomShed(_Policy):
    """Uniform random sample of ``keep_n`` events, arrival order preserved."""

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)

    def plan(self, pane, keep_n):
        n = len(pane)
        if keep_n >= n:
            return _keep_all(n)
        keep = np.sort(self._rng.choice(n, size=keep_n, replace=False))
        shed = np.setdiff1d(np.arange(n), keep, assume_unique=True)
        return ShedPlan(keep, shed)


class BenefitWeighted(_Policy):
    """Pattern- and benefit-aware shedding (module docstring)."""

    def __init__(self, workload: Workload, min_burst_keep: float = 0.25,
                 model: str = "v1"):
        self.profile = TypeProfile(workload)
        self.min_burst_keep = float(min_burst_keep)
        self.model = model

    # per-event sharing benefit of a burst of length b (Def. 11/12 per burst,
    # normalised by b): bursts that profit least from shared execution shed
    # first, so high-benefit types stay resident
    def _burst_score(self, tid: int, b: int, n_pane: int) -> float:
        k = self.profile.kleene_sharers.get(tid, 1)
        t = self.profile.kleene_types_per_q.get(tid, 1)
        if self.model == "v2":
            bc = B.benefit_v2(b=b, n=n_pane, s_p=1, s_c=1, k=k, g=b,
                              p=max(1, t // 2))
        else:
            bc = B.benefit_v1(b=b, n=n_pane, s_p=1, s_c=1, k=k, g=b, t=t)
        return bc.benefit / max(1, b)

    @staticmethod
    def _bursts(type_id: np.ndarray) -> list[tuple[int, int, int]]:
        """Maximal same-type runs as ``(type, start, stop)`` (Def. 10)."""
        if len(type_id) == 0:
            return []
        cut = np.nonzero(np.diff(type_id))[0] + 1
        bounds = np.concatenate([[0], cut, [len(type_id)]])
        return [(int(type_id[bounds[i]]), int(bounds[i]), int(bounds[i + 1]))
                for i in range(len(bounds) - 1)]

    def plan(self, pane, keep_n):
        n = len(pane)
        if keep_n >= n:
            return _keep_all(n)
        shed_n = n - keep_n
        prof = self.profile
        tids = pane.type_id

        order: list[int] = []
        # phase 1: irrelevant events, newest first
        irrelevant = np.nonzero(np.isin(tids, list(prof.irrelevant)))[0]
        order.extend(irrelevant[::-1].tolist())

        # phases 2+3: Kleene bursts — suffix-first within a burst, bursts
        # ranked by ascending per-event sharing benefit.  Bursts are segmented
        # *per group partition*, mirroring the engine (which partitions by
        # group before burst segmentation): a kept witness must live in the
        # same group as the trimmed suffix or it witnesses nothing.
        primary: list[tuple[float, list[int]]] = []
        secondary: list[tuple[float, list[int]]] = []
        for gk in np.unique(pane.group):
            gidx = np.nonzero(pane.group == gk)[0]
            for tid, start, stop in self._bursts(tids[gidx]):
                if tid not in prof.kleene:
                    continue
                b = stop - start
                floor_keep = max(1, math.ceil(self.min_burst_keep * b))
                score = self._burst_score(tid, b, n)
                idx = gidx[start:stop]
                suffix = idx[:floor_keep - 1:-1].tolist()
                protected = idx[floor_keep - 1::-1].tolist()
                if suffix:
                    primary.append((score, suffix))
                secondary.append((score, protected))
        for _, idxs in sorted(primary, key=lambda p: p[0]):
            order.extend(idxs)
        n_witnessed = len(order)   # through here every burst keeps a witness

        # phase 3: surplus heads and burst witnesses, degrading together
        crit = np.nonzero(np.isin(tids, list(prof.critical)))[0]
        witnesses: list[int] = []
        for _, idxs in sorted(secondary, key=lambda p: p[0]):
            witnesses.extend(idxs)
        order.extend(_merge_proportional(crit[::-1].tolist(), witnesses))
        # phase 4: negation types, only when nothing else is left
        neg = np.nonzero(np.isin(tids, list(prof.negative)))[0]
        order.extend(neg[::-1].tolist())

        return _plan_from_shed(n, order[:shed_n],
                               witnessed=shed_n <= n_witnessed)


def make_shedder(policy: str, workload: Workload, *, seed: int = 0,
                 min_burst_keep: float = 0.25,
                 benefit_model: str = "v1") -> _Policy | None:
    """Instantiate a shedding policy by name; ``"none"`` returns None."""
    if policy == "none":
        return None
    if policy == "drop_tail":
        return DropTail()
    if policy == "random":
        return RandomShed(seed=seed)
    if policy == "benefit_weighted":
        return BenefitWeighted(workload, min_burst_keep=min_burst_keep,
                               model=benefit_model)
    raise ValueError(f"unknown shed policy {policy!r}")
