"""Overload runtime: pane-granular load shedding, backpressure, and
latency-bound admission control around the HAMLET dataplane.

The paper assumes every arriving event is processed; under sustained offered
load beyond hardware capacity that just grows latency without bound.  This
subsystem adds the graceful-degradation story: a bounded ingress queue with
watermark backpressure, pluggable shedding policies (including a
pattern-aware, benefit-weighted one), a PID controller that holds a latency
SLO, and an error accountant that certifies what the shedded results still
guarantee.
"""

from .accountant import ErrorAccountant, QueryErrorReport, WindowBound  # noqa: F401
from .config import OverloadConfig  # noqa: F401
from .controller import LatencyController  # noqa: F401
from .ingress import IngressQueue  # noqa: F401
from .runtime import OverloadMetrics, OverloadRuntime, PaneMetric  # noqa: F401
from .shedding import (BenefitWeighted, DropTail, RandomShed, ShedPlan,  # noqa: F401
                       TypeProfile, make_shedder)
