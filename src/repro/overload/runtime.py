"""Bounded-latency streaming runtime wrapping :class:`HamletRuntime`.

``OverloadRuntime`` drives the HAMLET pane dataplane *incrementally* — one
pane at a time instead of one batch call — and puts an overload-control loop
around it:

    producers --offer()--> IngressQueue --poll (pane)--> admission control
        --> shedding policy --> PaneProcessor --> window instances --> results
                 ^                                    |
                 '---- PID controller <--- pane latency observation

Per pane: arrivals are pulled from the ingress queue, the admission budget is
``min(n * (1 - shed_ratio), pane_budget_events)``, the shedding policy picks
*which* events survive, the survivors run through the unchanged HAMLET pane
machinery, the measured pane-processing time feeds the PID controller, and
the shed events feed the error accountant.  With ``tick_seconds`` set, the
metrics additionally report end-to-end latency against a simulated arrival
timeline (sequential processing: backlog carries over), which is what makes
sustained overload visible as unbounded latency when shedding is off.

Cross-pane fused execution: with ``config.micro_batch = K > 1`` admitted
panes accumulate in a processing backlog and execute together — every group
driver's propagation jobs for K pane steps flush as one launch per size
bucket (see ``core/engine.py``).  Admission and shedding still happen per
pane at poll time; the controller and the per-pane metrics are then fed the
*amortized* per-pane processing time of the fused batch, so the control loop
reacts once per micro-batch instead of once per pane.  Results are bitwise
identical to ``K=1`` whenever the shed decisions agree (e.g. under
``fixed_shed``); with the live PID loop the coarser observation cadence can
shift shed ratios — that is the documented latency/efficiency trade.

A group partition seen for the first time at pane ``t`` starts with fresh
window state — correct because an absent group's earlier panes are empty and
the empty-pane transfer matrix is the identity.
"""

from __future__ import annotations

import math
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np

from ..core.engine import (HamletRuntime, PaneMicroBatcher, RunStats,
                           _Instance, advance_instances, combine_results)
from ..core.events import EventBatch
from ..core.query import Workload
from ..obs.metrics import LATENCY_MS_BUCKETS
from .accountant import ErrorAccountant
from .config import OverloadConfig
from .controller import LatencyController
from .ingress import IngressQueue
from .shedding import make_shedder

__all__ = ["OverloadRuntime", "OverloadMetrics", "PaneMetric"]


@dataclass(frozen=True)
class PaneMetric:
    t0: int
    offered: int
    admitted: int
    shed: int
    proc_ms: float
    lat_ms: float
    shed_ratio: float
    late: int = 0   # arrivals behind this pane's start (routed to accountant)


@dataclass
class OverloadMetrics:
    panes: list[PaneMetric] = field(default_factory=list)

    def add(self, m: PaneMetric) -> None:
        self.panes.append(m)

    def percentile(self, q: float, what: str = "lat_ms") -> float:
        if not self.panes:
            return 0.0
        return float(np.percentile([getattr(p, what) for p in self.panes], q))

    def summary(self) -> dict:
        # one pane-list pass per field (the percentile() helper would
        # re-extract the list for every quantile — 5 passes instead of 2)
        panes = self.panes
        offered = sum(p.offered for p in panes)
        admitted = sum(p.admitted for p in panes)
        shed = sum(p.shed for p in panes)
        if panes:
            proc = np.fromiter((p.proc_ms for p in panes), float, len(panes))
            lat = np.fromiter((p.lat_ms for p in panes), float, len(panes))
            mean_ratio = float(np.mean(
                np.fromiter((p.shed_ratio for p in panes), float,
                            len(panes))))
            p50_proc, p99_proc = np.percentile(proc, [50, 99])
            p50_lat, p99_lat, max_lat = np.percentile(lat, [50, 99, 100])
        else:
            mean_ratio = 0.0
            p50_proc = p99_proc = p50_lat = p99_lat = max_lat = 0.0
        return {
            "panes": len(panes),
            "offered": offered,
            "admitted": admitted,
            "shed": shed,
            "shed_frac": shed / offered if offered else 0.0,
            "mean_shed_ratio": mean_ratio,
            "p50_proc_ms": float(p50_proc),
            "p99_proc_ms": float(p99_proc),
            "p50_lat_ms": float(p50_lat),
            "p99_lat_ms": float(p99_lat),
            "max_lat_ms": float(max_lat),
        }


class _GroupDriver:
    """Pane-incremental window-instance state for one group partition."""

    def __init__(self, rt: HamletRuntime, group_key: int, t_now: int):
        self.rt = rt
        self.group_key = group_key
        # shed and admitted panes alike reuse the runtime's batched executor
        # and per-component plan caches
        self.procs = [rt.make_processor(ci) for ci in range(len(rt.ctxs))]
        # insts[component][member] : {window_start: _Instance}
        self.insts: list[list[dict[int, _Instance]]] = []
        for comp, ctx in zip(rt.components, rt.ctxs):
            per: list[dict[int, _Instance]] = []
            for aqi in comp:
                q = rt.workload.atomic[aqi]
                d: dict[int, _Instance] = {}
                # windows opened before this driver existed but still open;
                # their elapsed panes were empty for this group (identity
                # transfer), so fresh state is exact
                w0_min = max(0, ((t_now - q.within) // q.slide + 1) * q.slide)
                for w0 in range(w0_min, t_now, q.slide):
                    d[w0] = _Instance(w0, ctx.layout.fresh_state())
                per.append(d)
            self.insts.append(per)

    def plan(self, pane_ev: EventBatch, mb: PaneMicroBatcher,
             stats: RunStats) -> list:
        """Plan this group's pane across all components into the shared
        micro-batch; returns the pending handles ``apply`` consumes."""
        return [mb.submit(proc, pane_ev, stats) for proc in self.procs]

    def apply(self, pends: list, pane_ev: EventBatch, t0: int, out: dict,
              stats: RunStats) -> None:
        """Finalize + fold this group's pane (after the micro-batch drained)."""
        rt = self.rt
        pane = rt.pane
        obs = rt.obs
        key = (self.group_key, t0) if obs is not None and obs.tracing \
            else None
        fold_t0 = None
        fold_dt = 0.0
        for comp, ctx, pend, per in zip(rt.components, rt.ctxs, pends,
                                        self.insts):
            M = pend.finalize()
            for ci, aqi in enumerate(comp):
                q = rt.workload.atomic[aqi]
                insts = per[ci]
                if t0 % q.slide == 0:
                    insts[t0] = _Instance(t0, ctx.layout.fresh_state())
                needs_minmax = ci in ctx.minmax_queries
                t_fold = time.perf_counter()
                advance_instances(M[ci], insts)
                dt = time.perf_counter() - t_fold
                stats.fold_s += dt
                if fold_t0 is None:
                    fold_t0 = t_fold
                fold_dt += dt
                for w0, inst in list(insts.items()):
                    if needs_minmax and len(pane_ev):
                        inst.events.append(pane_ev)
                    if w0 + q.within == t0 + pane:
                        out[(aqi, self.group_key, w0)] = rt._emit(
                            ctx, ci, q, inst, self.group_key)
                        del insts[w0]
                        stats.windows_emitted += 1
                        if key is not None:
                            obs.lifecycle("emit", key,
                                          args={"w0": w0, "q": aqi})
        if obs is not None and fold_t0 is not None:
            obs.pane_phase("fold", fold_t0, fold_dt, key=key)

    def advance(self, pane_ev: EventBatch, t0: int, out: dict,
                stats: RunStats) -> None:
        """Single-pane convenience: plan, drain, apply."""
        mb = PaneMicroBatcher(self.rt.executor, k=1,
                              fold_exec=self.rt.fold_exec,
                              obs=self.rt.obs)
        pends = self.plan(pane_ev, mb, stats)
        mb.drain()
        self.apply(pends, pane_ev, t0, out, stats)


class OverloadRuntime:
    def __init__(self, workload: Workload, config: OverloadConfig,
                 policy=None, backend: str = "np", clock=time.perf_counter,
                 batch_exec: bool = True, obs=None):
        self.workload = workload
        self.config = config
        self.obs = obs
        self.rt = HamletRuntime(workload, policy=policy, backend=backend,
                                batch_exec=batch_exec,
                                plan_cache=config.plan_cache,
                                fold_exec=config.fold_exec, obs=obs)
        self.pane = self.rt.pane
        self.stats = self.rt.stats
        self.micro_batch = max(1, int(config.micro_batch))
        self.queue = IngressQueue(workload.schema,
                                  capacity=config.queue_capacity,
                                  high_watermark=config.high_watermark,
                                  low_watermark=config.low_watermark)
        self.controller = LatencyController.from_config(config)
        self.shedder = make_shedder(
            config.shed_policy, workload, seed=config.seed,
            min_burst_keep=config.min_burst_keep,
            benefit_model=config.benefit_model)
        self.accountant = ErrorAccountant(workload, pane=self.pane)
        self.metrics = OverloadMetrics()
        self._drivers: dict[int, _GroupDriver] = {}
        self._atomic: dict = {}
        self._t = 0
        self._clock = clock
        self._done_s = 0.0   # completion time on the simulated timeline
        # admitted panes awaiting fused execution (micro_batch > 1)
        self._backlog: list[tuple[int, int, int, int, EventBatch]] = []
        # pipelined flush: one worker thread runs flushes FIFO while the
        # caller polls/admits/sheds the next micro-batch (depth-1 pipeline)
        self._flush_pool = (ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="flush")
            if config.pipeline_flush else None)
        self._flush_fut = None

    # -- producer side --

    def offer(self, batch: EventBatch) -> int:
        """Offer arrivals; honours ingress backpressure.  Returns accepted."""
        return self.queue.offer(batch)

    @property
    def t_now(self) -> int:
        """Pane-clock frontier: panes ``[0, t_now)`` have been admitted and
        shed (execution may still be deferred in the micro-batch backlog)."""
        return self._t

    # -- pane loop --

    def step_pane(self) -> None:
        """Admit, shed, and process the next pane ``[t, t + pane)``.

        The pane loop assumes time order; arrivals that straddled the poll
        frontier (time < t0 — their pane was already processed) cannot be
        folded in here.  They are charged to the error accountant as late,
        unwitnessed shed events so every certificate they could invalidate
        is withdrawn (the event-time layer is the path that *revises* such
        events instead of dropping them)."""
        t0 = self._t
        ev = self.queue.poll_until(t0 + self.pane)
        n_late = 0
        if len(ev) and int(ev.time[0]) < t0:
            stale = np.nonzero(ev.time < t0)[0]
            n_late = len(stale)
            self.accountant.record(ev.select(stale), witnessed=False,
                                   late=True)
            ev = ev.select(np.arange(n_late, len(ev)))
        n = len(ev)

        if self.shedder is None:
            keep_n = n
        else:
            keep_n = int(math.floor(n * (1.0 - self.controller.shed_ratio)
                                    + 1e-9))
            if self.config.pane_budget_events is not None:
                keep_n = min(keep_n, self.config.pane_budget_events)
            keep_n = min(max(keep_n, 0), n)

        if keep_n < n:
            plan = self.shedder.plan(ev, keep_n)
            kept = ev.select(plan.keep)
            self.accountant.record(ev.select(plan.shed),
                                   witnessed=plan.witnessed)
        else:
            kept = ev

        self._backlog.append((t0, n, keep_n, n_late, kept))
        self._t = t0 + self.pane
        if len(self._backlog) >= self.micro_batch:
            self._drain_backlog()

    def flush_panes(self) -> None:
        """Execute any panes still deferred in the processing backlog (and,
        in pipelined mode, wait for the in-flight flush to land)."""
        self._drain_backlog()
        self._await_flush()

    def shutdown(self) -> None:
        """Drain everything and stop the pipelined flush worker (no-op when
        ``pipeline_flush`` is off)."""
        self.flush_panes()
        if self._flush_pool is not None:
            self._flush_pool.shutdown(wait=True)
            self._flush_pool = None

    def _await_flush(self) -> None:
        if self._flush_fut is not None:
            fut, self._flush_fut = self._flush_fut, None
            fut.result()

    def _drain_backlog(self) -> None:
        backlog, self._backlog = self._backlog, []
        if not backlog:
            return
        if self._flush_pool is not None:
            # depth-1 pipeline: wait for flush N-1, then hand flush N to the
            # worker and return — the caller overlaps its host-side staging
            # (poll, admission, shedding) with this flush's execution
            self._await_flush()
            self._flush_fut = self._flush_pool.submit(self._flush_one,
                                                      backlog)
            return
        self._flush_one(backlog)

    def _flush_one(self, backlog: list) -> None:
        c0 = self._clock()
        if len(backlog) == 1:
            t0, _n, _keep, _late, kept = backlog[0]
            self._process(kept, t0)
        else:
            self._process_batch([(t0, kept)
                                 for t0, _n, _k, _l, kept in backlog])
        # the controller acts on pane-processing time (the directly
        # controllable quantity), amortized across the fused micro-batch;
        # end-to-end latency is reported alongside
        proc_s = (self._clock() - c0) / len(backlog)
        obs = self.obs
        for t0, n, keep_n, n_late, kept in backlog:
            lat_ms = self._latency_ms(t0, proc_s)
            self.controller.update(proc_s * 1e3)
            self.metrics.add(PaneMetric(
                t0=t0, offered=n, admitted=len(kept), shed=n - keep_n,
                proc_ms=proc_s * 1e3, lat_ms=lat_ms,
                shed_ratio=self.controller.shed_ratio, late=n_late))
            if obs is not None:
                obs.observe("overload.pane_proc_ms", proc_s * 1e3,
                            LATENCY_MS_BUCKETS)
                obs.observe("overload.pane_shed_lat_ms", lat_ms,
                            LATENCY_MS_BUCKETS)
                obs.set_gauge("overload.shed_ratio",
                              self.controller.shed_ratio)
                if n > keep_n:
                    obs.count("overload.shed_events", n - keep_n)

    def _process(self, kept: EventBatch, t0: int) -> None:
        """Process one admitted pane through the group drivers."""
        self._process_batch([(t0, kept)])

    def _process_batch(self, panes: list[tuple[int, EventBatch]]) -> None:
        """Fused execution of K admitted panes: plan every (pane, group,
        component) into one micro-batch, drain once — one launch per size
        bucket per K panes — then finalize and fold in stream order."""
        mb = PaneMicroBatcher(self.rt.executor, k=len(panes),
                              fold_exec=self.rt.fold_exec, obs=self.rt.obs)
        planned: list = []
        for t0, kept in panes:
            parts = kept.partition_by_group() if len(kept) else {}
            for g in parts:
                if g not in self._drivers:
                    self._drivers[g] = _GroupDriver(self.rt, int(g), t0)
            empty = self._empty()
            planned.append([
                (drv, parts.get(g, empty), drv.plan(parts.get(g, empty),
                                                    mb, self.stats))
                for g, drv in self._drivers.items()])
        mb.drain()
        for (t0, _kept), per in zip(panes, planned):
            for drv, pane_ev, pends in per:
                drv.apply(pends, pane_ev, t0, self._atomic, self.stats)

    def _latency_ms(self, t0: int, proc_s: float) -> float:
        ts = self.config.tick_seconds
        if ts is None:
            return proc_s * 1e3
        # sequential server on the arrival timeline: work queues behind the
        # previous pane's completion, so backlog shows up as latency
        arrival_end = (t0 + self.pane) * ts
        self._done_s = max(self._done_s, arrival_end) + proc_s
        return (self._done_s - arrival_end) * 1e3

    def _empty(self) -> EventBatch:
        return EventBatch(self.workload.schema, np.array([], np.int32),
                          np.array([], np.int64), None)

    # -- results --

    def results(self) -> dict:
        """User-query results for every window closed so far (drains any
        deferred micro-batch first)."""
        self.flush_panes()
        return combine_results(self.workload, self._atomic)

    def run(self, batch: EventBatch, t_end: int | None = None) -> dict:
        """Convenience driver: feed ``batch`` pane-by-pane in arrival order
        and process through ``t_end`` (rounded up to a pane boundary)."""
        if t_end is None:
            t_end = int(batch.time.max()) + 1 if len(batch) else 0
        t_end = ((t_end + self.pane - 1) // self.pane) * self.pane
        for t0 in range(self._t, t_end, self.pane):
            self.offer(batch.time_slice(t0, t0 + self.pane))
            self.step_pane()
        return self.results()
