"""Bounded ingress queue with watermark-based backpressure.

The queue sits between producers and the pane loop.  It is bounded in event
count; crossing the high watermark flips ``accepting`` off (the backpressure
signal a producer should honour — offers made while not accepting are counted
as ``rejected`` and dropped, since this process cannot block a remote
producer), and draining below the low watermark flips it back on.  Offers that
would overflow the hard capacity are truncated and counted as ``dropped``.

Events inside one offered batch are time-ordered (``EventBatch`` enforces it),
but producers do **not** necessarily feed batches in global time order —
retried producers and clock-skewed sources interleave.  The queue therefore
guards the order assumption instead of silently relying on it: an offer that
starts before the buffered tail marks the buffer disordered (``poll_until``
then re-sorts before splitting, so its contract — every buffered event with
``time < t``, time-sorted — always holds), and events that *straddle* the
poll frontier (arrive with a timestamp older than the last ``poll_until``
boundary, so their pane has already been handed out) are counted in
``straddled_late`` and still delivered on the next poll; the consumer decides
whether to revise them in (the event-time layer) or charge them to the
shedding accountant (the plain pane loop).

The queue is safe under **concurrent producers**: every state transition
(offer, poll, the backpressure flips) happens under one internal lock, so
any number of session threads may ``offer`` while a single consumer polls.
The consumer side stays single-threaded by contract (the pane loop owns the
poll frontier); concurrent *pollers* would race the frontier semantics, not
the data structure.
"""

from __future__ import annotations

import threading

import numpy as np

from ..core.events import EventBatch, StreamSchema

__all__ = ["IngressQueue"]


class IngressQueue:
    def __init__(self, schema: StreamSchema, capacity: int = 1 << 16,
                 high_watermark: float = 0.75, low_watermark: float = 0.5):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.schema = schema
        self.capacity = int(capacity)
        self.high = int(np.ceil(high_watermark * capacity))
        self.low = int(np.floor(low_watermark * capacity))
        self.accepting = True
        self.rejected = 0        # offered while backpressure was asserted
        self.dropped = 0         # truncated against the hard capacity
        self.straddled_late = 0  # offered with time < the last poll boundary
        self._batches: list[EventBatch] = []
        self._n = 0
        self._tail_time = -(1 << 62)    # max buffered timestamp
        self._polled_until = -(1 << 62)  # last poll_until boundary
        self._disordered = False
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return self._n

    def headroom(self) -> int:
        """Events admissible before the high watermark flips ``accepting``
        off — the budget a credit-granting transport may hand to producers
        without ever tripping queue-side backpressure (0 when already
        at/above the high watermark)."""
        with self._lock:
            return max(0, self.high - self._n)

    def offer(self, batch: EventBatch) -> int:
        """Enqueue as much of ``batch`` as admission allows; returns accepted
        event count and updates the backpressure state.  Safe to call from
        any number of producer threads concurrently."""
        n = len(batch)
        if n == 0:
            return 0
        with self._lock:
            if not self.accepting:
                self.rejected += n
                return 0
            space = self.capacity - self._n
            take = min(n, space)
            if take < n:
                self.dropped += n - take
            if take > 0:
                b = batch if take == n else batch.select(np.arange(take))
                # straddle guard: an offer reaching behind the buffered tail
                # or the poll frontier breaks the global-order assumption —
                # flag it instead of letting searchsorted split a non-sorted
                # buffer
                if int(b.time[0]) < self._tail_time:
                    self._disordered = True
                self.straddled_late += int(np.sum(b.time
                                                  < self._polled_until))
                self._tail_time = max(self._tail_time, int(b.time[-1]))
                self._batches.append(b)
                self._n += take
            if self._n >= self.high:
                self.accepting = False
            return take

    def poll_until(self, t_exclusive: int) -> EventBatch:
        """Dequeue every buffered event with ``time < t_exclusive``."""
        with self._lock:
            self._polled_until = max(self._polled_until, int(t_exclusive))
            if self._n == 0:
                return self._empty()
            if self._disordered:
                merged = EventBatch.merge(self._batches)
                self._disordered = False
            else:
                merged = (self._batches[0] if len(self._batches) == 1
                          else EventBatch.concat(self._batches))
            hi = int(np.searchsorted(merged.time, t_exclusive, side="left"))
            out = merged.select(np.arange(hi))
            rest = merged.select(np.arange(hi, len(merged)))
            self._batches = [rest] if len(rest) else []
            self._n = len(rest)
            if self._n <= self.low:
                self.accepting = True
            return out

    def _empty(self) -> EventBatch:
        return EventBatch(self.schema, np.array([], np.int32),
                          np.array([], np.int64), None)
