"""Bounded ingress queue with watermark-based backpressure.

The queue sits between producers and the pane loop.  It is bounded in event
count; crossing the high watermark flips ``accepting`` off (the backpressure
signal a producer should honour — offers made while not accepting are counted
as ``rejected`` and dropped, since this process cannot block a remote
producer), and draining below the low watermark flips it back on.  Offers that
would overflow the hard capacity are truncated and counted as ``dropped``.

Events inside one offered batch are time-ordered (``EventBatch`` enforces it)
and producers feed in arrival order, so the buffer stays globally ordered and
``poll_until`` is a simple split.
"""

from __future__ import annotations

import numpy as np

from ..core.events import EventBatch, StreamSchema

__all__ = ["IngressQueue"]


class IngressQueue:
    def __init__(self, schema: StreamSchema, capacity: int = 1 << 16,
                 high_watermark: float = 0.75, low_watermark: float = 0.5):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.schema = schema
        self.capacity = int(capacity)
        self.high = int(np.ceil(high_watermark * capacity))
        self.low = int(np.floor(low_watermark * capacity))
        self.accepting = True
        self.rejected = 0        # offered while backpressure was asserted
        self.dropped = 0         # truncated against the hard capacity
        self._batches: list[EventBatch] = []
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def offer(self, batch: EventBatch) -> int:
        """Enqueue as much of ``batch`` as admission allows; returns accepted
        event count and updates the backpressure state."""
        n = len(batch)
        if n == 0:
            return 0
        if not self.accepting:
            self.rejected += n
            return 0
        space = self.capacity - self._n
        take = min(n, space)
        if take < n:
            self.dropped += n - take
        if take > 0:
            b = batch if take == n else batch.select(np.arange(take))
            self._batches.append(b)
            self._n += take
        if self._n >= self.high:
            self.accepting = False
        return take

    def poll_until(self, t_exclusive: int) -> EventBatch:
        """Dequeue every buffered event with ``time < t_exclusive``."""
        if self._n == 0:
            return self._empty()
        merged = (self._batches[0] if len(self._batches) == 1
                  else EventBatch.concat(self._batches))
        hi = int(np.searchsorted(merged.time, t_exclusive, side="left"))
        out = merged.select(np.arange(hi))
        rest = merged.select(np.arange(hi, len(merged)))
        self._batches = [rest] if len(rest) else []
        self._n = len(rest)
        if self._n <= self.low:
            self.accepting = True
        return out

    def _empty(self) -> EventBatch:
        return EventBatch(self.schema, np.array([], np.int32),
                          np.array([], np.int64), None)
