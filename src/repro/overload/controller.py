"""PID-style latency controller: observed pane latency -> shed ratio.

Position-form PI(D) on the relative latency error ``(latency - slo) / slo``.
The proportional term reacts to bursts within a pane or two; the integral
trims the steady-state shed ratio to exactly match sustained overload
(converging to ``1 - capacity/offered``, where the P-only ratio would leave a
standing error).  The plant gain scales with the overload factor — processing
time moves by ``offered/capacity · slo`` per unit of shed ratio — so the
default gains keep the discrete loop stable up to ~10x overload; a hotter
loop limit-cycles between shedding nothing and shedding everything.
Anti-windup: the integrator is clamped to the actuator range and frozen while
the output is saturated in the direction of the error.

Disorder-aware admission control: out-of-order streams add a cost axis pane
latency alone cannot see — every straggler behind the emitted frontier
re-plans its pane and re-folds the covering windows, and under a revision
storm that replay work crowds out fresh panes *before* per-pane latency
degrades (revisions run outside the admission path).  ``kr`` folds the
observed revision load (revisions per emitted window, supplied by the caller
that owns the event-time layer) into the same error signal, so the shed
ratio rises with disorder pressure as well as latency pressure and the
integrator trims against their sum.
"""

from __future__ import annotations

__all__ = ["LatencyController"]


def _clip(x: float, lo: float, hi: float) -> float:
    return lo if x < lo else hi if x > hi else x


class LatencyController:
    def __init__(self, slo_ms: float, kp: float = 0.1, ki: float = 0.05,
                 kd: float = 0.0, kr: float = 0.0, max_shed: float = 0.98,
                 fixed: float | None = None):
        if slo_ms <= 0:
            raise ValueError("slo_ms must be positive")
        self.slo_ms = float(slo_ms)
        self.kp, self.ki, self.kd = kp, ki, kd
        self.kr = float(kr)
        self.max_shed = float(max_shed)
        self.fixed = fixed
        self.shed_ratio = fixed if fixed is not None else 0.0
        self._i = 0.0
        self._prev_e: float | None = None
        self.updates = 0

    @classmethod
    def from_config(cls, cfg) -> "LatencyController":
        return cls(cfg.slo_ms, kp=cfg.kp, ki=cfg.ki, kd=cfg.kd,
                   kr=getattr(cfg, "kr", 0.0), max_shed=cfg.max_shed,
                   fixed=cfg.fixed_shed)

    def state(self) -> dict:
        """Control-loop state export for a supervising controller.

        The sharded service's router reads this per shard to actuate
        admission *upstream* of the ingress queues: ``shed_ratio`` is the
        actuator value, ``integrator``/``last_error`` expose how much of it
        is steady-state trim vs transient, ``saturated`` flags a shard whose
        controller is pinned at ``max_shed`` (shedding alone can no longer
        meet the SLO there — a rebalance candidate)."""
        return {
            "shed_ratio": self.shed_ratio,
            "integrator": self._i,
            "last_error": self._prev_e,
            "updates": self.updates,
            "slo_ms": self.slo_ms,
            "fixed": self.fixed,
            "saturated": self.fixed is None
            and self.shed_ratio >= self.max_shed,
        }

    def update(self, latency_ms: float,
               revision_load: float = 0.0) -> float:
        """Feed one latency observation (plus the optional revision-load
        observation, revisions per emitted window since the last update);
        returns the new shed ratio."""
        self.updates += 1
        if self.fixed is not None:
            return self.shed_ratio
        e = ((latency_ms - self.slo_ms) / self.slo_ms
             + self.kr * max(0.0, revision_load))
        d = 0.0 if self._prev_e is None else e - self._prev_e
        self._prev_e = e
        raw = self.kp * e + self._i + self.ki * e + self.kd * d
        saturated_up = raw >= self.max_shed and e > 0
        saturated_dn = raw <= 0.0 and e < 0
        if not (saturated_up or saturated_dn):
            self._i = _clip(self._i + self.ki * e, 0.0, self.max_shed)
        self.shed_ratio = _clip(self.kp * e + self._i + self.kd * d,
                                0.0, self.max_shed)
        return self.shed_ratio
