"""Configuration for the event-time subsystem.

One dataclass gathers the knobs of the out-of-order layer: which watermark
policy seals panes, how far past the watermark a straggler may land and still
be *revised* into its pane (the lateness horizon), and whether panes are
executed speculatively on arrival (emit-then-amend) or buffered until the
watermark seals them (emit-once).
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["EventTimeConfig"]

_POLICIES = ("bounded_skew", "percentile", "group_heartbeat")


@dataclass
class EventTimeConfig:
    """Opt-in event-time processing: reordering, watermarks, revision.

    watermark          "bounded_skew" | "percentile" | "group_heartbeat"
    skew               bounded-skew allowance (ticks): the watermark trails
                       the max seen timestamp by this much.  Also the floor
                       skew of the adaptive policies
    percentile         for "percentile": the observed-lateness percentile the
                       adaptive skew tracks
    percentile_window  for "percentile": ring-buffer size of lateness samples
    max_skew           ceiling on the adaptive skew (None = unbounded)
    idle_timeout       for "group_heartbeat": a group whose frontier trails
                       the global max by more than this stops holding the
                       watermark back (None = silent groups hold it forever;
                       send heartbeats to advance)
    max_retained_panes caps, per group partition, how many panes retain
                       their raw events for revision (bounded revision
                       memory).  When the cap is exceeded the *oldest*
                       retained panes are evicted: the pane is executed if
                       it has not been yet, its transfer matrices are kept
                       (emission and re-folds of *other* panes stay exact),
                       but its raw ``EventBatch`` is dropped — the evicted
                       events are expired into the shedding accountant
                       (``late_events``; bound certificates withdrawn) and
                       any later straggler landing in an evicted pane is
                       expired instead of absorbed.  MIN/MAX aggregates of
                       still-revisable windows covering an evicted pane lose
                       that pane's events.  None = retain for the whole
                       lateness horizon
    lateness_horizon   bounds how long pane state is retained for revision.
                       The speculative runtime expires an event only once
                       its pane has been *retired* (no still-revisable
                       window covers it: ``watermark - horizon -
                       max(within)`` behind); the reorder buffer expires
                       once an event is both behind the sealed frontier and
                       ``horizon`` behind the watermark.  Expired events are
                       counted and, when an accountant is attached, charged
                       as shed so the ``true <= 3^s * emitted`` story stays
                       sound.  None = never expire; revision depth is then
                       bounded only by what the consumer retains
                       (``HamletService`` retains — and therefore revises —
                       at most max(within) behind its emitted frontier)
    speculative        True: execute panes optimistically on arrival, emit as
                       soon as the stream frontier passes a window, amend on
                       late data.  False: buffer-everything baseline — emit a
                       window only once the watermark seals its last pane
    """

    watermark: str = "bounded_skew"
    skew: int = 8
    percentile: float = 95.0
    percentile_window: int = 256
    max_skew: int | None = None
    idle_timeout: int | None = None
    max_retained_panes: int | None = None
    lateness_horizon: int | None = None
    speculative: bool = True

    def __post_init__(self) -> None:
        if self.watermark not in _POLICIES:
            raise ValueError(f"unknown watermark policy {self.watermark!r}; "
                             f"have {_POLICIES}")
        if self.skew < 0:
            raise ValueError("skew must be non-negative")
        if not (0.0 < self.percentile <= 100.0):
            raise ValueError("percentile must be in (0, 100]")
        if self.lateness_horizon is not None and self.lateness_horizon < 0:
            raise ValueError("lateness_horizon must be non-negative")
        if self.max_retained_panes is not None and self.max_retained_panes < 1:
            raise ValueError("max_retained_panes must be >= 1")
