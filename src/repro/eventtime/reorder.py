"""Reorder buffer: disordered arrivals in, watermark-sealed panes out.

The buffer accepts event chunks in *arrival* order (timestamps arbitrary),
holds them until the watermark policy promises no earlier event can still
arrive, and releases **contiguous, time-sorted panes** — including empty
panes for gaps, so the consumer's window clock always advances pane by pane.

Arrivals behind the already-sealed frontier cannot be buffered (their pane
has been released); they come back in :attr:`ReorderResult.late` and the
caller decides — the speculative runtime revises them into their pane, the
buffer-everything baseline and the overload path charge them to the shedding
accountant.  When a ``lateness_horizon`` is set, events more than that many
ticks behind the watermark are split off into :attr:`ReorderResult.expired`
directly (the principled shed class for hopeless stragglers).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.events import EventBatch, StreamSchema
from .watermark import WatermarkPolicy

__all__ = ["ReorderBuffer", "ReorderResult", "SealedPane"]


@dataclass(frozen=True)
class SealedPane:
    t0: int
    events: EventBatch       # time-sorted, all inside [t0, t0 + pane)


@dataclass
class ReorderResult:
    sealed: list[SealedPane] = field(default_factory=list)
    late: EventBatch | None = None      # behind the sealed frontier, in horizon
    expired: EventBatch | None = None   # behind watermark - lateness_horizon

    @property
    def n_late(self) -> int:
        return 0 if self.late is None else len(self.late)

    @property
    def n_expired(self) -> int:
        return 0 if self.expired is None else len(self.expired)


class ReorderBuffer:
    def __init__(self, schema: StreamSchema, pane: int,
                 policy: WatermarkPolicy, lateness_horizon: int | None = None):
        if pane <= 0:
            raise ValueError("pane must be positive")
        self.schema = schema
        self.pane = int(pane)
        self.policy = policy
        self.lateness_horizon = lateness_horizon
        self._pending: list[EventBatch] = []
        self._n_pending = 0
        self._sealed_end = 0          # panes [0, _sealed_end) are released
        self.late_total = 0
        self.expired_total = 0

    def __len__(self) -> int:
        return self._n_pending

    @property
    def watermark(self) -> int:
        return self.policy.watermark()

    @property
    def sealed_end(self) -> int:
        return self._sealed_end

    def heartbeat(self, group: int, t: int) -> "ReorderResult":
        """Per-group liveness signal; may advance the watermark and seal."""
        self.policy.heartbeat(group, t)
        return self._seal(ReorderResult())

    def push(self, chunk: EventBatch) -> ReorderResult:
        """Feed an arrival chunk (internally time-sorted; build disordered
        wire chunks with :meth:`EventBatch.from_unsorted`)."""
        res = ReorderResult()
        if len(chunk):
            # lateness is judged against the watermark as it stood *before*
            # this chunk was observed — a chunk must never expire its own
            # (perfectly orderly) events just because it advanced the clock
            wm_before = self.policy.watermark()
            self.policy.observe(chunk.time, chunk.group)
            late_mask = chunk.time < self._sealed_end
            if self.lateness_horizon is not None:
                # only already-late events can expire; a fresh event's pane
                # is still open, so dropping it would be plain data loss
                exp_mask = late_mask & (
                    chunk.time < wm_before - self.lateness_horizon)
                if exp_mask.any():
                    res.expired = chunk.select(np.nonzero(exp_mask)[0])
                    self.expired_total += len(res.expired)
                late_mask &= ~exp_mask
            if late_mask.any():
                res.late = chunk.select(np.nonzero(late_mask)[0])
                self.late_total += len(res.late)
            fresh_mask = chunk.time >= self._sealed_end
            if fresh_mask.any():
                fresh = chunk.select(np.nonzero(fresh_mask)[0])
                self._pending.append(fresh)
                self._n_pending += len(fresh)
        return self._seal(res)

    def flush(self) -> ReorderResult:
        """Seal everything pending (stream end)."""
        res = ReorderResult()
        if self._n_pending:
            end = int(max(int(b.time.max()) for b in self._pending)) + 1
            end = -(-end // self.pane) * self.pane
            self._release(res, end)
        return res

    # -- internals --

    def _seal(self, res: ReorderResult) -> ReorderResult:
        wm = self.policy.watermark()
        # pane [t0, t0+pane) is final once no event with time <= t0+pane-1
        # can still arrive, i.e. wm >= t0 + pane - 1
        end = ((wm + 1) // self.pane) * self.pane
        if end > self._sealed_end:
            self._release(res, end)
        return res

    def _release(self, res: ReorderResult, end: int) -> None:
        merged = (EventBatch.merge(self._pending) if self._pending
                  else self._empty())
        cut = int(np.searchsorted(merged.time, end, side="left"))
        out = merged.select(np.arange(cut))
        rest = merged.select(np.arange(cut, len(merged)))
        self._pending = [rest] if len(rest) else []
        self._n_pending = len(rest)
        for t0 in range(self._sealed_end, end, self.pane):
            res.sealed.append(SealedPane(t0, out.time_slice(t0, t0 + self.pane)))
        self._sealed_end = end

    def _empty(self) -> EventBatch:
        return EventBatch(self.schema, np.array([], np.int32),
                          np.array([], np.int64), None)
