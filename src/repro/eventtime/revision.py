"""Speculative execution + snapshot-based pane revision.

:class:`EventTimeRuntime` is the pane-granular out-of-order runtime.  It
drives the HAMLET plan-then-execute machinery (:class:`PaneProcessor`)
*optimistically*: a pane is executed as soon as any of its events arrive, and
its per-query transfer matrix ``M`` (the pane's fold state — a linear map
over the window state channels, see ``core/engine.py``) is stored.  A window
is **emitted speculatively** once the stream frontier passes its close time —
long before the watermark certifies the window complete.

A late event that lands in an already-executed pane triggers *revision*:

* the dirty pane is **re-planned** through the same plan-then-execute
  pipeline over its merged event set — one pane's graphlets, one bucketed
  batched launch, not a from-scratch rerun of the stream;
* every already-emitted window covering that pane is **re-folded** from the
  stored transfer matrices: the clean panes' ``M`` are reused as-is, only
  the dirty pane contributes new work — and all dirty windows of a
  revision storm fold together as one stacked launch set through the
  runtime's :class:`~repro.core.fold_exec.FoldExecutor`
  (:meth:`~repro.core.fold_exec.FoldExecutor.fold_windows`, the batched
  twin of :func:`~repro.core.engine.fold_panes`);
* windows whose value changed produce a ``retract`` record (the superseded
  value) followed by an ``amend`` record (the new value) on the output
  channel — changelog semantics a downstream sink can apply idempotently.

An event is *expired* only when its pane state has been retired — once no
still-revisable window covers the pane (``watermark - lateness_horizon -
max(within)`` behind); anything landing in a live pane is absorbed exactly,
however late.  ``max_retained_panes`` additionally bounds revision *memory*:
beyond the per-group cap the oldest panes are evicted — their transfer
matrices survive (emission and re-folds of other panes stay exact) but the
raw events are expired into the accountant and later stragglers into them
expire too.  Expired events are counted, never folded in, and — when an
:class:`ErrorAccountant` is attached — charged as (unwitnessed) shed events,
so the overload subsystem's ``true <= 3^s * emitted`` accounting stays sound
under disorder.

With ``speculative=False`` the runtime degrades to the buffer-everything
baseline: arrivals sit in a :class:`ReorderBuffer` and a window is emitted
exactly once, after the watermark seals its last pane.  ``fig_disorder``
measures the emission-latency gap between the two modes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from time import perf_counter

import numpy as np

from ..core.engine import (HamletRuntime, PaneMicroBatcher, PaneProcessor,
                           _Instance, fold_panes, vals_equal)
from ..core.events import EventBatch
from ..core.query import Workload
from ..obs.metrics import DEPTH_BUCKETS, LAG_BUCKETS
from .config import EventTimeConfig
from .reorder import ReorderBuffer
from .watermark import WM_MIN, make_watermark

__all__ = ["EventTimeRuntime", "EventTimeMetrics", "EmissionRecord"]


@dataclass(frozen=True)
class EmissionRecord:
    """One entry on the output channel.

    kind        "emit" (first value for this window), "retract" (withdraws
                the previous value), or "amend" (the replacement value —
                always immediately preceded by its retract)
    query       atomic query name (user-level Or/And combination is applied
                by :meth:`EventTimeRuntime.results`)
    group       group partition key
    w0          window start (ticks)
    vals        aggregate values ({repr(agg): value})
    revision    0 for the first emission, incremented per amendment
    speculative True when emitted past the frontier but before the watermark
                sealed the window (the value may still be amended)
    """

    kind: str
    query: str
    group: int
    w0: int
    vals: dict | None
    revision: int
    speculative: bool = False


@dataclass
class EventTimeMetrics:
    ingested: int = 0
    expired: int = 0
    evicted_panes: int = 0       # bounded revision memory (max_retained_panes)
    panes_executed: int = 0
    panes_revised: int = 0
    windows_emitted: int = 0
    speculative_emits: int = 0
    amendments: int = 0
    retractions: int = 0
    noop_revisions: int = 0      # re-folds whose value did not change
    emit_lag: list = field(default_factory=list)  # stream progress past close

    def lag_percentile(self, q: float) -> float:
        if not self.emit_lag:
            return 0.0
        return float(np.percentile(self.emit_lag, q))

    def summary(self) -> dict:
        return {
            "ingested": self.ingested,
            "expired": self.expired,
            "evicted_panes": self.evicted_panes,
            "panes_executed": self.panes_executed,
            "panes_revised": self.panes_revised,
            "windows_emitted": self.windows_emitted,
            "speculative_emits": self.speculative_emits,
            "amendments": self.amendments,
            "retractions": self.retractions,
            "noop_revisions": self.noop_revisions,
            "revision_rate": (self.amendments / self.windows_emitted
                              if self.windows_emitted else 0.0),
            "p50_emit_lag": self.lag_percentile(50),
            "p99_emit_lag": self.lag_percentile(99),
        }


@dataclass
class _PaneState:
    events: EventBatch
    M: list[np.ndarray] | None = None    # per component: [k, C, C]
    evicted: bool = False                # events dropped (bounded memory)


class EventTimeRuntime:
    def __init__(self, workload: Workload, config: EventTimeConfig,
                 policy=None, backend: str = "np", batch_exec: bool = True,
                 accountant=None, micro_batch: int = 1,
                 plan_cache: bool = True, fold_exec: bool = True, obs=None):
        self.workload = workload
        self.config = config
        self.obs = obs
        self.micro_batch = max(1, int(micro_batch))
        self.rt = HamletRuntime(workload, policy=policy, backend=backend,
                                batch_exec=batch_exec, plan_cache=plan_cache,
                                fold_exec=fold_exec, obs=obs)
        self.pane = self.rt.pane
        self.stats = self.rt.stats
        self.metrics = EventTimeMetrics()
        self.accountant = accountant
        self.wm = make_watermark(config)
        self.max_within = max((q.within for q in workload.atomic), default=1)
        self._buffer = (None if config.speculative else ReorderBuffer(
            workload.schema, self.pane, self.wm,
            lateness_horizon=config.lateness_horizon))
        # per group: pane states, one PaneProcessor per component
        self._panes: dict[int, dict[int, _PaneState]] = {}
        self._procs: dict[int, list[PaneProcessor]] = {}
        self._frontier = WM_MIN
        self._atomic: dict[tuple[int, int, int], dict] = {}
        self._revno: dict[tuple[int, int, int], int] = {}
        self._next_w0: dict[tuple[int, int], int] = {}
        # bounded revision memory: (group, t0) eviction log, oldest first
        # (itself bounded — metrics.evicted_panes carries the full count)
        self.evictions: list[tuple[int, int]] = []
        self._evictions_keep = 4096

    # -- producer side -----------------------------------------------------

    def ingest(self, chunk: EventBatch) -> list[EmissionRecord]:
        """Feed an arrival chunk (build disordered chunks with
        :meth:`EventBatch.from_unsorted`); returns new emission records."""
        self.metrics.ingested += len(chunk)
        if len(chunk):
            # arrival frontier: max event time seen, regardless of mode —
            # emission lag is measured against it in both modes
            self._frontier = max(self._frontier, int(chunk.time.max()))
        if self._buffer is not None:
            return self._ingest_sealed(self._buffer.push(chunk))
        records: list[EmissionRecord] = []
        if len(chunk):
            # expiry is judged against the watermark *before* this chunk
            # advanced it — a chunk never expires its own orderly events
            wm_before = self.wm.watermark()
            self.wm.observe(chunk.time, chunk.group)
            if self.obs is not None:
                wm = self.wm.watermark()
                if wm > WM_MIN:
                    self.obs.observe("eventtime.watermark_lag",
                                     max(0, self._frontier - wm),
                                     LAG_BUCKETS)
            chunk = self._route_expired(chunk, wm_before)
        if len(chunk):
            dirty = self._absorb(chunk)
            records += self._revise(dirty)
        # speculative boundary: a window is emitted once an event *past* its
        # close has been seen — an in-order stream therefore never amends
        records += self._emit_ready(self._frontier)
        self._retire()
        return records

    def heartbeat(self, group: int, t: int) -> list[EmissionRecord]:
        """Group liveness signal (only the group_heartbeat policy reacts)."""
        if self._buffer is not None:
            return self._ingest_sealed(self._buffer.heartbeat(group, t))
        self.wm.heartbeat(group, t)
        return self._emit_ready(self._frontier)

    def flush(self, t_end: int | None = None) -> list[EmissionRecord]:
        """Stream end: emit every window closing inside [0, t_end), default
        the frontier rounded up to a pane — matching ``HamletRuntime.run``'s
        window set for the same ``t_end``.  An explicit ``t_end`` is honoured
        both ways: beyond the frontier it extends emission over the empty
        tail, below it it truncates flush-time emission (windows already
        emitted speculatively during streaming are never withdrawn)."""
        if self._buffer is not None:
            res = self._buffer.flush()
            records = self._ingest_sealed(res, emit=False)
            end = self._buffer.sealed_end
        else:
            records = []
            end = max(self._frontier + 1, 0)
        if t_end is not None:
            end = t_end
        end = -(-end // self.pane) * self.pane
        records += self._emit_ready(end, final=True)
        return records

    # -- consumer side -----------------------------------------------------

    def results(self) -> dict:
        """Current (post-revision) values of every emitted window, combined
        to user queries — comparable against ``HamletRuntime.run``."""
        from ..core.engine import combine_results

        return combine_results(self.workload, self._atomic)

    @property
    def watermark(self) -> int:
        return self.wm.watermark()

    # -- internals ---------------------------------------------------------

    def _route_expired(self, chunk: EventBatch, wm_before: int
                       ) -> EventBatch:
        """Split off events whose pane state has been retired.

        Expiry mirrors :meth:`_retire` exactly: an event is hopeless iff its
        pane was dropped (t0 + max_within behind watermark - horizon), since
        folding into a partial, rebuilt pane would corrupt final windows.
        Any event whose pane is still live is absorbed — even when it is
        more than ``lateness_horizon`` behind the watermark — because
        absorption into retained state is always exact; the horizon bounds
        *state retention*, it is not a license to drop revisable data."""
        if self.config.lateness_horizon is None:
            return chunk
        bound = wm_before - self.config.lateness_horizon
        pane_t0 = (chunk.time // self.pane) * self.pane
        mask = pane_t0 + self.max_within <= bound   # = _retire's condition
        if not mask.any():
            return chunk
        expired = chunk.select(np.nonzero(mask)[0])
        self.metrics.expired += len(expired)
        if self.accountant is not None:
            self.accountant.record(expired, witnessed=False, late=True)
        return chunk.select(np.nonzero(~mask)[0])

    def _group_procs(self, g: int) -> list[PaneProcessor]:
        if g not in self._procs:
            rt = self.rt
            # shared executor + per-component plan caches: a pane shape
            # learned on one group partition is reused on all of them
            self._procs[g] = [rt.make_processor(ci)
                              for ci in range(len(rt.ctxs))]
            self._panes[g] = {}
        return self._procs[g]

    def _prefetch(self, jobs: list) -> None:
        """Cross-pane fused execution: plan the given ``(group, pane-state)``
        pairs in first-touch order — identical to the order the lazy
        :meth:`_ensure_executed` walk would execute them, so sharing
        decisions and results stay bitwise reproducible — and flush the
        propagation backlog once per ``micro_batch`` panes."""
        if self.micro_batch <= 1 or not jobs:
            return
        mb = PaneMicroBatcher(self.rt.executor, k=self.micro_batch,
                              fold_exec=self.rt.fold_exec, obs=self.rt.obs)
        batch: list = []
        seen: set[int] = set()

        def drain():
            for ps, pends in batch:
                ps.M = [p.finalize() for p in pends]
                self.metrics.panes_executed += 1
            batch.clear()

        for g, ps in jobs:
            if ps.M is not None or id(ps) in seen:
                continue
            seen.add(id(ps))
            batch.append((ps, [mb.submit(proc, ps.events, self.stats)
                               for proc in self._procs[g]]))
            if len(batch) >= self.micro_batch:
                mb.drain()
                drain()
        mb.drain()
        drain()

    def _absorb(self, chunk: EventBatch) -> list[tuple[int, int]]:
        """Merge a chunk into per-(group, pane) state and mark the panes
        dirty.  Returns every touched (group, t0) — a *new* pane can also
        dirty already-emitted windows when the frontier raced ahead of it.

        Execution is lazy (:meth:`_ensure_executed`): a pane whose events
        arrive over several wire chunks is planned once, at the first
        emission or revision that folds it, not once per chunk."""
        dirty: list[tuple[int, int]] = []
        # canonicalize tie order up front: wire chunks are stable-sorted by
        # arrival, but pane content must follow the producer's (time, seq)
        # total order even when one chunk covers a whole pane and no merge
        # with prior state would have re-sorted it
        chunk = EventBatch.merge([chunk])
        for g, gb in chunk.partition_by_group().items():
            self._group_procs(g)
            panes = self._panes[g]
            pids = gb.time // self.pane
            for p in np.unique(pids):
                t0 = int(p) * self.pane
                sub = gb.select(np.nonzero(pids == p)[0])
                ps = panes.get(t0)
                if ps is None:
                    panes[t0] = _PaneState(events=sub)
                elif ps.evicted:
                    # bounded revision memory: the pane's raw events are
                    # gone, so a merge would rebuild a partial pane and
                    # corrupt final windows — expire the straggler instead
                    self.metrics.expired += len(sub)
                    if self.accountant is not None:
                        self.accountant.record(sub, witnessed=False,
                                               late=True)
                    continue
                else:
                    ps.events = EventBatch.merge([ps.events, sub])
                    ps.M = None
                dirty.append((g, t0))
        return dirty

    def _ensure_executed(self, g: int, ps: _PaneState) -> list[np.ndarray]:
        if ps.M is None:
            ps.M = [proc.process(ps.events, self.stats)
                    for proc in self._procs[g]]
            self.metrics.panes_executed += 1
        return ps.M

    def _ingest_sealed(self, res, emit: bool = True) -> list[EmissionRecord]:
        """Baseline path: sealed panes from the reorder buffer are executed
        in order; late/expired arrivals cannot be revised here and are all
        charged as expired."""
        for batch in (res.late, res.expired):
            if batch is not None and len(batch):
                self.metrics.expired += len(batch)
                if self.accountant is not None:
                    self.accountant.record(batch, witnessed=False, late=True)
        sealed_jobs: list = []
        for sp in res.sealed:
            if not len(sp.events):
                continue
            g_parts = sp.events.partition_by_group()
            for g, gb in g_parts.items():
                self._group_procs(g)
                ps = self._panes[g][sp.t0] = _PaneState(events=gb)
                sealed_jobs.append((g, ps))
                if self.obs is not None:
                    self.obs.lifecycle("seal", (int(g), sp.t0),
                                       args={"events": len(gb)})
            self._frontier = max(self._frontier, int(sp.events.time.max()))
        # fused execution across the sealed panes (lazy fallback when K=1)
        self._prefetch(sealed_jobs)
        for g, ps in sealed_jobs:
            self._ensure_executed(g, ps)
        if not emit:
            return []
        return self._emit_ready(self._buffer.sealed_end)

    # -- window folding ----------------------------------------------------

    def _window_chain(self, g: int, ic: int, ci: int, ctx, q,
                      w0: int) -> tuple[list, list]:
        """Gather one window's pane transfer-matrix chain (executing any
        still-pending pane lazily, in ascending ``t0`` order) plus the
        retained events MIN/MAX aggregates need."""
        panes = self._panes.get(g, {})
        empty_M = self.rt.empty_pane_matrices()[ic]
        needs_minmax = ci in ctx.minmax_queries
        Ms = []
        evs: list[EventBatch] = []
        for t0 in range(w0, w0 + q.within, self.pane):
            ps = panes.get(t0)
            if ps is None:
                Ms.append(empty_M[ci])
            else:
                Ms.append(self._ensure_executed(g, ps)[ic][ci])
                if needs_minmax and len(ps.events):
                    evs.append(ps.events)
        return Ms, evs

    def _fold_windows(self, wins: list) -> list[dict]:
        """Fold + emit a batch of windows (``wins`` rows as produced by
        ``_emit_ready``/``_revise``).  The chain gather walks the windows in
        order (pane execution order — and with it every sharing decision —
        stays the sequential one); the folds then run as **one stacked
        launch set** through the runtime's :class:`~repro.core.fold_exec
        .FoldExecutor` (per-window :func:`fold_panes` when it is detached) —
        a revision storm re-folds every dirty window together."""
        rt = self.rt
        chains = [self._window_chain(g, ic, ci, ctx, q, w0)
                  for g, ic, ci, ctx, q, _aqi, w0 in wins]
        t_f = perf_counter()
        if rt.fold_exec is not None:
            us = rt.fold_exec.fold_windows(
                [(wins[i][3].layout.fresh_state(), Ms)
                 for i, (Ms, _evs) in enumerate(chains)])
        else:
            us = [fold_panes(Ms, wins[i][3].layout.fresh_state())
                  for i, (Ms, _evs) in enumerate(chains)]
        dt = perf_counter() - t_f
        self.stats.fold_s += dt
        if self.obs is not None and wins:
            # the stacked fold spans many windows/groups: an engine-track
            # span, not a per-pane one
            self.obs.pane_phase("fold", t_f, dt, key=None)
        return [rt._emit(ctx, ci, q, _Instance(w0, u, events=evs), g)
                for (g, _ic, ci, ctx, q, _aqi, w0), u, (_Ms, evs)
                in zip(wins, us, chains)]

    def _unexecuted_panes(self, g: int, w0: int, q) -> list:
        """The window's pane states still awaiting execution, in the fold's
        own (ascending ``t0``) order — the one definition both the fused
        prefetch and the lazy :meth:`_window_chain` walk derive from, so
        their execution orders cannot drift apart."""
        panes = self._panes.get(g, {})
        out = []
        for t0 in range(w0, w0 + q.within, self.pane):
            ps = panes.get(t0)
            if ps is not None and ps.M is None:
                out.append((g, ps))
        return out

    def _emit_ready(self, end: int, final: bool = False
                    ) -> list[EmissionRecord]:
        """Emit every window with ``w0 + within <= end`` not yet emitted.

        One traversal builds the ordered window list; the fused prefetch
        (``micro_batch > 1``) and the emission fold both consume it, so
        pane execution order — which the optimizer's running event count,
        and hence bitwise reproducibility, depends on — has a single
        source of truth."""
        records: list[EmissionRecord] = []
        rt = self.rt
        wins: list[tuple] = []
        for g in sorted(self._panes):
            for ic, (comp, ctx) in enumerate(zip(rt.components, rt.ctxs)):
                for ci, aqi in enumerate(comp):
                    q = rt.workload.atomic[aqi]
                    w0 = self._next_w0.get((aqi, g), 0)
                    while w0 + q.within <= end:
                        wins.append((g, ic, ci, ctx, q, aqi, w0))
                        w0 += q.slide
                    self._next_w0[(aqi, g)] = w0
        if self.micro_batch > 1:
            self._prefetch([job for g, _ic, _ci, _ctx, q, _aqi, w0 in wins
                            for job in self._unexecuted_panes(g, w0, q)])
        sealed = ((self.wm.watermark() + 1) // self.pane) * self.pane
        vals_list = self._fold_windows(wins)
        for (g, ic, ci, ctx, q, aqi, w0), vals in zip(wins, vals_list):
            key = (aqi, g, w0)
            self._atomic[key] = vals
            self._revno[key] = 0
            spec = (not final) and (w0 + q.within > sealed)
            records.append(EmissionRecord("emit", q.name, g, w0, vals, 0,
                                          speculative=spec))
            self.metrics.windows_emitted += 1
            self.metrics.speculative_emits += int(spec)
            lag = self._frontier - (w0 + q.within)
            self.metrics.emit_lag.append(lag)
            if self.obs is not None:
                self.obs.observe("eventtime.emit_lag", max(0, lag),
                                 LAG_BUCKETS)
                if self.obs.tracing:
                    self.obs.lifecycle(
                        "emit", (int(g), (w0 // self.pane) * self.pane),
                        args={"w0": w0, "q": aqi, "speculative": spec})
        return records

    def _revise(self, dirty: list[tuple[int, int]]) -> list[EmissionRecord]:
        """Re-fold every already-emitted window covering a revised pane."""
        if not dirty:
            return []
        rt = self.rt
        affected: dict[tuple[int, int, int], tuple[int, int]] = {}
        for g, t0 in dirty:
            pane_hit = False
            for ic, (comp, ctx) in enumerate(zip(rt.components, rt.ctxs)):
                for ci, aqi in enumerate(comp):
                    q = rt.workload.atomic[aqi]
                    nxt = self._next_w0.get((aqi, g), 0)
                    lo = max(0, t0 + self.pane - q.within)
                    w0 = -(-lo // q.slide) * q.slide
                    while w0 <= t0 and w0 < nxt:
                        affected[(aqi, g, w0)] = (ic, ci)
                        pane_hit = True
                        w0 += q.slide
            # a pane counts as *revised* only when its (re-)execution
            # reached back behind the emitted frontier
            self.metrics.panes_revised += int(pane_hit)
            if pane_hit and self.obs is not None:
                self.obs.lifecycle("revise", (int(g), t0))
        ordered = sorted(affected.items())
        if self.obs is not None:
            # storm depth: emitted windows re-folded by one dirty batch
            self.obs.observe("eventtime.revision_storm_depth", len(ordered),
                             DEPTH_BUCKETS)
        if self.micro_batch > 1:
            self._prefetch([job for (aqi, g, w0), _ in ordered
                            for job in self._unexecuted_panes(
                                g, w0, rt.workload.atomic[aqi])])
        records: list[EmissionRecord] = []
        win_rows = [(g, ic, ci, rt.ctxs[ic], rt.workload.atomic[aqi], aqi, w0)
                    for (aqi, g, w0), (ic, ci) in ordered]
        news = self._fold_windows(win_rows)
        for ((aqi, g, w0), (_ic, _ci)), new in zip(ordered, news):
            q = rt.workload.atomic[aqi]
            old = self._atomic[(aqi, g, w0)]
            if vals_equal(old, new):
                self.metrics.noop_revisions += 1
                continue
            rev = self._revno[(aqi, g, w0)] + 1
            records.append(EmissionRecord("retract", q.name, g, w0, old,
                                          rev - 1))
            records.append(EmissionRecord("amend", q.name, g, w0, new, rev))
            self.metrics.retractions += 1
            self.metrics.amendments += 1
            self._atomic[(aqi, g, w0)] = new
            self._revno[(aqi, g, w0)] = rev
        return records

    def _retire(self) -> None:
        """Drop pane state no still-revisable window can reference: with a
        lateness horizon, panes older than ``watermark - horizon -
        max(within)`` only serve windows that are already final.  With
        ``max_retained_panes`` set, additionally bound revision *memory*:
        evict the oldest event-retaining panes beyond the per-group cap."""
        if self.config.lateness_horizon is not None:
            bound = self.wm.watermark() - self.config.lateness_horizon
            for g, panes in self._panes.items():
                for t0 in [t for t in panes if t + self.max_within <= bound]:
                    del panes[t0]
        cap = self.config.max_retained_panes
        if cap is None:
            return
        for g, panes in self._panes.items():
            live = sorted(t0 for t0, ps in panes.items() if not ps.evicted)
            for t0 in live[:max(0, len(live) - cap)]:
                self._evict(g, t0)

    def _evict(self, g: int, t0: int) -> None:
        """Bounded revision memory: keep the pane's transfer matrices (so
        emission and re-folds of *other* dirty panes stay exact) but drop
        its raw events.  The dropped events are expired into the shedding
        accountant — every certificate a straggler into this pane could
        have invalidated is withdrawn — and later stragglers into the pane
        expire instead of absorbing (see :meth:`_absorb`)."""
        ps = self._panes[g][t0]
        self._ensure_executed(g, ps)
        if len(ps.events):
            # the events *were* folded (the pane's M survives), so they are
            # not counted as expired — but their revisability is gone, so
            # the accountant withdraws every certificate they back
            if self.accountant is not None:
                self.accountant.record(ps.events, witnessed=False, late=True)
        ps.events = EventBatch(self.workload.schema, np.array([], np.int32),
                               np.array([], np.int64), None)
        ps.evicted = True
        self.metrics.evicted_panes += 1
        if self.obs is not None:
            self.obs.lifecycle("evict", (int(g), t0))
        self.evictions.append((g, t0))
        if len(self.evictions) > self._evictions_keep:
            del self.evictions[:len(self.evictions) - self._evictions_keep]

    # -- convenience driver ------------------------------------------------

    def run_disordered(self, base: EventBatch, order: np.ndarray,
                       chunk: int = 64, t_end: int | None = None) -> dict:
        """Feed ``base`` in the arrival order ``order`` (chunked), flush,
        and return combined results — the differential-test entry point."""
        for i in range(0, len(order), chunk):
            idx = np.asarray(order[i:i + chunk])
            self.ingest(EventBatch.from_unsorted(
                base.schema, base.type_id[idx], base.time[idx],
                base.attrs[idx], base.group[idx], seq=idx))
        self.flush(t_end=t_end)
        return self.results()
