"""Event-time subsystem: out-of-order streams, watermarks, pane revision.

The paper (and the pane dataplane under ``repro.core``) assumes arrival
order equals event time.  This layer sits between ingestion and the HAMLET
runtime and relaxes that:

* :mod:`watermark` — pluggable, provably monotone watermark policies
  (bounded skew, percentile-adaptive, per-group heartbeat);
* :mod:`reorder` — a reorder buffer that releases contiguous, time-sorted
  panes once the watermark seals them;
* :mod:`revision` — speculative pane execution with snapshot-based
  revision: panes run optimistically on arrival, late events re-plan only
  their pane and re-fold affected windows from stored transfer matrices,
  emitting retract/amend records;
* :mod:`frontier` — per-shard frontier export for the sharded service tier
  (``repro.shardsvc``): a router-fed watermark policy plus the frontier
  snapshot shards report to the cross-shard alignment coordinator;
* hopelessly late events (behind the lateness horizon) are routed into the
  overload subsystem's error accountant, keeping the shedding bounds sound
  under disorder.
"""

from .config import EventTimeConfig  # noqa: F401
from .frontier import FrontierSnapshot, RoutedFrontier  # noqa: F401
from .reorder import ReorderBuffer, ReorderResult, SealedPane  # noqa: F401
from .revision import (EmissionRecord, EventTimeMetrics,  # noqa: F401
                       EventTimeRuntime)
from .watermark import (BoundedSkew, GroupHeartbeat,  # noqa: F401
                        PercentileAdaptive, WatermarkPolicy, make_watermark)
