"""Watermark policies: when is a pane *sealed*?

A watermark is a promise about the future of a disordered stream: after
observing some prefix of arrivals, ``watermark() = w`` asserts that events
with timestamp ``<= w`` are no longer expected.  The reorder buffer seals a
pane ``[t0, t0 + pane)`` once ``w >= t0 + pane - 1``; events arriving behind
the watermark are *late* (revisable within the lateness horizon, expired
beyond it).

Every policy is **monotone** by construction — ``watermark()`` never
regresses, even when its internal estimate would (adaptive skew shrinking,
a new group appearing with an old frontier).  The property tests in
``tests/test_property.py`` fuzz this invariant.

Policies
--------
* :class:`BoundedSkew` — ``max_seen - skew``; the classic fixed-allowance
  watermark for clock-skewed producers.
* :class:`PercentileAdaptive` — tracks the observed per-event lateness
  (``max_seen_before - t`` at arrival) in a ring buffer and sets the skew to
  a percentile of it: calm streams seal fast, disordered phases widen the
  allowance.
* :class:`GroupHeartbeat` — per-group frontiers; the watermark is the
  minimum frontier over live groups minus ``skew``.  A silent group holds
  the watermark back until it sends a :meth:`~WatermarkPolicy.heartbeat`
  or exceeds ``idle_timeout`` ticks behind the global frontier.
"""

from __future__ import annotations

import numpy as np

__all__ = ["WatermarkPolicy", "BoundedSkew", "PercentileAdaptive",
           "GroupHeartbeat", "make_watermark", "WM_MIN"]

WM_MIN = -(1 << 62)


class WatermarkPolicy:
    """Base: observes arrivals, exposes a monotone watermark."""

    def __init__(self) -> None:
        self._wm = WM_MIN

    def observe(self, times: np.ndarray, groups: np.ndarray | None = None
                ) -> int:
        """Account a chunk of arrivals (any order); returns the watermark."""
        if len(times):
            self._advance(self._estimate(np.asarray(times, dtype=np.int64),
                                         groups))
        return self._wm

    def heartbeat(self, group: int, t: int) -> int:
        """Liveness signal: ``group`` promises no events with time < t.
        Policies without per-group state treat it as an empty observation."""
        return self._wm

    def watermark(self) -> int:
        return self._wm

    # -- internals --

    def _advance(self, estimate: int) -> None:
        # monotonicity is enforced here, not trusted from the estimate
        if estimate > self._wm:
            self._wm = estimate

    def _estimate(self, times: np.ndarray, groups) -> int:
        raise NotImplementedError


class BoundedSkew(WatermarkPolicy):
    """``max_seen - skew - 1``: an event late by *exactly* ``skew`` ticks
    (timestamp ``max_seen - skew``) is still within the promised bound, so
    the watermark must stay strictly below it — the classic off-by-one of
    bounded-out-of-orderness watermarks."""

    def __init__(self, skew: int = 0):
        super().__init__()
        if skew < 0:
            raise ValueError("skew must be non-negative")
        self.skew = int(skew)
        self._max_seen = WM_MIN

    def _estimate(self, times: np.ndarray, groups) -> int:
        self._max_seen = max(self._max_seen, int(times.max()))
        return self._max_seen - self.skew - 1


class PercentileAdaptive(WatermarkPolicy):
    def __init__(self, percentile: float = 95.0, window: int = 256,
                 min_skew: int = 0, max_skew: int | None = None):
        super().__init__()
        if not (0.0 < percentile <= 100.0):
            raise ValueError("percentile must be in (0, 100]")
        self.percentile = float(percentile)
        self.window = int(window)
        self.min_skew = int(min_skew)
        self.max_skew = max_skew
        self._lateness = np.zeros(self.window, dtype=np.int64)
        self._fill = 0
        self._pos = 0
        self._max_seen = WM_MIN

    def _estimate(self, times: np.ndarray, groups) -> int:
        # lateness sample per arrival: how far behind the running frontier it
        # landed.  Computed against the frontier *before* each event in this
        # chunk (cummax over the chunk, seeded by the global max).
        frontier = np.maximum.accumulate(
            np.concatenate([[self._max_seen], times]))[:-1]
        late = np.maximum(frontier - times, 0)
        self._max_seen = max(self._max_seen, int(times.max()))
        for v in late:
            self._lateness[self._pos] = v
            self._pos = (self._pos + 1) % self.window
            self._fill = min(self._fill + 1, self.window)
        skew = self.min_skew
        if self._fill:
            q = float(np.percentile(self._lateness[: self._fill],
                                    self.percentile))
            skew = max(skew, int(np.ceil(q)))
        if self.max_skew is not None:
            skew = min(skew, int(self.max_skew))
        # -1: lateness exactly == skew is still within the tracked bound
        return self._max_seen - skew - 1

    @property
    def current_skew(self) -> int:
        if not self._fill:
            return self.min_skew
        q = int(np.ceil(np.percentile(self._lateness[: self._fill],
                                      self.percentile)))
        skew = max(self.min_skew, q)
        return skew if self.max_skew is None else min(skew, self.max_skew)


class GroupHeartbeat(WatermarkPolicy):
    """Per-group *closed bounds*: an observed event at ``t`` closes ``t - 1``
    for its group (equal-timestamp ties may still arrive), and a heartbeat
    ``(g, t)`` — the promise that no group-g event with time **< t** is
    pending — likewise closes ``t - 1``.  The watermark is the minimum
    closed bound over live groups, minus ``skew``."""

    def __init__(self, skew: int = 0, idle_timeout: int | None = None):
        super().__init__()
        if skew < 0:
            raise ValueError("skew must be non-negative")
        self.skew = int(skew)
        self.idle_timeout = idle_timeout
        self._bound: dict[int, int] = {}    # group -> largest closed time
        self._max_bound = WM_MIN

    def heartbeat(self, group: int, t: int) -> int:
        self._close(int(group), int(t) - 1)
        self._advance(self._from_bounds())
        return self._wm

    def _estimate(self, times: np.ndarray, groups) -> int:
        if groups is None:
            groups = np.zeros(len(times), dtype=np.int64)
        for g in np.unique(groups):
            self._close(int(g), int(times[groups == g].max()) - 1)
        return self._from_bounds()

    def _close(self, g: int, bound: int) -> None:
        self._bound[g] = max(self._bound.get(g, WM_MIN), bound)
        self._max_bound = max(self._max_bound, bound)

    def _from_bounds(self) -> int:
        live = list(self._bound.values())
        if self.idle_timeout is not None:
            # groups too far behind the global frontier stop holding the
            # watermark back — their next event would be late anyway
            live = [b for b in live
                    if self._max_bound - b <= self.idle_timeout] or \
                   [self._max_bound]
        return min(live) - self.skew


def make_watermark(config) -> WatermarkPolicy:
    """Build the policy named by an :class:`~repro.eventtime.EventTimeConfig`."""
    if config.watermark == "bounded_skew":
        return BoundedSkew(skew=config.skew)
    if config.watermark == "percentile":
        return PercentileAdaptive(percentile=config.percentile,
                                  window=config.percentile_window,
                                  min_skew=config.skew,
                                  max_skew=config.max_skew)
    if config.watermark == "group_heartbeat":
        return GroupHeartbeat(skew=config.skew,
                              idle_timeout=config.idle_timeout)
    raise ValueError(f"unknown watermark policy {config.watermark!r}")
