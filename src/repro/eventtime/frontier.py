"""Per-shard frontier export for the sharded service tier.

A shard in the sharded service (``repro.shardsvc``) owns its own watermark:
its reorder buffer seals panes as *its* frontier allows, independently of
every other shard.  Two pieces make that work:

* :class:`RoutedFrontier` — the watermark policy a shard runs.  It is a
  bounded-skew estimate over the shard's **local** arrivals, advanced by
  *upstream promises*: the router heartbeats every shard with its global
  watermark after each routed chunk (the router has already forwarded every
  arrival at or below its own watermark, so "no shard-s event with time
  ``< t`` is still pending" is a sound promise even for a shard whose
  tenants are quiet).  Without the promise channel a quiet shard's frontier
  would stall at its last local event and hold its own sealing back forever;
  with it, sealing is driven by global stream progress while disorder
  tolerance stays local.
* :class:`FrontierSnapshot` — the per-shard state a shard exports to the
  cross-shard alignment coordinator (``shardsvc/coordinator.py``): the
  watermark, the sealed frontier (panes released by the reorder buffer) and
  the processed frontier (panes actually executed by the shard's pane
  loop).  Sealing and processing are deliberately separate axes — a shard
  that seals briskly but processes slowly is *lagging*, and the aligner
  excludes it from the aligned epoch instead of letting it stall the fleet.

Monotonicity: :class:`RoutedFrontier` inherits the enforced-in-``_advance``
monotone contract of every :class:`~repro.eventtime.watermark
.WatermarkPolicy` — a stale router promise (behind the local estimate)
simply does not move the watermark.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .watermark import WM_MIN, WatermarkPolicy

__all__ = ["RoutedFrontier", "FrontierSnapshot"]


class RoutedFrontier(WatermarkPolicy):
    """Bounded-skew local estimate, advanced by upstream router promises.

    ``observe`` accounts the shard's own arrivals (watermark estimate
    ``local_max_seen - skew - 1``, the classic closed-bound off-by-one);
    ``heartbeat(group, t)`` is the promise channel: *no event with time
    < t is still pending for this shard* — it closes ``t - 1`` regardless
    of group (the router promises for the whole shard, so the group id is
    advisory).  The resulting watermark is the max of both sources, and
    monotone.
    """

    def __init__(self, skew: int = 0):
        super().__init__()
        if skew < 0:
            raise ValueError("skew must be non-negative")
        self.skew = int(skew)
        self._max_seen = WM_MIN
        self.promises = 0

    def heartbeat(self, group: int, t: int) -> int:
        self.promises += 1
        self._advance(int(t) - 1)
        return self._wm

    def _estimate(self, times: np.ndarray, groups) -> int:
        self._max_seen = max(self._max_seen, int(times.max()))
        return self._max_seen - self.skew - 1


@dataclass(frozen=True)
class FrontierSnapshot:
    """One shard's frontier state, as reported to the alignment coordinator.

    watermark      the shard's :class:`RoutedFrontier` watermark (ticks)
    sealed_end     panes ``[0, sealed_end)`` released by the reorder buffer
    processed_end  panes ``[0, processed_end)`` executed by the pane loop;
                   ``sealed_end - processed_end`` is the shard's processing
                   backlog in ticks
    """

    shard: int
    watermark: int
    sealed_end: int
    processed_end: int

    def epoch(self, align_every: int) -> int:
        """Aligned-epoch index this shard has *processed* through."""
        return self.processed_end // align_every

    def backlog(self) -> int:
        return max(0, self.sealed_end - self.processed_end)
