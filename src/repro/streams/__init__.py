"""Bursty event-stream substrate: generators modelled on the paper's four
evaluation datasets, replay sources, and group-key partitioning."""

from .generator import (  # noqa: F401
    StreamConfig, ridesharing_stream, stock_stream, smarthome_stream,
    nyc_taxi_stream, bursty_stream, OverloadStreamConfig, overload_stream,
)
from .partition import shard_by_group  # noqa: F401
