"""Group-key partitioning onto the device mesh.

The paper's executor partitions the stream by grouping attributes
(Sec. 3.1); group partitions are independent, so they map onto the
``(pod, data)`` mesh axes.  ``shard_by_group`` buckets events into
``n_shards`` contiguous per-shard batches, padded to a common length so the
result is a dense [n_shards, cap, ...] tensor set ready for pjit.
"""

from __future__ import annotations

import numpy as np

from ..core.events import EventBatch

__all__ = ["shard_by_group", "PaddedShards"]


class PaddedShards:
    """Dense per-shard arrays with a validity mask (pjit-ready)."""

    def __init__(self, type_id, time, attrs, group, valid):
        self.type_id = type_id      # [s, cap] int32
        self.time = time            # [s, cap] int64
        self.attrs = attrs          # [s, cap, a] f32
        self.group = group          # [s, cap] int64
        self.valid = valid          # [s, cap] bool

    @property
    def n_shards(self) -> int:
        return self.type_id.shape[0]

    @property
    def capacity(self) -> int:
        return self.type_id.shape[1]

    @property
    def counts(self) -> np.ndarray:
        """Valid events per shard, shape [n_shards]."""
        return self.valid.sum(axis=1)

    def occupancy(self) -> float:
        """Fraction of the dense [s, cap] slab holding real events — the
        padding waste a skewed group distribution causes (1.0 = perfectly
        balanced, -> 1/n_shards when one shard holds everything)."""
        if self.valid.size == 0:
            return 0.0
        return float(self.valid.mean())


def shard_by_group(batch: EventBatch, n_shards: int,
                   capacity: int | None = None) -> PaddedShards:
    shard_of = (batch.group % n_shards).astype(np.int64)
    counts = np.bincount(shard_of, minlength=n_shards)
    cap = int(counts.max()) if capacity is None else capacity
    cap = max(cap, 1)

    type_id = np.zeros((n_shards, cap), dtype=np.int32)
    time = np.zeros((n_shards, cap), dtype=np.int64)
    attrs = np.zeros((n_shards, cap, batch.attrs.shape[1]), dtype=np.float32)
    group = np.zeros((n_shards, cap), dtype=np.int64)
    valid = np.zeros((n_shards, cap), dtype=bool)
    for s in range(n_shards):
        idx = np.nonzero(shard_of == s)[0][:cap]
        m = len(idx)
        type_id[s, :m] = batch.type_id[idx]
        time[s, :m] = batch.time[idx]
        attrs[s, :m] = batch.attrs[idx]
        group[s, :m] = batch.group[idx]
        valid[s, :m] = True
    return PaddedShards(type_id, time, attrs, group, valid)
