"""Bursty stream generators (paper Sec. 6.1).

The paper evaluates on four datasets: NYC taxi/Uber, smart home, stock, and a
synthetic ridesharing stream whose event rate and type distribution are
controlled by the generator.  We reproduce their *shapes*: per-minute event
rates, a controllable burstiness factor (events of one type arriving in
clumps — the regime where graphlet sharing pays), group-key cardinality, and
per-type attribute distributions.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.events import EventBatch, StreamSchema

__all__ = [
    "StreamConfig", "bursty_stream", "ridesharing_stream", "stock_stream",
    "smarthome_stream", "nyc_taxi_stream",
    "OverloadStreamConfig", "overload_stream",
    "TenantStreamConfig", "tenant_stream",
    "DisorderConfig", "DisorderedStream", "disorder_arrival_order",
    "apply_disorder", "disordered_stream", "NAMED_STREAMS",
    "RIDESHARING_SCHEMA", "STOCK_SCHEMA", "SMARTHOME_SCHEMA", "TAXI_SCHEMA",
]

RIDESHARING_SCHEMA = StreamSchema(
    types=("Request", "Accept", "Travel", "Pickup", "Dropoff", "Cancel"),
    attrs=("duration", "speed", "price", "rtype"),
)
STOCK_SCHEMA = StreamSchema(
    types=("Buy", "Sell", "Quote", "Trade"),
    attrs=("price", "volume"),
)
SMARTHOME_SCHEMA = StreamSchema(
    types=("Load", "Work", "Measure", "Idle"),
    attrs=("value", "voltage"),
)
TAXI_SCHEMA = StreamSchema(
    types=("Request", "Travel", "Pickup", "Dropoff"),
    attrs=("duration", "speed", "passengers", "price"),
)


@dataclass
class StreamConfig:
    schema: StreamSchema
    events_per_minute: int = 200
    minutes: int = 10
    n_groups: int = 4
    burstiness: float = 0.8        # 0: iid types; 1: long same-type runs
    type_weights: tuple[float, ...] | None = None
    attr_low: float = 0.0
    attr_high: float = 10.0
    seed: int = 0
    ticks_per_minute: int = 60


def _markov_types(rng, n: int, n_types: int, weights, burstiness: float
                  ) -> np.ndarray:
    """Markov-switching type sequence: with prob ``burstiness`` the next
    event repeats the current type (a burst); otherwise it redraws from the
    type distribution."""
    w = np.asarray(np.ones(n_types) if weights is None else weights,
                   dtype=float)
    w = w / w.sum()
    types = np.empty(n, dtype=np.int32)
    types[0] = rng.choice(n_types, p=w)
    redraw = rng.random(n) >= burstiness
    draws = rng.choice(n_types, size=n, p=w)
    for i in range(1, n):
        types[i] = draws[i] if redraw[i] else types[i - 1]
    return types


def bursty_stream(cfg: StreamConfig) -> EventBatch:
    """Bursty type sequence over strictly increasing integer tick times."""
    rng = np.random.default_rng(cfg.seed)
    n = cfg.events_per_minute * cfg.minutes
    types = _markov_types(rng, n, cfg.schema.n_types, cfg.type_weights,
                          cfg.burstiness)
    total_ticks = cfg.minutes * cfg.ticks_per_minute
    if n <= total_ticks:
        times = np.sort(rng.choice(total_ticks, size=n, replace=False))
    else:
        times = np.sort(rng.integers(0, total_ticks, size=n))
    attrs = rng.uniform(cfg.attr_low, cfg.attr_high,
                        size=(n, max(1, len(cfg.schema.attrs))))
    groups = rng.integers(0, cfg.n_groups, size=n)
    return EventBatch(cfg.schema, types, np.asarray(times, dtype=np.int64),
                      attrs, groups)


@dataclass
class OverloadStreamConfig:
    """Overload scenario: a rate ramp with flash crowds on top.

    The per-tick arrival rate starts at ``base_events_per_minute``, ramps
    linearly to ``ramp_to`` times that by the end of the stream, and each
    ``(start_tick, duration_ticks, multiplier)`` entry in ``flash_crowds``
    multiplies the rate over its span.  Per-tick counts are Poisson, so
    instantaneous load is itself bursty; event *types* keep the Markov
    burst structure of :func:`bursty_stream` (the regime graphlet sharing —
    and pattern-aware shedding — care about).
    """

    schema: StreamSchema
    base_events_per_minute: int = 300
    minutes: int = 10
    ramp_to: float = 1.0
    flash_crowds: tuple[tuple[int, int, float], ...] = ()
    n_groups: int = 4
    burstiness: float = 0.85
    type_weights: tuple[float, ...] | None = None
    attr_low: float = 0.0
    attr_high: float = 10.0
    seed: int = 0
    ticks_per_minute: int = 60


def overload_stream(cfg: OverloadStreamConfig) -> EventBatch:
    rng = np.random.default_rng(cfg.seed)
    total_ticks = cfg.minutes * cfg.ticks_per_minute
    base_per_tick = cfg.base_events_per_minute / cfg.ticks_per_minute
    mult = np.linspace(1.0, max(cfg.ramp_to, 0.0), total_ticks)
    for start, duration, m in cfg.flash_crowds:
        mult[start:start + duration] *= m
    counts = rng.poisson(base_per_tick * mult)
    n = int(counts.sum())
    if n == 0:
        return EventBatch(cfg.schema, np.array([], np.int32),
                          np.array([], np.int64), None)
    times = np.repeat(np.arange(total_ticks, dtype=np.int64), counts)
    types = _markov_types(rng, n, cfg.schema.n_types, cfg.type_weights,
                          cfg.burstiness)
    attrs = rng.uniform(cfg.attr_low, cfg.attr_high,
                        size=(n, max(1, len(cfg.schema.attrs))))
    groups = rng.integers(0, cfg.n_groups, size=n)
    return EventBatch(cfg.schema, types, times, attrs, groups)


def ridesharing_stream(events_per_minute: int = 200, minutes: int = 10,
                       n_groups: int = 4, burstiness: float = 0.85,
                       seed: int = 0) -> EventBatch:
    """Synthetic ridesharing stream (paper Sec. 6.1): Travel events dominate,
    arriving in bursts per district; default 10K events/min in the paper."""
    return bursty_stream(StreamConfig(
        schema=RIDESHARING_SCHEMA, events_per_minute=events_per_minute,
        minutes=minutes, n_groups=n_groups, burstiness=burstiness,
        type_weights=(1, 1, 6, 1, 1, 1), seed=seed))


def stock_stream(events_per_minute: int = 450, minutes: int = 8,
                 n_groups: int = 8, burstiness: float = 0.7,
                 seed: int = 1) -> EventBatch:
    return bursty_stream(StreamConfig(
        schema=STOCK_SCHEMA, events_per_minute=events_per_minute,
        minutes=minutes, n_groups=n_groups, burstiness=burstiness,
        type_weights=(2, 2, 4, 3), seed=seed))


def smarthome_stream(events_per_minute: int = 2000, minutes: int = 2,
                     n_groups: int = 16, burstiness: float = 0.9,
                     seed: int = 2) -> EventBatch:
    return bursty_stream(StreamConfig(
        schema=SMARTHOME_SCHEMA, events_per_minute=events_per_minute,
        minutes=minutes, n_groups=n_groups, burstiness=burstiness,
        type_weights=(1, 2, 6, 1), seed=seed))


def nyc_taxi_stream(events_per_minute: int = 200, minutes: int = 10,
                    n_groups: int = 6, burstiness: float = 0.8,
                    seed: int = 3) -> EventBatch:
    return bursty_stream(StreamConfig(
        schema=TAXI_SCHEMA, events_per_minute=events_per_minute,
        minutes=minutes, n_groups=n_groups, burstiness=burstiness,
        type_weights=(1, 5, 1, 1), seed=seed))


# --------------------------------------------------------------------------
# multi-tenant composition (sharded-service workloads)
# --------------------------------------------------------------------------


@dataclass
class TenantStreamConfig:
    """Multi-tenant composition of per-tenant overload streams.

    Tenant ``t`` owns the contiguous group range
    ``[t * groups_per_tenant, (t+1) * groups_per_tenant)`` — the same
    tenant/group convention the sharded service's placement table uses —
    and emits its own :func:`overload_stream` (Poisson per-tick counts,
    linear ramp, Markov-bursty types) with an independent rng.

    base_events_per_minute   per-tenant base rate before skew
    rate_skew                Zipf-style tenant rate skew exponent: tenant t
                             gets weight ``(t+1)**-rate_skew``, normalized
                             so the *total* offered load is preserved; 0 =
                             uniform tenants
    flash_tenant / flash     a flash crowd ``(start_tick, duration_ticks,
                             multiplier)`` applied to exactly one tenant's
                             rate — the hot-tenant scenario the router's
                             rebalance and SLO-isolation paths are tested
                             against; the other tenants' streams are
                             bit-for-bit unaffected (independent rngs)
    ramp_to                  per-tenant linear rate ramp (shared shape)
    """

    schema: StreamSchema
    n_tenants: int = 4
    groups_per_tenant: int = 2
    base_events_per_minute: int = 300
    minutes: int = 10
    rate_skew: float = 0.0
    flash_tenant: int | None = None
    flash: tuple[int, int, float] = (0, 60, 4.0)
    ramp_to: float = 1.0
    burstiness: float = 0.85
    type_weights: tuple[float, ...] | None = None
    seed: int = 0
    ticks_per_minute: int = 60

    def __post_init__(self) -> None:
        if self.n_tenants < 1:
            raise ValueError("n_tenants must be >= 1")
        if self.groups_per_tenant < 1:
            raise ValueError("groups_per_tenant must be >= 1")
        if self.rate_skew < 0:
            raise ValueError("rate_skew must be >= 0")
        if self.flash_tenant is not None \
                and not (0 <= self.flash_tenant < self.n_tenants):
            raise ValueError("flash_tenant out of range")


def tenant_stream(cfg: TenantStreamConfig) -> EventBatch:
    """Compose per-tenant overload streams into one time-sorted batch.

    Group keys are tenant-offset; ties on time keep tenant order (stable
    merge), so the composed stream is deterministic given ``seed``.
    """
    w = np.array([(t + 1.0) ** -cfg.rate_skew
                  for t in range(cfg.n_tenants)])
    w *= cfg.n_tenants / w.sum()
    parts: list[EventBatch] = []
    for t in range(cfg.n_tenants):
        sub = overload_stream(OverloadStreamConfig(
            schema=cfg.schema,
            base_events_per_minute=max(
                1, int(round(cfg.base_events_per_minute * w[t]))),
            minutes=cfg.minutes,
            ramp_to=cfg.ramp_to,
            flash_crowds=(cfg.flash,) if t == cfg.flash_tenant else (),
            n_groups=cfg.groups_per_tenant,
            burstiness=cfg.burstiness,
            type_weights=cfg.type_weights,
            seed=cfg.seed + 1009 * t,
            ticks_per_minute=cfg.ticks_per_minute))
        if len(sub):
            parts.append(EventBatch(
                sub.schema, sub.type_id, sub.time, sub.attrs,
                sub.group + t * cfg.groups_per_tenant))
    if not parts:
        return EventBatch(cfg.schema, np.array([], np.int32),
                          np.array([], np.int64), None)
    return EventBatch.merge(parts)


# --------------------------------------------------------------------------
# disorder models (event-time subsystem workloads)
# --------------------------------------------------------------------------

NAMED_STREAMS = {
    "ridesharing": ridesharing_stream,
    "stock": stock_stream,
    "smarthome": smarthome_stream,
    "taxi": nyc_taxi_stream,
}


@dataclass
class DisorderConfig:
    """How arrival order diverges from event-time order.

    model             "bounded_skew"     — an affected event's *arrival* is
                                           delayed by U[1, max_skew] ticks:
                                           every event is late by at most
                                           ``max_skew`` (the regime a
                                           bounded-skew watermark covers
                                           exactly);
                      "stragglers"       — whole bursts (maximal same-type
                                           runs, the unit the engine shares
                                           on) go late *together* by
                                           U[max_skew, straggler_delay]:
                                           retried producers re-sending a
                                           clump;
                      "adversarial_tail" — affected events draw Pareto
                                           delays: most modest, a heavy tail
                                           beyond any finite horizon, so the
                                           expiry/shedding path is exercised
    fraction          fraction of events affected (bursts are chosen until
                      the event fraction is covered for "stragglers")
    max_skew          delay bound for bounded_skew; delay floor for
                      stragglers
    straggler_delay   delay ceiling for stragglers
    tail_scale        Pareto scale (ticks) for adversarial_tail
    tail_alpha        Pareto shape (smaller = heavier tail)
    seed              rng seed (disorder is independent of the base stream)
    """

    model: str = "bounded_skew"
    fraction: float = 0.1
    max_skew: int = 8
    straggler_delay: int = 30
    tail_scale: float = 8.0
    tail_alpha: float = 1.1
    seed: int = 0

    def __post_init__(self) -> None:
        if self.model not in ("bounded_skew", "stragglers",
                              "adversarial_tail"):
            raise ValueError(f"unknown disorder model {self.model!r}")
        if not (0.0 <= self.fraction <= 1.0):
            raise ValueError("fraction must be in [0, 1]")


def _arrival_delays(batch: EventBatch, cfg: DisorderConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed)
    n = len(batch)
    delays = np.zeros(n, dtype=np.int64)
    if n == 0 or cfg.fraction == 0.0:
        return delays
    if cfg.model == "bounded_skew":
        hit = rng.random(n) < cfg.fraction
        delays[hit] = rng.integers(1, max(cfg.max_skew, 1) + 1,
                                   size=int(hit.sum()))
    elif cfg.model == "stragglers":
        # maximal same-type runs; late bursts arrive as one clump
        cut = np.nonzero(np.diff(batch.type_id))[0] + 1
        bounds = np.concatenate([[0], cut, [n]])
        order = rng.permutation(len(bounds) - 1)
        budget = int(np.ceil(cfg.fraction * n))
        lo = max(cfg.max_skew, 1)
        hi = max(cfg.straggler_delay, lo + 1)
        for bi in order:
            if budget <= 0:
                break
            s, e = int(bounds[bi]), int(bounds[bi + 1])
            delays[s:e] = rng.integers(lo, hi + 1)
            budget -= e - s
    else:  # adversarial_tail
        hit = rng.random(n) < cfg.fraction
        raw = cfg.tail_scale * (1.0 + rng.pareto(cfg.tail_alpha,
                                                 size=int(hit.sum())))
        delays[hit] = np.ceil(raw).astype(np.int64)
    return delays


def disorder_arrival_order(batch: EventBatch, cfg: DisorderConfig
                           ) -> np.ndarray:
    """Arrival permutation: position ``i`` arrives ``order[i]`` (an index
    into the time-sorted ``batch``).  Stable in arrival time, so undisturbed
    events keep their stream order."""
    arrival = batch.time + _arrival_delays(batch, cfg)
    return np.argsort(arrival, kind="stable")


@dataclass
class DisorderedStream:
    """A time-sorted truth batch plus the order its events hit the wire.

    ``base.seq`` is stamped with the stream position (the producer's
    sequence id), so a consumer that merges by ``(time, seq)`` reconstructs
    the exact original total order — including duplicate-timestamp ties.
    """

    base: EventBatch
    order: np.ndarray

    def __len__(self) -> int:
        return len(self.base)

    def chunks(self, size: int):
        """Yield wire chunks (time-sorted internally, provenance-stamped) in
        arrival order — ready for ``EventTimeRuntime.ingest``."""
        b = self.base
        for i in range(0, len(self.order), size):
            idx = self.order[i:i + size]
            yield EventBatch.from_unsorted(b.schema, b.type_id[idx],
                                           b.time[idx], b.attrs[idx],
                                           b.group[idx], seq=idx)

    def max_lateness(self) -> int:
        """Largest frontier lag any event arrives with (the minimal skew a
        bounded-skew watermark needs to lose nothing)."""
        times = self.base.time[self.order]
        if not len(times):
            return 0
        frontier = np.maximum.accumulate(times)
        return int((frontier - times).max())


def apply_disorder(batch: EventBatch, cfg: DisorderConfig) -> DisorderedStream:
    base = EventBatch(batch.schema, batch.type_id, batch.time, batch.attrs,
                      batch.group, seq=np.arange(len(batch), dtype=np.int64))
    return DisorderedStream(base=base, order=disorder_arrival_order(base, cfg))


def disordered_stream(dataset: str, disorder: DisorderConfig, **kwargs
                      ) -> DisorderedStream:
    """Disordered variant of a named workload stream — ``dataset`` is one of
    ``NAMED_STREAMS`` (ridesharing / stock / smarthome / taxi); ``kwargs``
    pass through to the base generator."""
    try:
        gen = NAMED_STREAMS[dataset]
    except KeyError:
        raise ValueError(f"unknown dataset {dataset!r}; "
                         f"have {sorted(NAMED_STREAMS)}") from None
    return apply_disorder(gen(**kwargs), disorder)
