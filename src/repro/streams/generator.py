"""Bursty stream generators (paper Sec. 6.1).

The paper evaluates on four datasets: NYC taxi/Uber, smart home, stock, and a
synthetic ridesharing stream whose event rate and type distribution are
controlled by the generator.  We reproduce their *shapes*: per-minute event
rates, a controllable burstiness factor (events of one type arriving in
clumps — the regime where graphlet sharing pays), group-key cardinality, and
per-type attribute distributions.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.events import EventBatch, StreamSchema

__all__ = [
    "StreamConfig", "bursty_stream", "ridesharing_stream", "stock_stream",
    "smarthome_stream", "nyc_taxi_stream",
    "OverloadStreamConfig", "overload_stream",
    "RIDESHARING_SCHEMA", "STOCK_SCHEMA", "SMARTHOME_SCHEMA", "TAXI_SCHEMA",
]

RIDESHARING_SCHEMA = StreamSchema(
    types=("Request", "Accept", "Travel", "Pickup", "Dropoff", "Cancel"),
    attrs=("duration", "speed", "price", "rtype"),
)
STOCK_SCHEMA = StreamSchema(
    types=("Buy", "Sell", "Quote", "Trade"),
    attrs=("price", "volume"),
)
SMARTHOME_SCHEMA = StreamSchema(
    types=("Load", "Work", "Measure", "Idle"),
    attrs=("value", "voltage"),
)
TAXI_SCHEMA = StreamSchema(
    types=("Request", "Travel", "Pickup", "Dropoff"),
    attrs=("duration", "speed", "passengers", "price"),
)


@dataclass
class StreamConfig:
    schema: StreamSchema
    events_per_minute: int = 200
    minutes: int = 10
    n_groups: int = 4
    burstiness: float = 0.8        # 0: iid types; 1: long same-type runs
    type_weights: tuple[float, ...] | None = None
    attr_low: float = 0.0
    attr_high: float = 10.0
    seed: int = 0
    ticks_per_minute: int = 60


def _markov_types(rng, n: int, n_types: int, weights, burstiness: float
                  ) -> np.ndarray:
    """Markov-switching type sequence: with prob ``burstiness`` the next
    event repeats the current type (a burst); otherwise it redraws from the
    type distribution."""
    w = np.asarray(np.ones(n_types) if weights is None else weights,
                   dtype=float)
    w = w / w.sum()
    types = np.empty(n, dtype=np.int32)
    types[0] = rng.choice(n_types, p=w)
    redraw = rng.random(n) >= burstiness
    draws = rng.choice(n_types, size=n, p=w)
    for i in range(1, n):
        types[i] = draws[i] if redraw[i] else types[i - 1]
    return types


def bursty_stream(cfg: StreamConfig) -> EventBatch:
    """Bursty type sequence over strictly increasing integer tick times."""
    rng = np.random.default_rng(cfg.seed)
    n = cfg.events_per_minute * cfg.minutes
    types = _markov_types(rng, n, cfg.schema.n_types, cfg.type_weights,
                          cfg.burstiness)
    total_ticks = cfg.minutes * cfg.ticks_per_minute
    if n <= total_ticks:
        times = np.sort(rng.choice(total_ticks, size=n, replace=False))
    else:
        times = np.sort(rng.integers(0, total_ticks, size=n))
    attrs = rng.uniform(cfg.attr_low, cfg.attr_high,
                        size=(n, max(1, len(cfg.schema.attrs))))
    groups = rng.integers(0, cfg.n_groups, size=n)
    return EventBatch(cfg.schema, types, np.asarray(times, dtype=np.int64),
                      attrs, groups)


@dataclass
class OverloadStreamConfig:
    """Overload scenario: a rate ramp with flash crowds on top.

    The per-tick arrival rate starts at ``base_events_per_minute``, ramps
    linearly to ``ramp_to`` times that by the end of the stream, and each
    ``(start_tick, duration_ticks, multiplier)`` entry in ``flash_crowds``
    multiplies the rate over its span.  Per-tick counts are Poisson, so
    instantaneous load is itself bursty; event *types* keep the Markov
    burst structure of :func:`bursty_stream` (the regime graphlet sharing —
    and pattern-aware shedding — care about).
    """

    schema: StreamSchema
    base_events_per_minute: int = 300
    minutes: int = 10
    ramp_to: float = 1.0
    flash_crowds: tuple[tuple[int, int, float], ...] = ()
    n_groups: int = 4
    burstiness: float = 0.85
    type_weights: tuple[float, ...] | None = None
    attr_low: float = 0.0
    attr_high: float = 10.0
    seed: int = 0
    ticks_per_minute: int = 60


def overload_stream(cfg: OverloadStreamConfig) -> EventBatch:
    rng = np.random.default_rng(cfg.seed)
    total_ticks = cfg.minutes * cfg.ticks_per_minute
    base_per_tick = cfg.base_events_per_minute / cfg.ticks_per_minute
    mult = np.linspace(1.0, max(cfg.ramp_to, 0.0), total_ticks)
    for start, duration, m in cfg.flash_crowds:
        mult[start:start + duration] *= m
    counts = rng.poisson(base_per_tick * mult)
    n = int(counts.sum())
    if n == 0:
        return EventBatch(cfg.schema, np.array([], np.int32),
                          np.array([], np.int64), None)
    times = np.repeat(np.arange(total_ticks, dtype=np.int64), counts)
    types = _markov_types(rng, n, cfg.schema.n_types, cfg.type_weights,
                          cfg.burstiness)
    attrs = rng.uniform(cfg.attr_low, cfg.attr_high,
                        size=(n, max(1, len(cfg.schema.attrs))))
    groups = rng.integers(0, cfg.n_groups, size=n)
    return EventBatch(cfg.schema, types, times, attrs, groups)


def ridesharing_stream(events_per_minute: int = 200, minutes: int = 10,
                       n_groups: int = 4, burstiness: float = 0.85,
                       seed: int = 0) -> EventBatch:
    """Synthetic ridesharing stream (paper Sec. 6.1): Travel events dominate,
    arriving in bursts per district; default 10K events/min in the paper."""
    return bursty_stream(StreamConfig(
        schema=RIDESHARING_SCHEMA, events_per_minute=events_per_minute,
        minutes=minutes, n_groups=n_groups, burstiness=burstiness,
        type_weights=(1, 1, 6, 1, 1, 1), seed=seed))


def stock_stream(events_per_minute: int = 450, minutes: int = 8,
                 n_groups: int = 8, burstiness: float = 0.7,
                 seed: int = 1) -> EventBatch:
    return bursty_stream(StreamConfig(
        schema=STOCK_SCHEMA, events_per_minute=events_per_minute,
        minutes=minutes, n_groups=n_groups, burstiness=burstiness,
        type_weights=(2, 2, 4, 3), seed=seed))


def smarthome_stream(events_per_minute: int = 2000, minutes: int = 2,
                     n_groups: int = 16, burstiness: float = 0.9,
                     seed: int = 2) -> EventBatch:
    return bursty_stream(StreamConfig(
        schema=SMARTHOME_SCHEMA, events_per_minute=events_per_minute,
        minutes=minutes, n_groups=n_groups, burstiness=burstiness,
        type_weights=(1, 2, 6, 1), seed=seed))


def nyc_taxi_stream(events_per_minute: int = 200, minutes: int = 10,
                    n_groups: int = 6, burstiness: float = 0.8,
                    seed: int = 3) -> EventBatch:
    return bursty_stream(StreamConfig(
        schema=TAXI_SCHEMA, events_per_minute=events_per_minute,
        minutes=minutes, n_groups=n_groups, burstiness=burstiness,
        type_weights=(1, 5, 1, 1), seed=seed))
