"""Global admission control: shed at the router, before events enqueue.

Per-operator shedding (each shard's PID loop acting on its own ingress)
cannot see fleet imbalance: a flash crowd saturates one shard while the
others idle, and the saturated shard's shedder throws work away *after* it
was queued, routed and buffered.  This module moves the actuation upstream
— the router sheds arrival chunks before they are enqueued anywhere — in
one of three modes:

``none``
    Admit everything.  Shards keep whatever local policy their config says.

``global_fixed``
    Shed a fixed ratio pane-by-pane on the **full chunk before routing**.
    Because the shed decision is a pure function of the (pane-sliced)
    arrival stream, the admitted event set is identical for every shard
    count — this is the mode under which the N-shard/1-shard differential
    contract covers shedding.  Shards run with local shedding disabled.

``per_shard``
    Read each shard's PID controller state (`LatencyController.state()`)
    and shed each shard's routed sub-chunk at that shard's current ratio —
    the controllers keep *observing* local pane latency, but *actuation*
    happens here, before the queue.  Deliberately not shard-count
    invariant: the ratios follow per-shard latency, which follows
    placement.  (The same observation-cadence trade as the micro-batched
    PID loop, documented in ``overload/runtime.py``.)

All router-shed events are charged to a router-level
:class:`ErrorAccountant`; ``global_accountant``/``global_report`` union it
with the per-shard accountants into one fleet certificate (subset
guarantee + ``3^s`` bound) via :meth:`ErrorAccountant.merged`.
"""

from __future__ import annotations

import math

import numpy as np

from ..core.events import EventBatch, pane_size_for
from ..core.query import Workload
from ..overload.accountant import ErrorAccountant, merge_error_reports
from ..overload.config import OverloadConfig
from ..overload.shedding import make_shedder

__all__ = ["GlobalAdmissionController", "ADMISSION_MODES"]

ADMISSION_MODES = ("none", "global_fixed", "per_shard")


class GlobalAdmissionController:
    def __init__(self, workload: Workload, cfg: OverloadConfig,
                 mode: str = "global_fixed", pane: int | None = None):
        if mode not in ADMISSION_MODES:
            raise ValueError(f"unknown admission mode {mode!r}; "
                             f"have {ADMISSION_MODES}")
        self.mode = mode
        self.cfg = cfg
        self.pane = int(pane) if pane else pane_size_for(workload.windows)
        self.fixed = cfg.fixed_shed if cfg.fixed_shed is not None else 0.0
        self.shedder = make_shedder(
            cfg.shed_policy if cfg.shed_policy != "none" else "drop_tail",
            workload, seed=cfg.seed, min_burst_keep=cfg.min_burst_keep,
            benefit_model=cfg.benefit_model)
        self.accountant = ErrorAccountant(workload, pane=self.pane)
        self.offered = 0
        self.admitted = 0

    # ---------------------------------------------------------- admission

    def admit_global(self, chunk: EventBatch) -> EventBatch:
        """``global_fixed`` / ``none`` actuation: shed the full chunk
        (pane-sliced) before routing.  Shard-count invariant."""
        self.offered += len(chunk)
        if self.mode != "global_fixed" or self.fixed <= 0.0 \
                or not len(chunk):
            self.admitted += len(chunk)
            return chunk
        out = self._shed_paned(chunk, self.fixed)
        self.admitted += len(out)
        return out

    def admit_for_shard(self, sub: EventBatch, state: dict) -> EventBatch:
        """``per_shard`` actuation: shed one shard's routed sub-chunk at
        that shard's controller ratio (its PID keeps observing; the router
        actuates)."""
        self.offered += len(sub)
        ratio = float(state["shed_ratio"])
        if ratio <= 0.0 or not len(sub):
            self.admitted += len(sub)
            return sub
        out = self._shed_paned(sub, ratio)
        self.admitted += len(out)
        return out

    def _shed_paned(self, chunk: EventBatch, ratio: float) -> EventBatch:
        """Shed ``ratio`` per pane slice (the same granularity the in-shard
        loop uses, so ``global_fixed`` matches a single runtime's fixed-shed
        admitted set bit for bit)."""
        kept: list[EventBatch] = []
        t0 = (int(chunk.time[0]) // self.pane) * self.pane
        t_end = int(chunk.time.max()) + 1
        for t in range(t0, t_end, self.pane):
            ev = chunk.time_slice(t, t + self.pane)
            n = len(ev)
            if not n:
                continue
            keep_n = int(math.floor(n * (1.0 - ratio) + 1e-9))
            keep_n = min(max(keep_n, 0), n)
            if keep_n < n:
                plan = self.shedder.plan(ev, keep_n)
                kept.append(ev.select(plan.keep))
                self.accountant.record(ev.select(plan.shed),
                                       witnessed=plan.witnessed)
            else:
                kept.append(ev)
        if not kept:
            return chunk.select(np.arange(0))
        return EventBatch.concat(kept)

    # -------------------------------------------------------- certificates

    def global_accountant(self, shard_accountants) -> ErrorAccountant:
        """Cell-exact fleet accountant: router + every shard."""
        return ErrorAccountant.merged([self.accountant,
                                       *shard_accountants])

    def global_report(self, shard_reports) -> dict:
        """Fleet certificate from report dicts (counts sum, subset
        guarantee ANDs)."""
        return merge_error_reports([self.accountant.report(),
                                    *shard_reports])

    def summary(self) -> dict:
        return {"mode": self.mode, "offered": self.offered,
                "admitted": self.admitted,
                "shed": self.offered - self.admitted,
                "router_shed_total": self.accountant.total_shed}
