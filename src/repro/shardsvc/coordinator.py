"""Cross-shard watermark alignment: the aligned-epoch protocol.

Each shard seals and processes panes against its **own** frontier (a
:class:`~repro.eventtime.frontier.RoutedFrontier` — local bounded-skew
estimate advanced by router promises), so no shard ever waits on another to
seal.  What the fleet still needs is a *joint* notion of progress: which
prefix of event time is final **everywhere**, so that merged results,
global error certificates and rebalance boundaries can be published
against it.

The naive answer — the global minimum over shard frontiers — re-couples
the fleet: one slow shard pins the aligned frontier for everyone, which is
exactly the failure mode sharding was meant to remove.  The aligned-epoch
protocol instead works on coarse epochs (``align_every`` ticks, a pane
multiple) and excludes *laggards*:

* every shard reports a :class:`FrontierSnapshot` after each drive cycle
  (watermark / sealed frontier / processed frontier);
* a shard is **lagging** when its processed epoch trails the fleet's
  maximum by more than ``max_lag_epochs``;
* the **aligned epoch** is the minimum processed epoch over the
  non-lagging shards — it keeps advancing with the healthy majority while
  a slowed shard catches up.

Consumers must treat laggards honestly: ``aligned_results`` in the service
marks windows owned by lagging shards as *pending* rather than final.
Nothing is lost — a laggard's own sealing, retract/amend accounting and
results are untouched; it is only excluded from the fleet-final prefix
until it rejoins (hysteresis: a laggard rejoins once it is back within
``max_lag_epochs``).

Two call protocols feed the aligner:

* **serial** — the driver calls ``update`` per shard then ``align`` once,
  all on one thread (the epoch-synchronous service loop);
* **rendezvous** — under the thread-pool drive path every shard worker
  thread calls ``arrive(snapshot)`` at the end of its drive cycle.  The
  call blocks until all ``n_shards`` workers of the cycle have arrived;
  the last arrival computes the alignment *once* (so the published epoch
  is a function of a consistent set of frontiers, exactly as in the serial
  protocol) and releases the others.  This is a real concurrent barrier:
  the aligned epoch a cycle publishes is identical to what the serial
  protocol would publish for the same frontiers.
"""

from __future__ import annotations

import threading

from ..eventtime.frontier import FrontierSnapshot

__all__ = ["WatermarkAligner"]


class WatermarkAligner:
    def __init__(self, n_shards: int, align_every: int,
                 max_lag_epochs: int = 2):
        if align_every <= 0:
            raise ValueError("align_every must be positive")
        if max_lag_epochs < 0:
            raise ValueError("max_lag_epochs must be non-negative")
        self.n_shards = int(n_shards)
        self.align_every = int(align_every)
        self.max_lag_epochs = int(max_lag_epochs)
        self._snaps: dict[int, FrontierSnapshot] = {}
        self._aligned_epoch = 0        # monotone published frontier
        self.rounds = 0
        # rendezvous state (thread-pool drive path)
        self._cond = threading.Condition()
        self._arrived = 0
        self._generation = 0

    # ------------------------------------------------------------- updates

    def update(self, snap: FrontierSnapshot) -> None:
        if not (0 <= snap.shard < self.n_shards):
            raise ValueError(f"shard {snap.shard} out of range")
        self._snaps[snap.shard] = snap

    def align(self) -> int:
        """Recompute and publish the aligned epoch (monotone)."""
        self.rounds += 1
        epochs = self._epochs()
        lag = self.laggards()
        live = [e for s, e in epochs.items() if s not in lag]
        if live:
            self._aligned_epoch = max(self._aligned_epoch, min(live))
        return self._aligned_epoch

    def arrive(self, snap: FrontierSnapshot,
               timeout: float | None = 60.0) -> int:
        """Concurrent rendezvous: record ``snap`` and block until all
        ``n_shards`` workers of this drive cycle have arrived.  The last
        arrival runs :meth:`align` exactly once over the complete frontier
        set and wakes the rest; every caller returns the cycle's aligned
        epoch.  ``timeout`` bounds the wait so a crashed worker surfaces as
        an error instead of a hang."""
        with self._cond:
            self.update(snap)
            self._arrived += 1
            if self._arrived >= self.n_shards:
                self._arrived = 0
                self._generation += 1
                epoch = self.align()
                self._cond.notify_all()
                return epoch
            gen = self._generation
            while gen == self._generation:
                if not self._cond.wait(timeout):
                    raise RuntimeError(
                        f"alignment rendezvous timed out: "
                        f"{self._arrived}/{self.n_shards} arrived")
            return self._aligned_epoch

    # ------------------------------------------------------------- queries

    def _epochs(self) -> dict[int, int]:
        return {s: self._snaps[s].epoch(self.align_every)
                if s in self._snaps else 0 for s in range(self.n_shards)}

    def laggards(self) -> set[int]:
        """Shards whose processed epoch trails the fleet max by more than
        ``max_lag_epochs`` (excluded from alignment until they catch up)."""
        epochs = self._epochs()
        top = max(epochs.values(), default=0)
        return {s for s, e in epochs.items()
                if top - e > self.max_lag_epochs}

    @property
    def aligned_epoch(self) -> int:
        return self._aligned_epoch

    @property
    def aligned_time(self) -> int:
        """Event time through which every non-lagging shard has processed."""
        return self._aligned_epoch * self.align_every

    def status(self) -> dict:
        epochs = self._epochs()
        lag = self.laggards()
        return {
            "aligned_epoch": self._aligned_epoch,
            "aligned_time": self.aligned_time,
            "epochs": epochs,
            "laggards": sorted(lag),
            "watermarks": {s: snap.watermark
                           for s, snap in self._snaps.items()},
            "backlogs": {s: snap.backlog()
                         for s, snap in self._snaps.items()},
            "rounds": self.rounds,
        }
