"""Cross-shard watermark alignment: the aligned-epoch protocol.

Each shard seals and processes panes against its **own** frontier (a
:class:`~repro.eventtime.frontier.RoutedFrontier` — local bounded-skew
estimate advanced by router promises), so no shard ever waits on another to
seal.  What the fleet still needs is a *joint* notion of progress: which
prefix of event time is final **everywhere**, so that merged results,
global error certificates and rebalance boundaries can be published
against it.

The naive answer — the global minimum over shard frontiers — re-couples
the fleet: one slow shard pins the aligned frontier for everyone, which is
exactly the failure mode sharding was meant to remove.  The aligned-epoch
protocol instead works on coarse epochs (``align_every`` ticks, a pane
multiple) and excludes *laggards*:

* every shard reports a :class:`FrontierSnapshot` after each drive cycle
  (watermark / sealed frontier / processed frontier);
* a shard is **lagging** when its processed epoch trails the fleet's
  maximum by more than ``max_lag_epochs``;
* the **aligned epoch** is the minimum processed epoch over the
  non-lagging shards — it keeps advancing with the healthy majority while
  a slowed shard catches up.

Consumers must treat laggards honestly: ``aligned_results`` in the service
marks windows owned by lagging shards as *pending* rather than final.
Nothing is lost — a laggard's own sealing, retract/amend accounting and
results are untouched; it is only excluded from the fleet-final prefix
until it rejoins (hysteresis: a laggard rejoins once it is back within
``max_lag_epochs``).
"""

from __future__ import annotations

from ..eventtime.frontier import FrontierSnapshot

__all__ = ["WatermarkAligner"]


class WatermarkAligner:
    def __init__(self, n_shards: int, align_every: int,
                 max_lag_epochs: int = 2):
        if align_every <= 0:
            raise ValueError("align_every must be positive")
        if max_lag_epochs < 0:
            raise ValueError("max_lag_epochs must be non-negative")
        self.n_shards = int(n_shards)
        self.align_every = int(align_every)
        self.max_lag_epochs = int(max_lag_epochs)
        self._snaps: dict[int, FrontierSnapshot] = {}
        self._aligned_epoch = 0        # monotone published frontier
        self.rounds = 0

    # ------------------------------------------------------------- updates

    def update(self, snap: FrontierSnapshot) -> None:
        if not (0 <= snap.shard < self.n_shards):
            raise ValueError(f"shard {snap.shard} out of range")
        self._snaps[snap.shard] = snap

    def align(self) -> int:
        """Recompute and publish the aligned epoch (monotone)."""
        self.rounds += 1
        epochs = self._epochs()
        lag = self.laggards()
        live = [e for s, e in epochs.items() if s not in lag]
        if live:
            self._aligned_epoch = max(self._aligned_epoch, min(live))
        return self._aligned_epoch

    # ------------------------------------------------------------- queries

    def _epochs(self) -> dict[int, int]:
        return {s: self._snaps[s].epoch(self.align_every)
                if s in self._snaps else 0 for s in range(self.n_shards)}

    def laggards(self) -> set[int]:
        """Shards whose processed epoch trails the fleet max by more than
        ``max_lag_epochs`` (excluded from alignment until they catch up)."""
        epochs = self._epochs()
        top = max(epochs.values(), default=0)
        return {s for s, e in epochs.items()
                if top - e > self.max_lag_epochs}

    @property
    def aligned_epoch(self) -> int:
        return self._aligned_epoch

    @property
    def aligned_time(self) -> int:
        """Event time through which every non-lagging shard has processed."""
        return self._aligned_epoch * self.align_every

    def status(self) -> dict:
        epochs = self._epochs()
        lag = self.laggards()
        return {
            "aligned_epoch": self._aligned_epoch,
            "aligned_time": self.aligned_time,
            "epochs": epochs,
            "laggards": sorted(lag),
            "watermarks": {s: snap.watermark
                           for s, snap in self._snaps.items()},
            "backlogs": {s: snap.backlog()
                         for s, snap in self._snaps.items()},
            "rounds": self.rounds,
        }
