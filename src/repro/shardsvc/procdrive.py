"""Process-pool shard drive: long-lived worker processes past the GIL.

``ShardServiceConfig.parallel="thread"`` overlaps shard drive cycles on a
thread pool — but every pane of numpy work still serializes on the GIL,
so measured speedup on CPython is ~1.0x no matter the core count.  This
module runs each :class:`~repro.shardsvc.service.ShardWorker` in its own
**long-lived worker process** instead:

* engine state stays pinned in the worker — ``HamletRuntime``, plan
  caches, the pane micro-batcher, the PID loop and the error accountant
  are built once per process and never cross the boundary;
* per drive cycle the parent ships only the shard's routed chunk: a
  pickled header over the command pipe plus the raw event columns in a
  ``multiprocessing.shared_memory`` segment (the same column layout the
  wire transport uses, so the child decodes with one memcpy); chunks
  under :data:`INLINE_BYTES` skip the segment and ride the pipe;
* the rendezvous is the command protocol itself: the parent dispatches
  one ``cycle`` command per worker (offer + heartbeat + drive), the
  children run concurrently, and the parent collects each reply — which
  carries the worker's post-drive :class:`FrontierSnapshot` — then feeds
  the aligner in shard order, exactly as the serial drive does.

Determinism: chunk columns cross as raw bytes and results return via
pickle, both of which preserve float64 bit patterns, and the aligner sees
the same frontier sequence as the serial drive — so process-drive results
are bitwise equal to the serial drive by construction, which the parity
tests assert across all four named workloads including event-time
disorder.

The spawn start method is used unconditionally: fork would duplicate
jax/thread state the runtime may hold, and spawn keeps the child's import
set explicit.  Rebalance (``plan_rebalance``) is not supported in process
mode — open-window instance handoff would require shipping live engine
state across the boundary; the service raises ``NotImplementedError``.
"""

from __future__ import annotations

import multiprocessing as mp
import pickle
import struct
import time
import traceback
from multiprocessing import shared_memory

__all__ = ["ProcShardWorker", "INLINE_BYTES"]

_CTX = mp.get_context("spawn")

INLINE_BYTES = 16 << 10     # chunks smaller than this ride the pipe

_CHUNK_HDR = struct.Struct("<IB")     # n events, has_seq (transport layout)


# --------------------------------------------------------------------------
# chunk shipping (pickled header + raw columns)
# --------------------------------------------------------------------------

def _pack_columns(batch) -> bytes:
    import numpy as np
    has_seq = batch.seq is not None
    parts = [_CHUNK_HDR.pack(len(batch), 1 if has_seq else 0),
             np.ascontiguousarray(batch.type_id).tobytes(),
             np.ascontiguousarray(batch.time).tobytes(),
             np.ascontiguousarray(batch.attrs).tobytes(),
             np.ascontiguousarray(batch.group).tobytes()]
    if has_seq:
        parts.append(np.ascontiguousarray(batch.seq).tobytes())
    return b"".join(parts)


def _unpack_columns(schema, payload) -> "object":
    import numpy as np

    from ..core.events import EventBatch
    buf = memoryview(payload)
    n, has_seq = _CHUNK_HDR.unpack_from(buf, 0)
    off = _CHUNK_HDR.size
    a = max(1, len(schema.attrs))
    type_id = np.frombuffer(buf, np.int32, n, off)
    off += 4 * n
    t = np.frombuffer(buf, np.int64, n, off)
    off += 8 * n
    attrs = np.frombuffer(buf, np.float64, n * a, off).reshape(n, a)
    off += 8 * n * a
    group = np.frombuffer(buf, np.int64, n, off)
    off += 8 * n
    seq = np.frombuffer(buf, np.int64, n, off) if has_seq else None
    return EventBatch(schema, type_id, t, attrs, group, seq=seq)


def _attach_shm(name: str) -> shared_memory.SharedMemory:
    """Attach to a parent-owned segment.

    Before 3.13 an attach also registers with the resource tracker — but a
    spawn child shares the *parent's* tracker process (the fd rides the
    spawn handshake), and the tracker's cache is a set: the child's
    register dedupes against the parent's and the parent's ``unlink()``
    removes the single entry.  Explicitly unregistering here would
    unbalance that accounting (tracker KeyError spam at unlink time), so
    the attach is left as-is."""
    return shared_memory.SharedMemory(name=name)


def _load_chunk(schema, header):
    """Child side of the shipment: rebuild the EventBatch.  Shared-memory
    payloads are copied out with one memcpy (``bytes(buf)``) so the
    segment can be released immediately after the reply."""
    if header is None:
        return None
    inline = header.get("inline")
    if inline is not None:
        return _unpack_columns(schema, inline)
    seg = _attach_shm(header["shm"])
    try:
        payload = bytes(seg.buf[:header["size"]])
    finally:
        seg.close()
    return _unpack_columns(schema, payload)


# --------------------------------------------------------------------------
# worker process main
# --------------------------------------------------------------------------

def _worker_main(conn, shard_id, workload, cfg, policy, backend,
                 eventtime, skew, lateness_horizon, obs_on) -> None:
    from ..obs.facade import Observability
    from .service import ShardWorker

    w = ShardWorker(shard_id, workload, cfg, policy=policy, backend=backend,
                    eventtime=eventtime, skew=skew,
                    lateness_horizon=lateness_horizon,
                    obs=Observability.disabled() if obs_on else None)
    conn.send(("ready", w.pane))
    schema = workload.schema
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            break
        op = msg[0]
        try:
            if op == "cycle":
                _, header, safe_end, hb, throttle = msg
                w.throttle = throttle
                sub = _load_chunk(schema, header)
                if sub is not None:
                    w.offer(sub, safe_end)
                if hb is not None:
                    w.heartbeat(hb)
                w.drive()
                payload = w.frontier()
            elif op == "close":
                w.close(msg[1])
                payload = w.frontier()
            elif op == "results":
                payload = w.results()
            elif op == "stats":
                payload = w.stats()
            elif op == "accountant":
                payload = w.accountant()
            elif op == "summary":
                payload = w.summary()
            elif op == "controller_state":
                payload = w.controller_state()
            elif op == "pending_flush":
                payload = w.pending_flush()
            elif op == "obs_registry":
                payload = w.obs.registry if w.obs is not None else None
            elif op == "set":
                setattr(w, msg[1], msg[2])
                payload = None
            elif op == "shutdown":
                w.shutdown()
                conn.send((True, None, w.t_now, w.busy_s,
                           w.late_total, w.expired_total))
                break
            else:
                raise ValueError(f"unknown worker op {op!r}")
            conn.send((True, payload, w.t_now, w.busy_s,
                       w.late_total, w.expired_total))
        except Exception as e:  # noqa: BLE001 — surfaced parent-side
            conn.send((False, (repr(e), traceback.format_exc()),
                       w.t_now, w.busy_s, w.late_total, w.expired_total))
    conn.close()


# --------------------------------------------------------------------------
# parent-side proxy
# --------------------------------------------------------------------------

class ProcShardWorker:
    """Parent-side proxy exposing the :class:`ShardWorker` surface the
    service drives, backed by one long-lived spawn process.

    ``cycle_async``/``cycle_wait`` split one drive cycle into dispatch and
    collect so the service can run every shard's cycle concurrently; all
    other methods are synchronous RPCs.  ``t_now``/``busy_s``/``frontier``
    are served from the cache every reply refreshes — the read side never
    blocks on the worker mid-cycle.
    """

    def __init__(self, shard_id: int, workload, cfg, *, policy=None,
                 backend: str = "np", eventtime: bool = False,
                 skew: int = 0, lateness_horizon: int | None = None,
                 obs: bool = False, clock=time.perf_counter):
        self.shard_id = int(shard_id)
        self.throttle: int | None = None
        self.cap_t: int | None = None       # rebalance unsupported here
        self.pane: int | None = None
        self.obs = None                      # registry lives in the child
        self._t_now = 0
        self._busy_s = 0.0
        self.late_total = 0
        self.expired_total = 0
        self._frontier = None
        self._final: dict | None = None      # read-side snapshot at shutdown
        self._shm: shared_memory.SharedMemory | None = None
        self._inflight = False
        self._clock = clock
        self._conn, child = _CTX.Pipe()
        self._proc = _CTX.Process(
            target=_worker_main,
            args=(child, shard_id, workload, cfg, policy, backend,
                  eventtime, skew, lateness_horizon, obs),
            name=f"shard-proc-{shard_id}", daemon=True)
        self._proc.start()
        self._pid = self._proc.pid
        child.close()

    def wait_ready(self, timeout: float = 120.0) -> None:
        if self.pane is not None:
            return
        if not self._conn.poll(timeout):
            raise TimeoutError(f"shard process {self.shard_id} did not "
                               f"come up within {timeout}s")
        tag, pane = self._conn.recv()
        if tag != "ready":
            raise RuntimeError(f"bad handshake from shard "
                               f"{self.shard_id}: {tag!r}")
        self.pane = pane

    # ----------------------------------------------------------------- rpc

    def _recv(self):
        ok, payload, t_now, busy_s, late, expired = self._conn.recv()
        self._t_now = t_now
        self._busy_s = busy_s
        self.late_total = late
        self.expired_total = expired
        self._release_shm()
        if not ok:
            err, tb = payload
            raise RuntimeError(
                f"shard process {self.shard_id} failed: {err}\n{tb}")
        return payload

    _SNAPSHOT_OPS = ("results", "stats", "accountant", "summary",
                     "controller_state", "pending_flush", "obs_registry")

    def _rpc(self, op, *args):
        if self._final is not None:
            # process already gone: serve reads from the shutdown snapshot
            if op in self._final:
                return self._final[op]
            raise RuntimeError(f"shard process {self.shard_id} is shut "
                               f"down; op {op!r} unavailable")
        self._conn.send((op, *args))
        return self._recv()

    def _release_shm(self) -> None:
        if self._shm is not None:
            seg, self._shm = self._shm, None
            seg.close()
            seg.unlink()

    def _ship(self, batch):
        if batch is None:
            return None
        payload = _pack_columns(batch)
        if len(payload) <= INLINE_BYTES:
            return {"inline": payload}
        seg = shared_memory.SharedMemory(create=True, size=len(payload))
        seg.buf[:len(payload)] = payload
        self._shm = seg       # released once the cycle reply lands
        return {"shm": seg.name, "size": len(payload)}

    # --------------------------------------------------------- drive cycle

    def cycle_async(self, sub, safe_end: int, hb: int | None) -> None:
        # empty batches still ship (a few bytes inline): the child's
        # offer() must see safe_end so its step limit advances
        header = self._ship(sub)
        self._conn.send(("cycle", header, safe_end, hb, self.throttle))
        self._inflight = True

    def cycle_wait(self):
        self._inflight = False
        self._frontier = self._recv()
        return self._frontier

    # ----------------------------------------------- ShardWorker surface

    @property
    def t_now(self) -> int:
        return self._t_now

    @property
    def busy_s(self) -> float:
        return self._busy_s

    def frontier(self):
        if self._frontier is None:
            from ..eventtime.frontier import FrontierSnapshot
            return FrontierSnapshot(shard=self.shard_id, watermark=-1,
                                    sealed_end=0, processed_end=0)
        return self._frontier

    def close(self, t_end: int) -> None:
        self._frontier = self._rpc("close", t_end)

    def results(self) -> dict:
        return self._rpc("results")

    def stats(self):
        return self._rpc("stats")

    def accountant(self):
        return self._rpc("accountant")

    def summary(self) -> dict:
        s = self._rpc("summary")
        s["process"] = {"pid": self._pid}
        return s

    def controller_state(self):
        return self._rpc("controller_state")

    def pending_flush(self) -> bool:
        return self._rpc("pending_flush")

    def obs_registry(self):
        return self._rpc("obs_registry")

    def shutdown(self, timeout: float = 30.0) -> None:
        """Snapshot the read side, stop the worker process, serve every
        later read (``results``/``stats``/...) from the snapshot — so the
        service's post-close read API works identically to in-process
        workers."""
        if self._proc is None:
            return
        try:
            if self._proc.is_alive() and self._final is None:
                snap = {op: self._rpc(op) for op in self._SNAPSHOT_OPS}
                self._rpc("shutdown")
                self._final = snap
        except (BrokenPipeError, EOFError, OSError, RuntimeError):
            self._final = self._final or {}
        self._proc.join(timeout)
        if self._proc.is_alive():
            self._proc.terminate()
            self._proc.join(timeout)
        self._conn.close()
        self._release_shm()
        self._proc = None
