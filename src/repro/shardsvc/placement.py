"""Router placement: consistent hash of (tenant, group) -> shard, plus an
explicit override table for targeted rebalancing.

Groups are the unit of work (group partitions are fully independent in the
pane dataplane); tenants are contiguous group ranges (``tenant = group //
groups_per_tenant``).  The default placement is a consistent-hash ring over
*tenant* keys — a tenant's groups always colocate, so its state lives on
one shard — where every shard owns ``replicas`` pseudo-random points on a
64-bit ring and a key lands on the first shard point at or after its own
hash.  Two properties matter here:

* **Determinism** — the ring uses ``blake2b``, not Python's per-process
  salted ``hash()``, so the same (tenant, group) maps to the same shard in
  every process, every run.  The differential contract of the sharded
  service (N-shard output == 1-shard output) needs routing to be a pure
  function of the key.
* **Stability under change** — moving one hot tenant is an *override*, not
  a rehash: the table records ``group -> shard`` exceptions and bumps its
  version, leaving every other group's mapping (and therefore every other
  shard's plan-cache and window state) untouched.  Likewise growing the
  ring to ``n+1`` shards remaps only ~1/(n+1) of the keys.

``shard_of_groups`` is the hot-path form: vectorized over an arrival
chunk's group column with a memoized group->shard map (group-key
cardinality is small next to event counts, so the map converges after the
first few chunks).
"""

from __future__ import annotations

import hashlib

import numpy as np

__all__ = ["PlacementTable", "ring_hash"]


def ring_hash(key: str) -> int:
    """Deterministic 64-bit ring position for ``key`` (process-stable)."""
    return int.from_bytes(hashlib.blake2b(key.encode(), digest_size=8)
                          .digest(), "big")


class PlacementTable:
    """(tenant, group) -> shard via consistent hashing + explicit overrides."""

    def __init__(self, n_shards: int, groups_per_tenant: int = 1,
                 replicas: int = 64):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if groups_per_tenant < 1:
            raise ValueError("groups_per_tenant must be >= 1")
        self.n_shards = int(n_shards)
        self.groups_per_tenant = int(groups_per_tenant)
        self.replicas = int(replicas)
        self.version = 0
        self._overrides: dict[int, int] = {}
        # ring: sorted point positions and the shard owning each point
        pts = [(ring_hash(f"shard:{s}:{r}"), s)
               for s in range(self.n_shards) for r in range(self.replicas)]
        pts.sort()
        self._ring_pos = np.array([p for p, _ in pts], dtype=np.uint64)
        self._ring_shard = np.array([s for _, s in pts], dtype=np.int64)
        self._cache: dict[int, int] = {}

    # ------------------------------------------------------------- lookups

    def tenant_of(self, group: int) -> int:
        return int(group) // self.groups_per_tenant

    def shard_of(self, group: int) -> int:
        g = int(group)
        s = self._cache.get(g)
        if s is None:
            s = self._cache[g] = self._resolve(g)
            return s
        return s

    def _resolve(self, group: int) -> int:
        ov = self._overrides.get(group)
        if ov is not None:
            return ov
        # hash the *tenant*, not the group: a tenant's groups colocate, so
        # per-tenant state (and any cross-group sharing within the tenant's
        # pane batches) stays on one shard
        h = ring_hash(f"tenant:{self.tenant_of(group)}")
        i = int(np.searchsorted(self._ring_pos, np.uint64(h), side="left"))
        if i == len(self._ring_pos):        # wrap around the ring
            i = 0
        return int(self._ring_shard[i])

    def shard_of_groups(self, groups: np.ndarray) -> np.ndarray:
        """Vectorized ``shard_of`` over an arrival chunk's group column."""
        out = np.empty(len(groups), dtype=np.int64)
        cache = self._cache
        for i, g in enumerate(groups.tolist()):
            s = cache.get(g)
            if s is None:
                s = cache[g] = self._resolve(g)
            out[i] = s
        return out

    def groups_on(self, shard: int, groups) -> list[int]:
        """Of ``groups`` (iterable of group keys), those placed on ``shard``."""
        return [g for g in groups if self.shard_of(g) == shard]

    # ----------------------------------------------------------- rebalance

    def override(self, group: int, shard: int) -> None:
        """Pin ``group`` to ``shard`` (a targeted rebalance).  Only this
        group's mapping changes; the table version is bumped so routers can
        detect staleness."""
        if not (0 <= shard < self.n_shards):
            raise ValueError(f"shard {shard} out of range")
        self._overrides[int(group)] = int(shard)
        self._cache[int(group)] = int(shard)
        self.version += 1

    def clear_override(self, group: int) -> None:
        if self._overrides.pop(int(group), None) is not None:
            self._cache.pop(int(group), None)
            self.version += 1

    @property
    def overrides(self) -> dict[int, int]:
        return dict(self._overrides)
