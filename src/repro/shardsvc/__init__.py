"""Sharded multi-tenant service tier over the HAMLET pane dataplane.

Partitions tenants (contiguous group ranges) across N shard workers, each
owning an unchanged single-process stack — ``HamletRuntime`` + plan cache +
``PaneMicroBatcher`` + overload PID loop + error accountant — and adds the
three things group-independence does not give for free:

* :mod:`placement` — deterministic consistent-hash routing with an
  override table for targeted, warmth-preserving rebalances;
* :mod:`admission` — global admission control: shed at the router before
  any queue, aggregate every accountant into one fleet certificate;
* :mod:`coordinator` — aligned-epoch watermark alignment: fleet-final
  progress that excludes laggards instead of waiting on them;
* :mod:`service` — the composed ``ShardedHamletService`` (router, shard
  workers, rebalance barriers, merged read side);
* :mod:`procdrive` — ``parallel="process"``: each shard worker pinned in
  a long-lived spawn process (chunks via shared memory, rendezvous over
  the command pipe) so shard drive cycles overlap past the GIL.

Differential contract (tested): with ``none``/``global_fixed`` admission
the N-shard service's results are a permutation-stable bitwise match of
the 1-shard service on the same stream.
"""

from .admission import ADMISSION_MODES, GlobalAdmissionController  # noqa: F401
from .coordinator import WatermarkAligner  # noqa: F401
from .placement import PlacementTable, ring_hash  # noqa: F401
from .procdrive import ProcShardWorker  # noqa: F401
from .service import (ShardedHamletService, ShardServiceConfig,  # noqa: F401
                      ShardWorker)
