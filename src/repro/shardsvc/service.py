"""Sharded multi-tenant HAMLET service: router, shard workers, alignment.

Topology (one process, N independent shard states):

    arrivals --> GlobalAdmissionController --> PlacementTable router
                      (shed at the router)      (tenant,group) -> shard
                           |                               |
                           v                               v
                 router ErrorAccountant        ShardWorker[0..N-1], each:
                                                 ReorderBuffer(RoutedFrontier)
                                                 OverloadRuntime (own
                                                   HamletRuntime, plan cache,
                                                   PaneMicroBatcher, PID loop,
                                                   ErrorAccountant)
                           ^                               |
                           |                               v
                 WatermarkAligner  <---- FrontierSnapshot per drive cycle

Group partitions are fully independent in the pane dataplane, so sharding
by group is semantically free: each shard runs the *unchanged* engine over
its own groups.  The service's job is everything groups don't isolate —
admission, routing, time, and the merged read side:

* **Admission** happens at the router (``shardsvc/admission.py``), before
  any queue.  In ``global_fixed`` mode the shed decision is a pure
  function of the pane-sliced arrival stream, so the admitted set — and
  therefore every downstream result — is identical for every shard count.
* **Time** is per shard: each worker seals panes against its own
  :class:`RoutedFrontier` (local bounded-skew estimate ∨ router promises),
  so no shard waits on another to seal, and the per-shard retract/amend
  accounting of the event-time layer is untouched.  The router heartbeats
  its global watermark after every chunk; since routing is synchronous
  (every arrival at or below the router watermark has already been
  forwarded), the promise is sound, and a quiet shard's frontier advances
  with global stream progress.  Fleet-level finality is negotiated by the
  :class:`WatermarkAligner` (aligned-epoch protocol — laggards are
  excluded, not waited on).
* **Rebalancing** moves one group between shards at a pane-aligned
  boundary strictly above every event seen so far: old-time events keep
  routing to the source shard, the two involved shards cap their pane
  clocks at the boundary (a barrier *only* for the pair, *only* while the
  move is pending), and at the barrier the group's open-window instances
  are handed to the target shard.  Untouched shards never stall and keep
  their plan caches warm; the handoff is exact for in-flight windows.

**Differential contract**: with ``none``/``global_fixed`` admission, the
results of an N-shard service are a permutation-stable bitwise match of
the 1-shard service on the same stream — same keys, same values, only the
emission interleaving differs.  ``per_shard`` admission (PID-driven
ratios actuated at the router) intentionally departs from this: shed
ratios then depend on per-shard latency, which depends on placement.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field, replace

import numpy as np

from ..core.engine import RunStats
from ..core.events import EventBatch
from ..core.query import Workload
from ..eventtime.frontier import FrontierSnapshot, RoutedFrontier
from ..eventtime.reorder import ReorderBuffer
from ..obs.facade import Observability
from ..overload.config import OverloadConfig
from ..overload.runtime import OverloadRuntime, _GroupDriver
from .admission import ADMISSION_MODES, GlobalAdmissionController
from .coordinator import WatermarkAligner
from .placement import PlacementTable

__all__ = ["ShardServiceConfig", "ShardWorker", "ShardedHamletService"]


@dataclass
class ShardServiceConfig:
    """Knobs of the sharded service tier.

    n_shards           shard worker count (1 = the differential baseline)
    groups_per_tenant  tenant granularity: ``tenant = group // this``
    admission          "none" | "global_fixed" | "per_shard" (see
                       ``shardsvc/admission.py``); under the first two the
                       N-shard/1-shard differential contract holds
    eventtime          run each shard behind a reorder buffer with a
                       :class:`RoutedFrontier` (disordered arrival); off =
                       arrival order is event-time order
    skew               bounded-skew allowance of every shard frontier and
                       of the router watermark (eventtime mode)
    lateness_horizon   per-shard expiry horizon (ticks behind watermark)
    align_every_panes  aligned-epoch granularity, in panes
    max_lag_epochs     how far a shard may trail the fleet max before the
                       aligner excludes it
    overload           the per-shard overload config template; when the
                       router owns admission, shards get a copy with local
                       shedding disabled (actuation moves to the router,
                       observation stays on the shard)
    obs                give every shard a registry-only Observability and
                       expose the merged + per-shard tracks in ``collect()``
    ring_replicas      consistent-hash ring points per shard
    parallel           how drive cycles overlap across shard workers:

                       * ``False`` — serial: drive every worker in turn
                         on the caller thread (the differential baseline);
                       * ``True`` / ``"thread"`` — thread pool: each cycle
                         dispatches (offer, heartbeat, drive) per worker
                         concurrently and the workers meet at the
                         aligner's rendezvous barrier.  Measured wall
                         clock, but numpy pane work still serializes on
                         the GIL;
                       * ``"process"`` — long-lived worker processes
                         (:mod:`repro.shardsvc.procdrive`): engine state
                         pinned per process, chunks shipped via shared
                         memory, rendezvous over the command pipe — the
                         mode that can actually exceed 1.0x measured
                         speedup on multi-core hosts.  Rebalance is not
                         supported in this mode.

                       All modes are bitwise identical to the serial drive
                       (workers share no mutable state; the aligner sees
                       the same frontier sequence per cycle).
    """

    n_shards: int = 2
    groups_per_tenant: int = 1
    admission: str = "global_fixed"
    eventtime: bool = False
    skew: int = 0
    lateness_horizon: int | None = None
    align_every_panes: int = 4
    max_lag_epochs: int = 2
    overload: OverloadConfig = field(default_factory=OverloadConfig)
    obs: bool = False
    ring_replicas: int = 64
    parallel: bool | str = False

    def __post_init__(self) -> None:
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if self.admission not in ADMISSION_MODES:
            raise ValueError(f"unknown admission mode {self.admission!r}")
        if self.skew < 0:
            raise ValueError("skew must be non-negative")
        if self.align_every_panes < 1:
            raise ValueError("align_every_panes must be >= 1")
        if self.parallel not in (False, True, "thread", "process"):
            raise ValueError(
                f"parallel must be False, True, 'thread' or 'process', "
                f"got {self.parallel!r}")

    @property
    def drive_mode(self) -> str:
        """Normalized drive mode: ``serial`` | ``thread`` | ``process``."""
        if self.parallel is False:
            return "serial"
        if self.parallel is True:
            return "thread"
        return self.parallel


@dataclass
class _PendingMove:
    group: int
    src: int
    dst: int
    boundary: int      # pane-aligned handoff time, > max_seen at registration


class ShardWorker:
    """One shard: reorder buffer + overload runtime + busy accounting.

    ``throttle`` (max panes stepped per drive cycle) simulates a slow or
    degraded shard — the aligner's laggard-exclusion path and the
    weak-scaling benchmark's slow-shard scenario both use it.  ``cap_t``
    is the rebalance barrier: while set, the pane clock will not advance
    past it.
    """

    def __init__(self, shard_id: int, workload: Workload,
                 cfg: OverloadConfig, *, policy=None, backend: str = "np",
                 eventtime: bool = False, skew: int = 0,
                 lateness_horizon: int | None = None, obs=None,
                 clock=time.perf_counter):
        self.shard_id = int(shard_id)
        self.obs = obs
        self.rt = OverloadRuntime(workload, cfg, policy=policy,
                                  backend=backend, obs=obs)
        self.pane = self.rt.pane
        if eventtime:
            self.frontier_policy = RoutedFrontier(skew=skew)
            self.reorder = ReorderBuffer(workload.schema, self.pane,
                                         self.frontier_policy,
                                         lateness_horizon=lateness_horizon)
        else:
            self.frontier_policy = None
            self.reorder = None
        self._safe_end = 0       # ordered-mode step limit (router max_seen)
        self.cap_t: int | None = None
        self.throttle: int | None = None
        self.busy_s = 0.0
        self.late_total = 0
        self.expired_total = 0
        self._clock = clock

    @property
    def t_now(self) -> int:
        return self.rt.t_now

    # ------------------------------------------------------------- ingest

    def offer(self, sub: EventBatch, safe_end: int) -> None:
        """Accept this shard's routed slice of one arrival chunk.

        ``safe_end`` (ordered mode) is the router's promise that every
        future arrival — for any shard — has time >= it, so panes ending
        at or before it are complete."""
        c0 = self._clock()
        if self.reorder is None:
            if len(sub):
                self.rt.offer(sub)
            self._safe_end = max(self._safe_end, safe_end)
        elif len(sub):
            self._ingest(self.reorder.push(sub))
        self.busy_s += self._clock() - c0

    def heartbeat(self, t: int) -> None:
        """Router promise: no event with time < t is still in flight."""
        if self.reorder is not None:
            c0 = self._clock()
            self._ingest(self.reorder.heartbeat(-1, t))
            self.busy_s += self._clock() - c0

    def _ingest(self, res) -> None:
        for sp in res.sealed:
            if len(sp.events):
                self.rt.offer(sp.events)
        for late in (res.late, res.expired):
            if late is not None:
                # behind this shard's sealed frontier: charge like the
                # in-runtime stale path so every certificate stays sound
                self.rt.accountant.record(late, witnessed=False, late=True)
        self.late_total += res.n_late
        self.expired_total += res.n_expired

    # -------------------------------------------------------------- drive

    def _step_limit(self) -> int:
        lim = self._safe_end
        if self.reorder is not None:
            lim = max(lim, self.reorder.sealed_end)
        if self.cap_t is not None:
            lim = min(lim, self.cap_t)
        return lim

    def drive(self) -> int:
        """Step every complete pane (bounded by throttle/cap); returns the
        number of panes stepped."""
        c0 = self._clock()
        stepped = 0
        lim = self._step_limit()
        while self.rt.t_now + self.pane <= lim:
            if self.throttle is not None and stepped >= self.throttle:
                break
            self.rt.step_pane()
            stepped += 1
        self.busy_s += self._clock() - c0
        return stepped

    def close(self, t_end: int) -> None:
        """Stream end: flush the reorder buffer, release the step limit."""
        c0 = self._clock()
        self.throttle = None
        if self.reorder is not None:
            self._ingest(self.reorder.flush())
        self._safe_end = max(self._safe_end, t_end)
        self.busy_s += self._clock() - c0

    # ------------------------------------------------------------ exports

    def frontier(self) -> FrontierSnapshot:
        if self.reorder is not None:
            wm = self.reorder.watermark
            sealed = self.reorder.sealed_end
        else:
            wm = self._safe_end - 1
            sealed = (self._safe_end // self.pane) * self.pane
        return FrontierSnapshot(shard=self.shard_id, watermark=wm,
                                sealed_end=sealed, processed_end=self.t_now)

    def results(self) -> dict:
        c0 = self._clock()
        out = self.rt.results()
        self.busy_s += self._clock() - c0
        return out

    # The read-side accessors below exist so the service never reaches
    # through ``w.rt`` directly: a process-mode proxy can then forward the
    # same calls over its command pipe instead of exposing live state.

    def stats(self) -> RunStats:
        return self.rt.stats

    def accountant(self):
        return self.rt.accountant

    def controller_state(self):
        return self.rt.controller.state()

    def pending_flush(self) -> bool:
        return len(self.rt._backlog) > 0

    def obs_registry(self):
        return self.obs.registry if self.obs is not None else None

    def shutdown(self) -> None:
        self.rt.shutdown()       # joins per-shard pipelined flush workers

    def summary(self) -> dict:
        return {
            "shard": self.shard_id,
            "busy_s": self.busy_s,
            "t_now": self.t_now,
            "overload": self.rt.metrics.summary(),
            "controller": self.rt.controller.state(),
            "plan_cache": self.rt.rt.plan_cache_stats(),
            "late": self.late_total,
            "expired": self.expired_total,
            "ingress_dropped": self.rt.queue.dropped,
        }


class ShardedHamletService:
    """N shard workers behind one router, admission controller and aligner.

    ``ingest`` accepts wire chunks in arrival order (time-sorted inside a
    chunk; across chunks arbitrary when ``eventtime`` is on), ``close``
    seals the stream, ``results``/``stats``/``error_report``/``collect``
    are the merged read side.  ``run`` is the batch convenience driver.
    """

    def __init__(self, workload: Workload,
                 cfg: ShardServiceConfig | None = None, *, policy=None,
                 backend: str = "np", clock=time.perf_counter):
        self.workload = workload
        self.cfg = cfg = cfg if cfg is not None else ShardServiceConfig()
        self.placement = PlacementTable(cfg.n_shards,
                                        cfg.groups_per_tenant,
                                        replicas=cfg.ring_replicas)
        shard_cfg = self._shard_overload_cfg()
        self._mode = cfg.drive_mode
        if self._mode == "process":
            from .procdrive import ProcShardWorker
            self.workers = [
                ProcShardWorker(s, workload, shard_cfg, policy=policy,
                                backend=backend, eventtime=cfg.eventtime,
                                skew=cfg.skew,
                                lateness_horizon=cfg.lateness_horizon,
                                obs=cfg.obs, clock=clock)
                for s in range(cfg.n_shards)]
            for w in self.workers:       # spawns overlap; then handshake
                w.wait_ready()
        else:
            self.workers = [
                ShardWorker(s, workload, shard_cfg, policy=policy,
                            backend=backend, eventtime=cfg.eventtime,
                            skew=cfg.skew,
                            lateness_horizon=cfg.lateness_horizon,
                            obs=Observability.disabled() if cfg.obs
                            else None,
                            clock=clock)
                for s in range(cfg.n_shards)]
        self.pane = self.workers[0].pane
        self.admission = GlobalAdmissionController(
            workload, cfg.overload, mode=cfg.admission, pane=self.pane)
        self.aligner = WatermarkAligner(
            cfg.n_shards, align_every=cfg.align_every_panes * self.pane,
            max_lag_epochs=cfg.max_lag_epochs)
        self._within = {qname: max(workload.atomic[i].within for i in idxs)
                        for qname, idxs, _ in workload.combines}
        self._max_seen = -1
        self._moves: list[_PendingMove] = []
        self._closed = False
        self.chunks = 0
        self.router_busy_s = 0.0
        self.drive_cycles = 0
        self.drive_wall_s = 0.0     # measured wall clock across drive cycles
        self._pool = (ThreadPoolExecutor(
            max_workers=cfg.n_shards, thread_name_prefix="shard")
            if self._mode == "thread" and cfg.n_shards > 1 else None)
        self._clock = clock

    def _shard_overload_cfg(self) -> OverloadConfig:
        cfg = self.cfg.overload
        if self.cfg.admission == "none":
            return cfg
        # the router owns actuation; shards observe latency but do not shed
        return replace(cfg, shed_policy="none", fixed_shed=None)

    # -------------------------------------------------------------- write

    def promise(self, t: int) -> None:
        """External order promise: no future arrival has ``time <= t``.

        The serving scheduler seals panes against the session watermark
        before forwarding, which is a stronger guarantee than the router's
        own max-seen heuristic — honouring it lets shards seal panes the
        routed chunks alone would leave open."""
        self._max_seen = max(self._max_seen, int(t))

    def ingest(self, chunk: EventBatch) -> None:
        """Route one arrival chunk and run a drive cycle."""
        if self._closed:
            raise RuntimeError("service is closed")
        c0 = self._clock()
        self.chunks += 1
        if len(chunk):
            self._max_seen = max(self._max_seen, int(chunk.time.max()))
        if self.admission.mode != "per_shard":
            chunk = self.admission.admit_global(chunk)
        subs = self._route(chunk)
        if self.admission.mode == "per_shard":
            subs = [self.admission.admit_for_shard(
                sub, self.workers[s].controller_state())
                for s, sub in enumerate(subs)]
        self.router_busy_s += self._clock() - c0
        hb = self._max_seen - self.cfg.skew if self.cfg.eventtime else None
        if self._pool is not None or self._mode == "process":
            # offers ride the worker tasks: ingest + drive overlap per shard
            self._drive(subs, hb)
            return
        for w, sub in zip(self.workers, subs):
            w.offer(sub, self._max_seen)
        if hb is not None:
            for w in self.workers:
                w.heartbeat(hb)
        self._drive()

    def _route(self, chunk: EventBatch) -> list[EventBatch]:
        if not len(chunk):
            return [chunk] * self.cfg.n_shards
        shard_of = self.placement.shard_of_groups(chunk.group)
        # pending moves route by time: < boundary to the source shard (its
        # placement entry is untouched until commit), >= boundary to the
        # target — no event at or past the boundary has arrived before the
        # move was registered, so the split is exact
        for mv in self._moves:
            hot = (chunk.group == mv.group) & (chunk.time >= mv.boundary)
            if hot.any():
                shard_of = np.where(hot, mv.dst, shard_of)
        return [chunk.select(np.nonzero(shard_of == s)[0])
                for s in range(self.cfg.n_shards)]

    def _drive(self, subs: list[EventBatch] | None = None,
               hb: int | None = None) -> None:
        """One drive cycle.  Serial mode: drive every worker in turn, then
        feed the aligner.  Thread mode: dispatch one task per worker onto
        the thread pool — (offer, heartbeat, drive) — and let the workers
        meet at the aligner's concurrent rendezvous; the cycle's wall
        clock is *measured*, not modeled.  Process mode: dispatch one
        ``cycle`` command per worker process, collect the replies (each
        carries the post-drive frontier), then feed the aligner in shard
        order — the same frontier sequence as the serial drive.
        Rebalance commits stay on the caller thread, strictly between
        cycles."""
        self._maybe_commit_moves()
        self.drive_cycles += 1
        c0 = self._clock()
        if self._mode == "process":
            safe = self._max_seen
            for s, w in enumerate(self.workers):
                w.cycle_async(subs[s] if subs is not None else None,
                              safe, hb)
            fronts = [w.cycle_wait() for w in self.workers]
            self.drive_wall_s += self._clock() - c0
            c0 = self._clock()
            for f in fronts:
                self.aligner.update(f)
            self.aligner.align()
            self.router_busy_s += self._clock() - c0
            return
        if self._pool is not None:
            safe = self._max_seen
            futs = [self._pool.submit(
                self._worker_cycle, w,
                subs[s] if subs is not None else None, safe, hb)
                for s, w in enumerate(self.workers)]
            for f in futs:
                f.result()
            self.drive_wall_s += self._clock() - c0
            self._maybe_commit_moves()
            return
        for w in self.workers:
            w.drive()
        self.drive_wall_s += self._clock() - c0
        self._maybe_commit_moves()
        c0 = self._clock()
        for w in self.workers:
            self.aligner.update(w.frontier())
        self.aligner.align()
        self.router_busy_s += self._clock() - c0

    def _worker_cycle(self, w: ShardWorker, sub: EventBatch | None,
                      safe_end: int, hb: int | None) -> None:
        """Per-worker task of one parallel drive cycle.  The ``finally``
        guarantees the rendezvous completes even when a worker errors —
        the exception still surfaces through the future, but no sibling
        deadlocks at the barrier."""
        try:
            if sub is not None:
                w.offer(sub, safe_end)
            if hb is not None:
                w.heartbeat(hb)
            w.drive()
        finally:
            self.aligner.arrive(w.frontier())

    def close(self) -> None:
        """Seal the stream: flush reorder buffers, drive every shard to the
        final pane boundary (releasing rebalance barriers on the way)."""
        if self._closed:
            return
        self._closed = True
        t_end = ((self._max_seen + self.pane) // self.pane) * self.pane
        for w in self.workers:
            w.close(t_end)
        stalls = 0
        while any(w.t_now < t_end for w in self.workers):
            before = [w.t_now for w in self.workers]
            self._drive()
            stalls = stalls + 1 if [w.t_now for w in self.workers] == before \
                else 0
            if stalls > 2:
                raise RuntimeError(
                    "close() stalled; a rebalance barrier cannot be "
                    f"reached (moves={self._moves})")
        self._drive()
        for w in self.workers:
            w.shutdown()       # joins flush workers / worker processes
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    # ---------------------------------------------------------- rebalance

    def plan_rebalance(self, group: int, to_shard: int) -> int:
        """Register a targeted move of ``group``; returns the pane-aligned
        handoff boundary.  Only the two involved shards barrier (cap their
        pane clocks at the boundary); the move commits — open-window state
        handed off, placement overridden — once both reach it."""
        if self._mode == "process":
            raise NotImplementedError(
                "rebalance is not supported with parallel='process': the "
                "open-window instance handoff would require shipping live "
                "engine state across the process boundary")
        g, dst = int(group), int(to_shard)
        if not (0 <= dst < self.cfg.n_shards):
            raise ValueError(f"shard {dst} out of range")
        src = self.placement.shard_of(g)
        if src == dst:
            return self.workers[src].t_now
        lo = max(self.workers[src].t_now, self.workers[dst].t_now,
                 self._max_seen + 1)
        boundary = ((lo + self.pane - 1) // self.pane) * self.pane
        self._moves.append(_PendingMove(g, src, dst, boundary))
        self._apply_caps()
        return boundary

    def _apply_caps(self) -> None:
        caps: dict[int, int] = {}
        for mv in self._moves:
            for s in (mv.src, mv.dst):
                caps[s] = min(caps.get(s, mv.boundary), mv.boundary)
        for s, w in enumerate(self.workers):
            w.cap_t = caps.get(s)

    def _maybe_commit_moves(self) -> None:
        if not self._moves:
            return
        still: list[_PendingMove] = []
        for mv in self._moves:
            src, dst = self.workers[mv.src], self.workers[mv.dst]
            if src.t_now >= mv.boundary and dst.t_now >= mv.boundary:
                self._transfer(mv)
            else:
                still.append(mv)
        if len(still) != len(self._moves):
            self._moves = still
            self._apply_caps()

    def _transfer(self, mv: _PendingMove) -> None:
        """Hand the group's open-window instances to the target shard.

        Both shards sit exactly at the boundary (their caps made passing it
        impossible), so after flushing deferred micro-batches the source
        driver's instances are precisely the group's open windows at the
        boundary — and a fresh driver on the target at ``t_now=boundary``
        with those instances continues them bit-for-bit.  Shards not party
        to the move were never paused; their plan caches stay warm."""
        src, dst = self.workers[mv.src], self.workers[mv.dst]
        src.rt.flush_panes()
        dst.rt.flush_panes()
        drv = src.rt._drivers.pop(mv.group, None)
        if drv is not None:
            moved = _GroupDriver(dst.rt.rt, mv.group, mv.boundary)
            moved.insts = drv.insts
            dst.rt._drivers[mv.group] = moved
        self.placement.override(mv.group, mv.dst)

    # --------------------------------------------------------------- read

    def run(self, batch: EventBatch, chunk_ticks: int | None = None) -> dict:
        """Feed a time-sorted batch chunk-by-chunk, close, return results."""
        if len(batch):
            step = int(chunk_ticks) if chunk_ticks else self.pane
            t_hi = int(batch.time.max()) + 1
            for t0 in range(0, t_hi, step):
                self.ingest(batch.time_slice(t0, t0 + step))
        self.close()
        return self.results()

    def run_chunks(self, chunks) -> dict:
        """Feed wire chunks (e.g. ``DisorderedStream.chunks``), close,
        return results."""
        for chunk in chunks:
            self.ingest(chunk)
        self.close()
        return self.results()

    def results(self) -> dict:
        """Merged user-query results, keyed ``(query, group, w0)``.  Groups
        are disjoint per shard (and a rebalanced group's windows close on
        exactly one side of the boundary), so the union is collision-free."""
        out: dict = {}
        for w in self.workers:
            out.update(w.results())
        return out

    def aligned_results(self) -> tuple[dict, dict]:
        """Results split at the aligned frontier: ``(final, pending)``.

        A window is *final* when it closed at or before the aligned time
        and its owner is not currently a laggard; everything else —
        windows past the frontier, and every window of an excluded shard —
        is *pending* (complete on its shard, not yet fleet-final)."""
        at = self.aligner.aligned_time
        lag = self.aligner.laggards()
        final: dict = {}
        pending: dict = {}
        for s, w in enumerate(self.workers):
            for key, v in w.results().items():
                qname, _gk, w0 = key
                if s not in lag and w0 + self._within[qname] <= at:
                    final[key] = v
                else:
                    pending[key] = v
        return final, pending

    def stats(self) -> RunStats:
        """Fleet RunStats (count fields are shard-count invariant; wall
        timers sum)."""
        return RunStats.merged([w.stats() for w in self.workers])

    def error_report(self) -> dict:
        """Global certificate: router + shard accountants, cell-exact."""
        return self.admission.global_accountant(
            [w.accountant() for w in self.workers]).report()

    def window_bound(self, query: str, group: int, w0: int):
        """Global ``3^s`` / subset bound for one window (all accountants)."""
        return self.admission.global_accountant(
            [w.accountant() for w in self.workers]).window_bound(
                query, group, w0)

    def collect(self) -> dict:
        """Unified read side: router, alignment, per-shard tracks, merged
        metrics registry (when per-shard observability is on)."""
        out = {
            "router": {
                "admission": self.admission.summary(),
                "placement": {"n_shards": self.cfg.n_shards,
                              "version": self.placement.version,
                              "overrides": self.placement.overrides},
                "alignment": self.aligner.status(),
                "busy_s": self.router_busy_s,
                "chunks": self.chunks,
                "parallel": self.cfg.parallel,
                "drive_mode": self._mode,
                "drive_cycles": self.drive_cycles,
                "drive_wall_s": round(self.drive_wall_s, 4),
            },
            "shards": [w.summary() for w in self.workers],
            "stats": {k: v for k, v in vars(self.stats()).items()},
        }
        if self.cfg.obs:
            regs = [w.obs_registry() for w in self.workers]
            merged = Observability.disabled()
            for r in regs:
                if r is not None:
                    merged.registry.merge(r)
            out["metrics"] = merged.registry.collect()
            out["shard_metrics"] = [r.collect() if r is not None else {}
                                    for r in regs]
        return out
