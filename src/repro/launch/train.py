"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke \
        --steps 100 --ckpt /tmp/ckpt

``--smoke`` runs the reduced config on the local device(s); on a real TPU
fleet the same entry point shards over the production mesh (params/opt via
``param_pspecs``, batch over (pod, data)); checkpoint/restart and straggler
mitigation come from the fault-tolerant loop in repro.train.trainer.
"""

from __future__ import annotations

import argparse

from ..configs import get_config, reduce_for_smoke
from ..train.trainer import TrainLoopConfig, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=10)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    loop = TrainLoopConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                           ckpt_dir=args.ckpt,
                           ckpt_interval=args.ckpt_interval, lr=args.lr)
    params, losses, resumed = run_training(cfg, loop)
    print(f"arch={cfg.name} resumed_from={resumed} "
          f"first_loss={losses[0]:.4f} last_loss={losses[-1]:.4f}")


if __name__ == "__main__":
    main()
