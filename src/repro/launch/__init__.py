"""Launchers: production mesh construction, the multi-pod dry-run, the
training driver, the serving driver, and the distributed HAMLET service."""
