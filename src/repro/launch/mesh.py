"""Production mesh construction.

Importing this module never touches jax device state; call
``make_production_mesh`` only after the runtime's device count is final
(the dry-run forces 512 placeholder host devices *before* any jax import).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "describe_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def describe_mesh(mesh) -> str:
    return "x".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)
