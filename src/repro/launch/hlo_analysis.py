"""Trip-count-aware analysis of compiled (post-SPMD) HLO text.

XLA's HloCostAnalysis counts a while body exactly once, regardless of trip
count, so both FLOP and collective numbers from ``compiled.cost_analysis()``
undercount scanned models by the (nested) trip counts.  This module parses
the HLO text into its computation graph, reads each while's trip count from
the compare constant in its condition computation, and walks the graph from
ENTRY multiplying nested bodies by their trip counts.  It reports:

* per-kind collective bytes (per device, since post-SPMD shapes are
  per-partition), trip-count weighted;
* an HBM-traffic estimate: operand + result bytes of every top-level
  instruction (fusions counted as single instructions, so fused intermediates
  stay internal), trip-count weighted.

Validated against hand-counted loops in tests/test_dryrun_small.py.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "HloReport"]

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
                "u64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "iota",
             # control flow: carried state is read/written by the *body's*
             # instructions (counted there, per trip); the op itself moves
             # nothing through HBM
             "while", "conditional", "call"}

_TENSOR_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(ty: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _TENSOR_RE.finditer(ty):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Instr:
    name: str
    result_ty: str
    opcode: str
    operands: list[str]
    attrs: str
    opstr: str = ""


@dataclass
class _Computation:
    name: str
    instrs: list[_Instr] = field(default_factory=list)


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^{]*)?\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]\{\},\/ ]+?))\s+"
    r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _parse(text: str) -> tuple[dict[str, _Computation], str | None]:
    comps: dict[str, _Computation] = {}
    entry: str | None = None
    cur: _Computation | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = _Computation(m.group(1))
                if line.startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, ty, opcode = m.groups()
            rest = line[m.end():]
            # operands are up to the closing paren of the op call; attrs after
            depth = 1
            i = 0
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            opstr, attrs = rest[:i], rest[i + 1:]
            operands = _OPERAND_RE.findall(opstr)
            cur.instrs.append(_Instr(name, ty.strip(), opcode, operands,
                                     attrs, opstr))
    return comps, entry


@dataclass
class HloReport:
    collective_bytes: dict
    collective_counts: dict
    traffic_bytes: float
    flop_weighted_note: str = ""
    whiles: list = field(default_factory=list)


def analyze_hlo(text: str) -> HloReport:
    comps, entry = _parse(text)

    # trip counts: while conditions compare the induction var to constant(N)
    def trip_count(cond_name: str) -> int:
        c = comps.get(cond_name)
        if not c:
            return 1
        consts = []
        for ins in c.instrs:
            if ins.opcode == "constant" and re.fullmatch(r"-?\d+",
                                                         ins.opstr.strip()):
                consts.append(int(ins.opstr.strip()))
            consts += [int(x) for x in _CONST_RE.findall(ins.attrs)]
        return max(consts) if consts else 1

    # multipliers via DFS from entry
    mult: dict[str, float] = {}

    types: dict[str, str] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            types[ins.name] = ins.result_ty

    def visit(name: str, m: float):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] = mult.get(name, 0.0) + m
        for ins in comp.instrs:
            if ins.opcode == "while":
                body = _BODY_RE.search(ins.attrs)
                cond = _COND_RE.search(ins.attrs)
                n = trip_count(cond.group(1)) if cond else 1
                if body:
                    visit(body.group(1), m * n)
                if cond:
                    visit(cond.group(1), m * n)
            elif ins.opcode in ("fusion", "call", "map", "reduce",
                                "reduce-window", "sort", "scatter",
                                "conditional", "custom-call", "async-start"):
                # called computations execute with the parent's multiplier;
                # their *internals* are not HBM traffic (fused), so we do not
                # descend for traffic, but collectives never hide in fusions.
                pass

    if entry:
        visit(entry, 1.0)

    coll_bytes = {k: 0.0 for k in _COLLECTIVES}
    coll_counts = {k: 0.0 for k in _COLLECTIVES}
    traffic = 0.0
    whiles = []
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs:
            base = ins.opcode.replace("-start", "")
            if base in _COLLECTIVES and not ins.opcode.endswith("-done"):
                b = _type_bytes(ins.result_ty)
                coll_bytes[base] += b * m
                coll_counts[base] += m
            if ins.opcode == "while":
                cond = _COND_RE.search(ins.attrs)
                whiles.append((comp.name, ins.name,
                               trip_count(cond.group(1)) if cond else 1))
            if ins.opcode in _SKIP_OPS or ins.opcode.endswith("-done"):
                continue
            if ins.opcode == "dynamic-slice":
                # reads only the slice region, not the whole operand
                traffic += 2 * _type_bytes(ins.result_ty) * m
                continue
            if ins.opcode == "dynamic-update-slice":
                # in-place read-modify-write of the update region (XLA
                # aliases the buffer); counting the full result per loop
                # trip would inflate KV-cache decode traffic ~40x
                upd_ty = (types.get(ins.operands[1])
                          if len(ins.operands) > 1 else None)
                traffic += 2 * _type_bytes(upd_ty or "") * m
                continue
            tb = _type_bytes(ins.result_ty)
            for op in ins.operands:
                ty = types.get(op)
                if ty:
                    tb += _type_bytes(ty)
            traffic += tb * m

    coll_bytes["total"] = sum(coll_bytes[k] for k in _COLLECTIVES)
    return HloReport(coll_bytes, coll_counts, traffic, whiles=whiles)
