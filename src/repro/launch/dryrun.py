"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell against ShapeDtypeStruct inputs, on 512 placeholder host devices.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch gemma2-2b
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi          # all

Artifacts (per cell: HLO flops/bytes, per-device collective bytes by kind,
memory analysis, sharding fallbacks) land in benchmarks/artifacts/ for the
roofline analysis (EXPERIMENTS.md §Roofline).
"""

# The placeholder-device flag must precede EVERY jax import (jax locks the
# device count on first init), hence the top-of-module environment poke.
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse        # noqa: E402
import json            # noqa: E402
import re              # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402
from dataclasses import replace  # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCHS, get_config, input_specs  # noqa: E402
from ..configs.base import SHAPE_CELLS  # noqa: E402
from ..distributed.sharding import (batch_pspecs, cache_pspecs,  # noqa: E402
                                    param_pspecs)
from ..models import lm  # noqa: E402
from ..models.partitioning import activation_specs, unrolled_scans  # noqa: E402
from ..train.optimizer import AdamW  # noqa: E402
from .mesh import describe_mesh, make_production_mesh  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/artifacts")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
                       r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                       r"collective-permute)(?:-start|-done)?\(")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
                "u64": 8}


def _tensor_bytes(ty: str) -> int:
    m = re.match(r"(\w+)\[([\d,]*)\]", ty.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives, from the post-SPMD HLO.
    Uses each collective's result shape (per-partition)."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for m in _SHAPE_RE.finditer(hlo_text):
        tuple_tys, single_ty, kind = m.groups()
        tys = (tuple_tys.split(",") if tuple_tys else [single_ty])
        # tuple entries look like "f32[128,64]{1,0}"; keep tensor-typed ones
        b = sum(_tensor_bytes(t) for t in tys if "[" in t)
        out[kind] += b
        count[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    return out


def _spec_tree_to_shardings(tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _lower_plain(cfg, cell):
    """Lower (no mesh, no compile) with all scans unrolled; returns the
    cost_analysis dict — exact global FLOP/byte counts (XLA's HloCostAnalysis
    counts while bodies once, so the production scanned module undercounts by
    the trip count; see EXPERIMENTS.md §Method)."""
    seq, batch, step = SHAPE_CELLS[cell]
    specs = input_specs(cfg, cell)
    params_shapes = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    with unrolled_scans(True):
        if step == "train":
            opt = AdamW(lr=1e-4)
            opt_shapes = jax.eval_shape(opt.init, params_shapes)
            lowered = jax.jit(lm.train_step_fn(cfg, opt)).lower(
                params_shapes, opt_shapes, specs)
        elif step == "prefill":
            lowered = jax.jit(lm.prefill_fn(cfg)).lower(params_shapes, specs)
        else:
            cache_shapes = jax.eval_shape(
                lambda: lm.init_cache(cfg, batch, cap=seq))
            lowered = jax.jit(lm.decode_fn(cfg)).lower(
                params_shapes, cache_shapes, specs)
    return lowered.cost_analysis()


def exact_cost(cfg, cell) -> dict:
    """Exact HLO flops/bytes via 1-group/2-group extrapolation (groups are
    homogeneous, so the marginal is exact), plus the unrolled tail."""
    cyc, n_groups, tail = cfg.layer_plan()

    def costs(cfg2):
        ca = _lower_plain(cfg2, cell)
        return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))

    f1, b1 = costs(replace(cfg, n_layers=len(cyc)))
    f2, b2 = costs(replace(cfg, n_layers=2 * len(cyc)))
    mf, mb = f2 - f1, b2 - b1
    f0, b0 = f1 - mf, b1 - mb
    flops = f0 + n_groups * mf
    byts = b0 + n_groups * mb
    if tail:
        ft, bt = costs(replace(cfg, attn_pattern=tuple(tail),
                               n_layers=len(tail)))
        flops += ft - f0
        byts += bt - b0
    return {"flops_exact": flops, "bytes_lowered_exact": byts}


def _act_specs_for(mesh, cfg, cell) -> dict:
    seq, batch, step = SHAPE_CELLS[cell]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model = mesh.shape["model"]
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    specs: dict = {}
    if step == "decode" or batch % dp:
        return specs
    if step == "train":
        # residual stream [B, S, D]: batch over dp, sequence over model (SP)
        specs["act"] = (P(dp_axes, "model", None)
                        if seq % model == 0 else P(dp_axes, None, None))
        specs["logits"] = (P(dp_axes, None, "model")
                           if cfg.vocab % model == 0 else
                           P(dp_axes, None, None))
    if step == "prefill" and cfg.n_heads % model != 0:
        # per-chunk sequence-parallel attention for head counts that don't
        # divide TP: q/k/v replicate over model, each query chunk's rows
        # shard over model (local softmax), outputs re-concatenate.
        # Prefill only: in training the constraint's backward inserts
        # per-chunk gather/scatter pairs that cost more than the forward
        # saves (A/B in EXPERIMENTS.md §Perf it.8).
        specs["attn_kv"] = P(dp_axes, None, None, None)
        specs["attn_chunk"] = P(dp_axes, "model", None, None)
        specs["attn_chunks"] = P(None, dp_axes, "model", None, None)
    return specs


def lower_cell(arch: str, cell: str, mesh, *, compile_: bool = True) -> dict:
    cfg = get_config(arch)
    rec: dict = {"arch": arch, "cell": cell, "mesh": describe_mesh(mesh),
                 "status": "ok"}
    skip = cfg.supports_cell(cell)
    if skip:
        rec.update(status="skipped", reason=skip)
        return rec

    seq, batch, step = SHAPE_CELLS[cell]
    specs = input_specs(cfg, cell)
    notes: list = []

    params_shapes = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    p_spec = param_pspecs(params_shapes, mesh, notes)
    p_shard = _spec_tree_to_shardings(p_spec, mesh)
    b_spec = batch_pspecs(specs, mesh, global_batch=batch)
    b_shard = _spec_tree_to_shardings(b_spec, mesh)

    t0 = time.time()
    with mesh, activation_specs(**_act_specs_for(mesh, cfg, cell)):
        if step == "train":
            opt = AdamW(lr=1e-4, state_dtype="bfloat16"
                        if "400b" in arch else None)
            opt_shapes = jax.eval_shape(opt.init, params_shapes)
            o_spec = param_pspecs(opt_shapes, mesh, notes)
            o_shard = _spec_tree_to_shardings(o_spec, mesh)
            fn = lm.train_step_fn(cfg, opt)
            lowered = jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard),
                              donate_argnums=(0, 1)).lower(
                params_shapes, opt_shapes, specs)
        elif step == "prefill":
            fn = lm.prefill_fn(cfg)
            lowered = jax.jit(fn, in_shardings=(p_shard, b_shard)).lower(
                params_shapes, specs)
        else:  # decode
            cache_shapes = jax.eval_shape(
                lambda: lm.init_cache(cfg, batch, cap=seq))
            c_spec = cache_pspecs(cache_shapes, mesh, batch=batch)
            c_shard = _spec_tree_to_shardings(c_spec, mesh)
            fn = lm.decode_fn(cfg)
            lowered = jax.jit(fn, in_shardings=(p_shard, c_shard, b_shard),
                              donate_argnums=(1,)).lower(
                params_shapes, cache_shapes, specs)
        rec["lower_s"] = round(time.time() - t0, 2)
        if not compile_:
            return rec
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    try:
        cost = compiled.cost_analysis()
        rec["flops"] = float(cost.get("flops", 0.0))
        rec["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        rec["cost_error"] = repr(e)
    try:
        mem = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(mem, k):
                rec[k] = int(getattr(mem, k))
    except Exception as e:  # pragma: no cover
        rec["memory_error"] = repr(e)
    try:
        from .hlo_analysis import analyze_hlo

        rep = analyze_hlo(compiled.as_text())
        rec["collectives"] = {k: v for k, v in rep.collective_bytes.items()}
        rec["collective_counts"] = {k: v for k, v in
                                    rep.collective_counts.items() if v}
        rec["traffic_bytes_per_device"] = rep.traffic_bytes
        rec["whiles"] = [(c, n) for c, _, n in rep.whiles]
    except Exception as e:  # pragma: no cover
        rec["hlo_analysis_error"] = repr(e)
    rec["sharding_fallbacks"] = [f"{p}: {r}" for p, s, l, r in notes]

    # exact trip-count-corrected global FLOPs (unrolled-lowered extrapolation)
    try:
        rec.update(exact_cost(cfg, cell))
    except Exception as e:  # pragma: no cover
        rec["exact_cost_error"] = repr(e)
    return rec


def hamlet_pane_step(mesh, dense_frac: float = 0.9):
    """Lower the HAMLET dataplane on the production mesh: group-partitioned
    burst propagation + per-query snapshot resolution (beyond the 40 cells).

    Mirrors the engine's production mix (§Perf it.5): ~90% of bursts have no
    edge predicates / divergence and use the O(b) dense closed form; the
    rest run the blocked Neumann solve (the Pallas kernel's algorithm)."""
    from ..kernels import ref
    from .hlo_analysis import analyze_hlo

    G, b, B, k, C = 4096, 256, 8, 64, 16   # groups, burst, basis, queries, C
    shards = 512 if "pod" in mesh.axis_names else 256
    dp_size = shards // mesh.shape["model"]
    Gd = (int(G * dense_frac) // dp_size) * dp_size   # dp-divisible split
    Gm = G - Gd

    def pane_step(base_d, base_m, masks, W, u):
        coef_d = jax.vmap(ref.prefix_propagate_dense)(base_d)
        coef_m = jax.vmap(lambda bb, mm: ref.masked_prefix_propagate_blocked(
            bb, mm, tile=128))(base_m, masks)
        coef = jnp.concatenate([coef_d, coef_m], axis=0)
        counts = jnp.einsum("gbB,gkBC,gkC->gbk", coef, W, u)
        return coef.sum(axis=1), counts.sum(axis=1)

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sh = lambda *spec: NamedSharding(mesh, P(*spec))
    args = (
        jax.ShapeDtypeStruct((Gd, b, B), jnp.float32),
        jax.ShapeDtypeStruct((Gm, b, B), jnp.float32),
        jax.ShapeDtypeStruct((Gm, b, b), jnp.float32),
        jax.ShapeDtypeStruct((G, k, B, C), jnp.float32),
        jax.ShapeDtypeStruct((G, k, C), jnp.float32),
    )
    in_sh = (sh(dp, None, None), sh(dp, None, None), sh(dp, None, None),
             sh(dp, "model", None, None), sh(dp, "model", None))
    with mesh:
        lowered = jax.jit(pane_step, in_shardings=in_sh).lower(*args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    rep = analyze_hlo(compiled.as_text())
    return {"arch": "hamlet-pane-step",
            "cell": f"G{G}xb{b}xB{B}xk{k}-dense{dense_frac}",
            "mesh": describe_mesh(mesh), "status": "ok",
            "flops": float(cost.get("flops", 0)),
            "flops_exact": float(cost.get("flops", 0)),  # no while loops
            "traffic_bytes_per_device": rep.traffic_bytes,
            "collectives": dict(rep.collective_bytes)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--cell", default="all")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "dry-run expects 512 placeholder devices"

    archs = ARCHS if args.arch == "all" else [args.arch]
    cells = list(SHAPE_CELLS) if args.cell == "all" else [args.cell]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    records = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        try:
            records.append(hamlet_pane_step(mesh))
            print(json.dumps(records[-1]))
        except Exception:
            traceback.print_exc()
        for arch in archs:
            for cell in cells:
                try:
                    rec = lower_cell(arch, cell, mesh,
                                     compile_=not args.no_compile)
                except Exception as e:
                    rec = {"arch": arch, "cell": cell,
                           "mesh": describe_mesh(mesh), "status": "error",
                           "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                records.append(rec)
                print(json.dumps({k: v for k, v in rec.items()
                                  if k != "trace"}))
        out = args.out or os.path.join(
            ARTIFACT_DIR, f"dryrun_{'multi' if multi else 'single'}.json")
        with open(out, "w") as f:
            json.dump([r for r in records
                       if r["mesh"] == describe_mesh(mesh)], f, indent=1)

    n_err = sum(r["status"] == "error" for r in records)
    print(f"\n{len(records)} cells, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
