"""Distributed HAMLET streaming service driver.

Processes a bursty event stream pane-by-pane through the HAMLET runtime
(group partitions are data-parallel; this single-host driver iterates them,
while the dry-run proves the pane dataplane lowers onto the production mesh).

    PYTHONPATH=src python -m repro.launch.hamlet_service --minutes 2 \
        --events-per-minute 500 --policy dynamic
"""

from __future__ import annotations

import argparse
import time

from ..core.engine import HamletRuntime
from ..core.optimizer import AlwaysShare, DynamicPolicy, FlopPolicy, NeverShare
from ..core.pattern import EventType, Kleene, Not, Seq
from ..core.query import Pred, Query, Workload, agg_avg, agg_sum, count_star
from ..streams.generator import RIDESHARING_SCHEMA, ridesharing_stream

POLICIES = {"dynamic": DynamicPolicy, "always": AlwaysShare,
            "never": NeverShare, "flop": FlopPolicy}


def ridesharing_workload(n_queries: int = 3) -> Workload:
    """The paper's Fig. 1 workload shape, replicated/perturbed to n queries."""
    R, T, P, D, C = (EventType(t) for t in
                     ("Request", "Travel", "Pickup", "Dropoff", "Cancel"))
    qs = [
        Query("q1", Seq(R, Kleene(T), Not(P)),
              aggs=(count_star(), agg_sum("Travel", "duration")),
              within=30, slide=5, group_by=("district",)),
        Query("q2", Seq(R, Kleene(T), D),
              aggs=(count_star(), agg_avg("Travel", "speed")),
              preds={"Request": [Pred("rtype", "<", 5.0)]},
              within=30, slide=5, group_by=("district",)),
        Query("q3", Seq(R, Kleene(T), C),
              aggs=(count_star(), agg_sum("Travel", "duration")),
              preds={"Travel": [Pred("speed", "<", 6.0)]},
              within=20, slide=5, group_by=("district",)),
    ]
    out = list(qs)
    i = 0
    while len(out) < n_queries:
        q = qs[i % 3]
        out.append(Query(f"q{len(out) + 1}", q.pattern, aggs=q.aggs,
                         preds={"Travel": [Pred("speed", "<",
                                                2.0 + (i % 8))]},
                         within=q.within, slide=q.slide,
                         group_by=q.group_by))
        i += 1
    return Workload(RIDESHARING_SCHEMA, out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=int, default=2)
    ap.add_argument("--events-per-minute", type=int, default=500)
    ap.add_argument("--queries", type=int, default=3)
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--policy", choices=list(POLICIES), default="dynamic")
    ap.add_argument("--backend", default="np")
    args = ap.parse_args()

    wl = ridesharing_workload(args.queries)
    batch = ridesharing_stream(events_per_minute=args.events_per_minute,
                               minutes=args.minutes, n_groups=args.groups)
    rt = HamletRuntime(wl, policy=POLICIES[args.policy](),
                       backend=args.backend)
    t0 = time.time()
    res = rt.run(batch, t_end=args.minutes * 60)
    dt = time.time() - t0
    s = rt.stats
    print(f"policy={args.policy} events={len(batch)} "
          f"windows={s.windows_emitted} results={len(res)}")
    print(f"wall={dt:.3f}s throughput={len(batch) / dt:.0f} ev/s "
          f"latency/pane={1e3 * dt / max(1, s.panes):.2f} ms")
    print(f"bursts={s.bursts} shared={s.shared_bursts} "
          f"graphlets={s.graphlets} snapshots={s.snapshots_created} "
          f"propagated={s.snapshots_propagated} decisions={s.decisions}")
    some = sorted(res.items())[:5]
    for k, v in some:
        print(" ", k, {a: round(x, 2) for a, x in v.items()})


if __name__ == "__main__":
    main()
