"""Distributed HAMLET streaming service driver.

Processes a bursty event stream pane-by-pane through the HAMLET runtime
(group partitions are data-parallel; this single-host driver iterates them,
while the dry-run proves the pane dataplane lowers onto the production mesh).

    PYTHONPATH=src python -m repro.launch.hamlet_service --minutes 2 \
        --events-per-minute 500 --policy dynamic

``--overload`` switches to the bounded-latency runtime: an overload scenario
stream (rate ramp + flash crowds) is offered at ``--offered-x`` times the
calibrated capacity and processed through ingress backpressure, per-pane
admission control, the selected shedding policy, and the PID latency
controller:

    PYTHONPATH=src python -m repro.launch.hamlet_service --overload \
        --offered-x 2 --shed-policy benefit_weighted --recall

``--shards N --tenants M`` runs the sharded multi-tenant service tier
(``repro.shardsvc``): M tenants' overload streams compose into one stream,
a consistent-hash router places tenant groups on N shard workers (each its
own runtime + plan cache + PID loop), admission happens at the router, and
per-shard frontiers negotiate fleet progress through the aligned-epoch
coordinator.  ``--flash-tenant`` aims a flash crowd at one tenant,
``--rebalance`` moves that tenant's hottest group to the least-busy shard
mid-stream:

    PYTHONPATH=src python -m repro.launch.hamlet_service --shards 4 \
        --tenants 8 --minutes 2 --flash-tenant 0 --rebalance

``--serve --sessions N`` runs the asynchronous serving front-end
(``repro.serve``): N concurrent client sessions trickle events in on real
threads, the continuous-batching scheduler merges them by watermark into
the same K-pane micro-batched flush path the batch runtime uses, and each
session's inbox receives the emissions (and retract/amend revisions) for
the tenant groups it subscribes to, with per-session delivery-latency
histograms in the summary:

    PYTHONPATH=src python -m repro.launch.hamlet_service --serve \
        --sessions 16 --tenants 4 --minutes 2

``--listen HOST:PORT`` puts the same front-end on a real socket
(``repro.serve.transport``: zero-copy chunk frames, credit-based
backpressure) and waits for ``--sessions`` clients; ``--connect
HOST:PORT --session-index i`` runs one paced client session from another
process, so the paced-session study crosses real process boundaries:

    PYTHONPATH=src python -m repro.launch.hamlet_service \
        --listen 127.0.0.1:7431 --sessions 2 --tenants 2 &
    for i in 0 1; do
        PYTHONPATH=src python -m repro.launch.hamlet_service \
            --connect 127.0.0.1:7431 --sessions 2 --session-index $i \
            --tenants 2 &
    done

``--trace out.jsonl`` attaches the observability layer (``repro.obs``):
pane-lifecycle spans are exported as Chrome-trace JSONL (convert with
``python -m repro.obs.trace out.jsonl out.json`` and load in Perfetto),
and the run report gains the per-phase span-sum vs ``RunStats`` check plus
the sharing-decision audit summary.
"""

from __future__ import annotations

import argparse
import time

from ..core.engine import HamletRuntime
from ..core.optimizer import AlwaysShare, DynamicPolicy, FlopPolicy, NeverShare
from ..core.pattern import EventType, Kleene, Not, Seq
from ..core.query import Pred, Query, Workload, agg_avg, agg_sum, count_star
from ..obs import PHASES, Observability
from ..streams.generator import (RIDESHARING_SCHEMA, OverloadStreamConfig,
                                 overload_stream, ridesharing_stream)

POLICIES = {"dynamic": DynamicPolicy, "always": AlwaysShare,
            "never": NeverShare, "flop": FlopPolicy}


def ridesharing_workload(n_queries: int = 3) -> Workload:
    """The paper's Fig. 1 workload shape, replicated/perturbed to n queries."""
    R, T, P, D, C = (EventType(t) for t in
                     ("Request", "Travel", "Pickup", "Dropoff", "Cancel"))
    qs = [
        Query("q1", Seq(R, Kleene(T), Not(P)),
              aggs=(count_star(), agg_sum("Travel", "duration")),
              within=30, slide=5, group_by=("district",)),
        Query("q2", Seq(R, Kleene(T), D),
              aggs=(count_star(), agg_avg("Travel", "speed")),
              preds={"Request": [Pred("rtype", "<", 5.0)]},
              within=30, slide=5, group_by=("district",)),
        Query("q3", Seq(R, Kleene(T), C),
              aggs=(count_star(), agg_sum("Travel", "duration")),
              preds={"Travel": [Pred("speed", "<", 6.0)]},
              within=20, slide=5, group_by=("district",)),
    ]
    out = list(qs)
    i = 0
    while len(out) < n_queries:
        q = qs[i % 3]
        out.append(Query(f"q{len(out) + 1}", q.pattern, aggs=q.aggs,
                         preds={"Travel": [Pred("speed", "<",
                                                2.0 + (i % 8))]},
                         within=q.within, slide=q.slide,
                         group_by=q.group_by))
        i += 1
    return Workload(RIDESHARING_SCHEMA, out)


def _make_obs(args) -> Observability | None:
    if not args.trace:
        return None
    return Observability(sample=args.trace_sample)


def _obs_report(obs: Observability, path: str, stats) -> None:
    """Export the trace and print the observability run report: span sums
    checked against the RunStats phase timers, plus the audit summary."""
    n = obs.export_trace(path)
    print(f"trace: {n} events -> {path} "
          f"(dropped={obs.tracer.dropped}, sample={obs.tracer.sample}); "
          f"perfetto: python -m repro.obs.trace {path} {path}.chrome.json")
    totals = obs.phase_totals()
    for ph in PHASES:
        span_s = totals.get(ph, 0.0)
        stat_s = getattr(stats, f"{ph}_s")
        dev = abs(span_s - stat_s) / stat_s * 100 if stat_s else 0.0
        print(f"  {ph:8s} spans={span_s * 1e3:9.2f} ms "
              f"stats={stat_s * 1e3:9.2f} ms (dev {dev:.2f}%)")
    if obs.audit is not None:
        a = obs.audit.summary()
        print(f"audit: {a['decisions']} decisions "
              f"(shared={a['shared']} split={a['split']} "
              f"flips={a['flips']} sites={a['sites']} "
              f"dropped={a['dropped']})")


def run_overload(args) -> None:
    from ..overload import OverloadConfig, OverloadRuntime

    wl = ridesharing_workload(args.queries)
    t_end = args.minutes * 60
    stream = overload_stream(OverloadStreamConfig(
        schema=RIDESHARING_SCHEMA,
        base_events_per_minute=args.events_per_minute,
        minutes=args.minutes, ramp_to=1.5,
        flash_crowds=((t_end // 3, 20, 3.0),),
        n_groups=args.groups, type_weights=(1, 1, 6, 1, 1, 1)))

    # calibrate capacity (events/s the unshedded engine sustains) on a prefix
    sample = stream.time_slice(0, min(60, t_end))
    cal = HamletRuntime(wl, policy=POLICIES[args.policy]())
    t0 = time.perf_counter()
    cal.run(sample, t_end=min(60, t_end))
    capacity = len(sample) / max(time.perf_counter() - t0, 1e-9)

    pane = cal.pane
    tick_seconds = (len(stream) / t_end) / (args.offered_x * capacity)
    slo_ms = args.slo_ms or pane * tick_seconds * 1e3  # default: real time
    cfg = OverloadConfig(
        slo_ms=slo_ms, shed_policy=args.shed_policy,
        tick_seconds=tick_seconds,
        pane_budget_events=int(capacity * pane * tick_seconds))
    obs = _make_obs(args)
    ort = OverloadRuntime(wl, cfg, policy=POLICIES[args.policy](),
                          backend=args.backend, obs=obs)
    res = ort.run(stream, t_end)
    s = ort.metrics.summary()
    if obs is not None:
        _obs_report(obs, args.trace, ort.stats)
    print(f"offered_x={args.offered_x} capacity={capacity:.0f} ev/s "
          f"slo={slo_ms:.2f} ms policy={args.shed_policy}")
    print(f"offered={s['offered']} admitted={s['admitted']} "
          f"shed={s['shed']} ({100 * s['shed_frac']:.1f}%) "
          f"ingress_dropped={ort.queue.dropped} rejected={ort.queue.rejected}")
    print(f"pane proc p50={s['p50_proc_ms']:.2f} ms "
          f"p99={s['p99_proc_ms']:.2f} ms ({s['p99_proc_ms'] / slo_ms:.2f}x slo) "
          f"| e2e p99={s['p99_lat_ms']:.2f} ms "
          f"mean_shed_ratio={s['mean_shed_ratio']:.2f}")
    for name, rep in sorted(ort.accountant.report().items()):
        print(f"  {name}: shed kleene={rep.shed_kleene} "
              f"critical={rep.shed_critical} negative={rep.shed_negative} "
              f"subset_guarantee={rep.subset_guarantee}")
    if args.recall:
        truth = HamletRuntime(wl, policy=POLICIES[args.policy]()).run(
            stream, t_end)
        num = den = 0.0
        for k, v in truth.items():
            if v.get("COUNT(*)", 0.0) <= 0:
                continue
            num += res.get(k, {}).get("COUNT(*)", 0.0) > 0
            den += 1
        print(f"detection recall={num / max(den, 1):.3f} "
              f"over {int(den)} windows")


def run_sharded(args) -> None:
    from ..overload import OverloadConfig
    from ..shardsvc import ShardedHamletService, ShardServiceConfig
    from ..streams.generator import TenantStreamConfig, tenant_stream

    wl = ridesharing_workload(args.queries)
    t_end = args.minutes * 60
    stream = tenant_stream(TenantStreamConfig(
        schema=RIDESHARING_SCHEMA, n_tenants=args.tenants,
        groups_per_tenant=args.groups_per_tenant,
        base_events_per_minute=args.events_per_minute,
        minutes=args.minutes, rate_skew=args.rate_skew,
        flash_tenant=args.flash_tenant,
        flash=(t_end // 3, 30, 4.0),
        type_weights=(1, 1, 6, 1, 1, 1)))
    cfg = ShardServiceConfig(
        n_shards=args.shards, groups_per_tenant=args.groups_per_tenant,
        admission=args.admission,
        overload=OverloadConfig(shed_policy=args.shed_policy,
                                fixed_shed=args.fixed_shed,
                                micro_batch=4))
    svc = ShardedHamletService(wl, cfg, policy=POLICIES[args.policy](),
                               backend=args.backend)
    t0 = time.time()
    moved_at = None
    for c0 in range(0, t_end, svc.pane):
        svc.ingest(stream.time_slice(c0, c0 + svc.pane))
        if args.rebalance and moved_at is None and c0 >= t_end // 2:
            hot = args.flash_tenant or 0
            g = hot * args.groups_per_tenant
            busy = [w.busy_s for w in svc.workers]
            target = int(min(range(args.shards), key=busy.__getitem__))
            moved_at = svc.plan_rebalance(g, target)
            print(f"rebalance: group {g} -> shard {target} "
                  f"at boundary {moved_at}")
    svc.close()
    res = svc.results()
    dt = time.time() - t0
    col = svc.collect()
    st = svc.stats()
    print(f"shards={args.shards} tenants={args.tenants} "
          f"events={len(stream)} windows={st.windows_emitted} "
          f"results={len(res)} wall={dt:.3f}s")
    print(f"router: {col['router']['admission']} busy={svc.router_busy_s:.3f}s")
    print(f"alignment: {col['router']['alignment']}")
    for s in col["shards"]:
        ov = s["overload"]
        print(f"  shard {s['shard']}: busy={s['busy_s']:.3f}s "
              f"panes={ov['panes']} admitted={ov['admitted']} "
              f"p99_proc={ov['p99_proc_ms']:.2f} ms "
              f"cache_hit={s['plan_cache']['hit_rate']:.2f}")
    for name, rep in sorted(svc.error_report().items()):
        print(f"  {name}: shed kleene={rep.shed_kleene} "
              f"critical={rep.shed_critical} negative={rep.shed_negative} "
              f"subset_guarantee={rep.subset_guarantee}")


def _serving_stream(args):
    """The tenant stream every serving mode shares — deterministic, so a
    ``--connect`` client in another process rebuilds the identical split."""
    import numpy as np

    from ..core.events import EventBatch
    from ..streams.generator import TenantStreamConfig, tenant_stream

    stream = tenant_stream(TenantStreamConfig(
        schema=RIDESHARING_SCHEMA, n_tenants=args.tenants,
        groups_per_tenant=args.groups_per_tenant,
        base_events_per_minute=args.events_per_minute,
        minutes=args.minutes, rate_skew=args.rate_skew,
        type_weights=(1, 1, 6, 1, 1, 1)))
    if stream.seq is None:
        # original positions as producer seq: the serving merge then breaks
        # timestamp ties exactly like the batch run would
        stream = EventBatch(schema=stream.schema, type_id=stream.type_id,
                            time=stream.time, attrs=stream.attrs,
                            group=stream.group,
                            seq=np.arange(len(stream), dtype=np.int64))
    return stream


def _session_part(stream, i, n_sessions, tenants, groups_per_tenant):
    """Session ``i``'s (tenant, stream slice): sessions round-robin over
    tenants, each tenant's events stride-split across its sessions."""
    import numpy as np

    t = i % tenants
    lo, hi = t * groups_per_tenant, (t + 1) * groups_per_tenant
    idx = np.flatnonzero((stream.group >= lo) & (stream.group < hi))
    stride = max(1, n_sessions // tenants)
    return t, stream.select(idx[i // tenants::stride])


def _parse_hostport(s: str) -> tuple[str, int]:
    host, _, port = s.rpartition(":")
    return host or "127.0.0.1", int(port)


def run_serving(args) -> None:
    """Asynchronous serving demo: ``--sessions`` concurrent trickle clients
    on real threads, merged by the continuous-batching scheduler into the
    shared K-pane flush path, results routed back per session."""
    import threading

    from ..overload import OverloadConfig
    from ..serve import ServingFrontend

    wl = ridesharing_workload(args.queries)
    stream = _serving_stream(args)
    obs = _make_obs(args)
    fe = ServingFrontend(
        wl, backend="overload",
        overload=OverloadConfig(shed_policy=args.shed_policy, micro_batch=4),
        groups_per_tenant=args.groups_per_tenant, obs=obs)
    n_sessions = max(1, args.sessions)
    parts, handles = [], []
    for i in range(n_sessions):
        t, part = _session_part(stream, i, n_sessions, args.tenants,
                                args.groups_per_tenant)
        parts.append(part)
        handles.append(fe.open_session(tenant=t))
    fe.start(interval_s=0.001)

    def trickle(h, part):
        hi = int(part.time.max()) + 1 if len(part) else 0
        for c0 in range(0, hi, fe.pane):
            h.submit(part.time_slice(c0, c0 + fe.pane))
            h.advance_to(min(c0 + fe.pane, hi))
            time.sleep(0.001)
        h.close()

    t0 = time.time()
    threads = [threading.Thread(target=trickle, args=(h, p))
               for h, p in zip(handles, parts)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    res = fe.drain()
    dt = time.time() - t0
    summ = fe.summary()
    if obs is not None:
        n = obs.export_trace(args.trace)
        print(f"trace: {n} events -> {args.trace} (serving spans + "
              f"per-session latency histograms in obs.collect)")
    lat = summ["latency_ms"]
    print(f"serve: sessions={n_sessions} tenants={len(summ['tenants'])} "
          f"events={summ['submitted']} windows={len(res)} wall={dt:.3f}s")
    print(f"deliveries={summ['deliveries']} sealed_to={summ['sealed_to']} "
          f"pump_cycles={summ['pump_cycles']} "
          f"latency p50={lat['p50']:.1f} ms p99={lat['p99']:.1f} ms")
    worst = sorted(summ["sessions"].items(),
                   key=lambda kv: -kv[1].get("p99_ms", 0.0))[:4]
    for sid, s in worst:
        print(f"  session {sid}: tenant={s['tenant']} "
              f"submitted={s['submitted']} delivered={s['delivered']} "
              f"p50={s.get('p50_ms', 0.0):.1f} ms "
              f"p99={s.get('p99_ms', 0.0):.1f} ms")


def run_listen(args) -> None:
    """Wire-transport server: the serving front-end behind a real socket
    (``repro.serve.transport``), zero-copy chunk ingest + credit-based
    backpressure.  Waits for ``--sessions`` clients to connect and close,
    then drains and reports:

        PYTHONPATH=src python -m repro.launch.hamlet_service \\
            --listen 127.0.0.1:7431 --sessions 8 --tenants 4
    """
    from ..overload import OverloadConfig
    from ..serve import ServingFrontend, ServingServer

    host, port = _parse_hostport(args.listen)
    wl = ridesharing_workload(args.queries)
    obs = _make_obs(args)
    fe = ServingFrontend(
        wl, backend="overload",
        overload=OverloadConfig(shed_policy=args.shed_policy, micro_batch=4),
        groups_per_tenant=args.groups_per_tenant, obs=obs)
    srv = ServingServer(fe, host, port, credit_window=args.credit_window)
    host, port = srv.start()
    n = max(1, args.sessions)
    print(f"listening on {host}:{port}; waiting for {n} session(s) "
          f"(connect with --connect {host}:{port} --session-index i)")
    t0 = time.time()
    try:
        while True:
            sess = fe.summary()["sessions"]
            if len(sess) >= n and all(s["closed"] for s in sess.values()):
                break
            time.sleep(0.05)
        res = srv.drain()
    finally:
        srv.stop()
    dt = time.time() - t0
    summ, wire = fe.summary(), srv.summary()
    lat = summ["latency_ms"]
    print(f"serve: sessions={len(summ['sessions'])} "
          f"events={summ['submitted']} windows={len(res)} wall={dt:.3f}s")
    print(f"wire: frames_in={wire['frames_in']} "
          f"bytes_in={wire['bytes_in']} bytes_out={wire['bytes_out']} "
          f"disconnects={wire['disconnects']}")
    cr = wire["credit"]
    print(f"credit: window={cr['window']} granted={cr['granted']} "
          f"withheld={cr['withheld']} "
          f"staging_hwm={summ['staging']['hwm']}")
    print(f"latency p50={lat['p50']:.1f} ms p99={lat['p99']:.1f} ms "
          f"deliveries={summ['deliveries']}")


def run_connect(args) -> None:
    """Wire-transport client: one session over a real socket, pacing its
    deterministic split of the tenant stream pane-by-pane:

        PYTHONPATH=src python -m repro.launch.hamlet_service \\
            --connect 127.0.0.1:7431 --sessions 8 --session-index 3 \\
            --tenants 4
    """
    from ..serve import ServingClient

    host, port = _parse_hostport(args.connect)
    stream = _serving_stream(args)
    n = max(1, args.sessions)
    i = args.session_index % n
    tenant, part = _session_part(stream, i, n, args.tenants,
                                 args.groups_per_tenant)
    c = ServingClient(host, port, tenant=tenant)
    t0 = time.time()
    hi = int(part.time.max()) + 1 if len(part) else 0
    pane = c.pane or 10
    for c0 in range(0, hi, pane):
        c.submit(part.time_slice(c0, c0 + pane))
        c.advance_to(min(c0 + pane, hi))
        time.sleep(args.pace_s)
    c.close()
    got = list(c.deliveries())
    dt = time.time() - t0
    c.shutdown()
    res = c.results or {}
    print(f"session {c.sid}: tenant={tenant} submitted={len(part)} "
          f"deliveries={len(got)} windows={len(res)} wall={dt:.3f}s "
          f"blocked={c.blocked_s * 1e3:.1f} ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=int, default=2)
    ap.add_argument("--events-per-minute", type=int, default=500)
    ap.add_argument("--queries", type=int, default=3)
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--policy", choices=list(POLICIES), default="dynamic")
    ap.add_argument("--backend", default="np")
    ap.add_argument("--overload", action="store_true",
                    help="bounded-latency runtime on an overload scenario")
    ap.add_argument("--serve", action="store_true",
                    help="async serving front-end: concurrent trickle "
                         "sessions merged into shared micro-batched flushes")
    ap.add_argument("--sessions", type=int, default=8,
                    help="concurrent client sessions for --serve; expected "
                         "session count for --listen/--connect")
    ap.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="serve the front-end on a real socket and wait "
                         "for --sessions clients")
    ap.add_argument("--connect", default=None, metavar="HOST:PORT",
                    help="run one socket client session against --listen")
    ap.add_argument("--session-index", type=int, default=0,
                    help="which deterministic session split this "
                         "--connect client drives")
    ap.add_argument("--credit-window", type=int, default=2048,
                    help="per-session event credit window for --listen")
    ap.add_argument("--pace-s", type=float, default=0.001,
                    help="--connect inter-chunk pacing sleep")
    ap.add_argument("--shards", type=int, default=0,
                    help="run the sharded multi-tenant service with N shards")
    ap.add_argument("--tenants", type=int, default=4,
                    help="tenant count for the sharded service")
    ap.add_argument("--groups-per-tenant", type=int, default=2)
    ap.add_argument("--rate-skew", type=float, default=0.0,
                    help="Zipf exponent of per-tenant rates (0 = uniform)")
    ap.add_argument("--flash-tenant", type=int, default=None,
                    help="aim a flash crowd at this tenant")
    ap.add_argument("--rebalance", action="store_true",
                    help="move the hot tenant's lead group to the "
                         "least-busy shard mid-stream")
    ap.add_argument("--admission", default="global_fixed",
                    choices=["none", "global_fixed", "per_shard"],
                    help="router admission mode for the sharded service")
    ap.add_argument("--fixed-shed", type=float, default=None,
                    help="fixed router shed ratio (global_fixed admission)")
    ap.add_argument("--offered-x", type=float, default=2.0,
                    help="offered load as a multiple of calibrated capacity")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="pane latency SLO (default: the real-time pane budget)")
    ap.add_argument("--shed-policy", default="benefit_weighted",
                    choices=["none", "drop_tail", "random", "benefit_weighted"])
    ap.add_argument("--recall", action="store_true",
                    help="also compute recall vs the unshedded run")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="attach the observability layer and export the "
                         "pane-span trace as Chrome-trace JSONL")
    ap.add_argument("--trace-sample", type=int, default=1,
                    help="per-pane track sampling: trace every Nth pane")
    args = ap.parse_args()

    if args.listen:
        run_listen(args)
        return
    if args.connect:
        run_connect(args)
        return
    if args.serve:
        run_serving(args)
        return
    if args.shards > 0:
        run_sharded(args)
        return
    if args.overload:
        run_overload(args)
        return

    wl = ridesharing_workload(args.queries)
    batch = ridesharing_stream(events_per_minute=args.events_per_minute,
                               minutes=args.minutes, n_groups=args.groups)
    obs = _make_obs(args)
    rt = HamletRuntime(wl, policy=POLICIES[args.policy](),
                       backend=args.backend, obs=obs)
    t0 = time.time()
    res = rt.run(batch, t_end=args.minutes * 60)
    dt = time.time() - t0
    s = rt.stats
    if obs is not None:
        _obs_report(obs, args.trace, s)
    print(f"policy={args.policy} events={len(batch)} "
          f"windows={s.windows_emitted} results={len(res)}")
    print(f"wall={dt:.3f}s throughput={len(batch) / dt:.0f} ev/s "
          f"latency/pane={1e3 * dt / max(1, s.panes):.2f} ms")
    print(f"bursts={s.bursts} shared={s.shared_bursts} "
          f"graphlets={s.graphlets} snapshots={s.snapshots_created} "
          f"propagated={s.snapshots_propagated} decisions={s.decisions}")
    some = sorted(res.items())[:5]
    for k, v in some:
        print(" ", k, {a: round(x, 2) for a, x in v.items()})


if __name__ == "__main__":
    main()
