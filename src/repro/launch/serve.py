"""Serving driver: batched prefill + decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduce_for_smoke
from ..models.lm import decode_fn, init_cache, init_params, prefill_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    B, Lp, G = args.batch, args.prompt_len, args.gen
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, Lp)), jnp.int32)

    cache = init_cache(cfg, B, cap=Lp + G)
    prefill = jax.jit(prefill_fn(cfg, with_cache=True))
    decode = jax.jit(decode_fn(cfg))

    batch = {"tokens": toks}
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, Lp, cfg.d_model)), jnp.float32)
    t0 = time.time()
    logits, cache = prefill(params, cache, batch)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [nxt]
    for i in range(G - 1):
        step = {"token": nxt[:, None],
                "pos": jnp.full((B,), Lp + i, jnp.int32)}
        if cfg.mrope_sections:
            step["positions"] = jnp.broadcast_to(
                jnp.asarray(Lp + i, jnp.int32), (3, B, 1))
        logits, cache = decode(params, cache, step)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(nxt)
    dt = time.time() - t0
    gen = np.stack([np.asarray(o) for o in out], axis=1)
    print(f"arch={cfg.name} generated {gen.shape} in {dt:.2f}s "
          f"({B * G / dt:.1f} tok/s)")
    print(gen[:, :12])


if __name__ == "__main__":
    main()
