"""Shared neural layers: RMSNorm, RoPE / M-RoPE / sinusoidal positions,
GQA attention (full / sliding-window, logit softcap, QK-norm, KV cache),
and gated/plain MLPs.  Pure functions over parameter pytrees."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm", "apply_rope", "apply_mrope", "sincos_positions",
    "attention_block", "mlp_block", "init_attention", "init_mlp",
]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------- positions


def _rope_angles(positions: jax.Array, dims: int, theta: float) -> jax.Array:
    """positions [...]; returns [..., dims/2] angles."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dims, 2, dtype=jnp.float32) / dims))
    return positions.astype(jnp.float32)[..., None] * freqs


def _rotate(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x [..., H, hd]; angles [..., hd/2] broadcast over heads."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = jnp.cos(angles)[..., None, :]
    s = jnp.sin(angles)[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [B, S, H, hd]; positions [B, S]."""
    return _rotate(x, _rope_angles(positions, x.shape[-1], theta))


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE: positions [3, B, S] (temporal, h, w);
    the hd/2 rotary frequencies are partitioned into three sections, each
    driven by its own position stream."""
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    angles = []
    for stream, sec in enumerate(sections):
        a = _rope_angles(positions[stream], hd, theta)  # [B, S, hd/2]
        angles.append(a[..., sum(sections[:stream]):sum(sections[:stream]) + sec])
    return _rotate(x, jnp.concatenate(angles, axis=-1))


def sincos_positions(seq: int, d_model: int, offset: int = 0) -> jax.Array:
    """Whisper-style sinusoidal absolute position embedding [seq, d_model]."""
    pos = np.arange(offset, offset + seq)[:, None]
    dim = np.arange(d_model // 2)[None, :]
    inv = np.exp(-math.log(10000.0) * dim / max(1, d_model // 2 - 1))
    ang = pos * inv
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=-1),
                       dtype=jnp.float32)


# ---------------------------------------------------------------- attention


def init_attention(key, cfg, dtype) -> dict:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    sd = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(ks[0], (d, qd)) * sd).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, kvd)) * sd).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, kvd)) * sd).astype(dtype),
        "wo": (jax.random.normal(ks[3], (qd, d)) / math.sqrt(qd)).astype(dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), dtype)
    return p


def _positional(q, k, cfg, kind, positions, k_positions=None):
    if cfg.enc_dec:
        return q, k  # whisper: sinusoidal embeddings added at the stem
    theta = cfg.rope_theta
    if kind == "local" and cfg.rope_local_theta is not None:
        theta = cfg.rope_local_theta
    kp = positions if k_positions is None else k_positions
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, theta, cfg.mrope_sections)
        k = apply_mrope(k, kp, theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, kp, theta)
    return q, k


def _sdpa(q, k, v, mask, cfg):
    """q [B,S,H,hd]; k/v [B,T,KV,hd]; mask [B,1,1,S,T] or broadcastable.

    Operands stay in their storage dtype with f32 *accumulation*
    (`preferred_element_type`): upcasting k/v first makes XLA materialise an
    f32 copy of the whole KV cache per layer (§Perf it.7 — 40x the decode
    memory floor); the MXU multiplies bf16 with f32 accumulation natively."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    q = q.reshape(B, S, KV, rep, hd)
    logits = jnp.einsum("bsgrh,btgh->bgrst", q, k,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        logits = jnp.tanh(logits / c) * c
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrst,btgh->bsgrh", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H * hd).astype(v.dtype)


ATTN_Q_CHUNK = 512


def sdpa_chunked(q, k, v, cfg, mask_fn, q_offset: int = 0,
                 chunk: int = ATTN_Q_CHUNK, local_window: int | None = None):
    """Memory-bounded attention: scan over query chunks so the [S, T] logits
    never materialise — the live set is one [chunk, T] slab per head group
    (the TPU-memory-hierarchy analogue of flash attention at the XLA level).

    For sliding-window layers (``local_window``), each chunk only reads the
    [window + chunk] K/V band that can be attended — prefill traffic and
    FLOPs drop by T/(window+chunk) (§Perf it.9).

    mask_fn(qpos [Cq], kpos [T]) -> bool [Cq, T]; q [B,S,H,hd]; k/v [B,T,..].
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    if S <= chunk:
        mask = mask_fn(jnp.arange(S) + q_offset, jnp.arange(T))
        return _sdpa(q, k, v, mask[None, None, None, :, :], cfg)
    assert q_offset == 0, "banded path assumes self-attention alignment"
    n = S // chunk
    rem = S - n * chunk
    kpos = jnp.arange(T)

    from .partitioning import constrain, scan_unroll

    band = None
    if local_window is not None and local_window + chunk < T:
        W = local_window
        band = W + chunk
        kpad = jnp.pad(k, ((0, 0), (W, 0), (0, 0), (0, 0)))
        vpad = jnp.pad(v, ((0, 0), (W, 0), (0, 0), (0, 0)))

    @jax.checkpoint
    def one(qc, qpos, qstart):
        # per-chunk sequence parallelism: the chunk's rows shard over the
        # model axis (set when head counts don't divide TP), so every shard
        # computes a slice of softmax rows with local reductions
        qc = constrain(qc, "attn_chunk")
        if band is not None:
            kk = jax.lax.dynamic_slice_in_dim(kpad, qstart, band, 1)
            vv = jax.lax.dynamic_slice_in_dim(vpad, qstart, band, 1)
            kp = qstart - W + jnp.arange(band)   # pads land at kp < 0
            mask = mask_fn(qpos, kp)
            return _sdpa(qc, kk, vv, mask[None, None, None, :, :], cfg)
        mask = mask_fn(qpos, kpos)
        return _sdpa(qc, k, v, mask[None, None, None, :, :], cfg)

    unroll = True if scan_unroll() else 1
    if rem == 0:
        # scan over *stacked* chunks: slicing the (unsharded) leading chunk
        # axis is shard-local, so per-iteration q slices never reshard
        # (a traced-index dynamic_slice on a sharded tensor makes GSPMD
        # gather the whole operand every layer)
        qs = q.reshape(B, n, chunk, H, hd).swapaxes(0, 1)
        qs = constrain(qs, "attn_chunks")

        def body(_, xs):
            qc, i = xs
            qpos = i * chunk + jnp.arange(chunk) + q_offset
            return None, one(qc, qpos, i * chunk)

        _, outs = jax.lax.scan(body, None, (qs, jnp.arange(n)),
                               unroll=unroll)
        return outs.swapaxes(0, 1).reshape(B, S, H * hd)

    def body(_, i):
        qc = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, 1)
        qpos = i * chunk + jnp.arange(chunk) + q_offset
        return None, one(qc, qpos, i * chunk)

    _, outs = jax.lax.scan(body, None, jnp.arange(n), unroll=unroll)
    out = outs.swapaxes(0, 1).reshape(B, n * chunk, H * hd)
    if rem:
        tail = one(q[:, n * chunk:], jnp.arange(n * chunk, S) + q_offset,
                   n * chunk)
        out = jnp.concatenate([out, tail], axis=1)
    return out


def attention_block(p: dict, x: jax.Array, cfg, kind: str,
                    positions: jax.Array, *, causal: bool = True,
                    cache: dict | None = None, cache_pos: jax.Array | None = None,
                    kv_from: jax.Array | None = None,
                    kv_positions: jax.Array | None = None):
    """One attention op.

    Modes:
      * full-sequence (train / prefill): ``cache is None`` — returns
        (out, {"k","v"}) so prefill can build a cache;
      * incremental decode: ``cache`` holds [B, Smax, KV, hd]; the new k/v is
        written at ``cache_pos`` and attention runs over the whole cache;
      * cross attention: ``kv_from`` supplies the keys/values source
        (encoder output), no causal mask.
    """
    B, S, d = x.shape
    hd, H, KV = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    src = x if kv_from is None else kv_from
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (src @ p["wk"]).reshape(B, src.shape[1], KV, hd)
    v = (src @ p["wv"]).reshape(B, src.shape[1], KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if kv_from is None:
        q, k = _positional(q, k, cfg, kind, positions, kv_positions)

    if cache is not None and kv_from is None:
        # incremental decode: write new kv at cache_pos, attend over cache
        bidx = jnp.arange(B)
        ck = cache["k"].at[bidx, cache_pos].set(k[:, 0])
        cv = cache["v"].at[bidx, cache_pos].set(v[:, 0])
        T = ck.shape[1]
        tpos = jnp.arange(T)[None, :]                      # [1, T]
        mask = tpos <= cache_pos[:, None]
        if kind == "local":
            mask &= tpos > cache_pos[:, None] - cfg.window
        mask = mask[:, None, None, None, :]                # [B,1,1,1,T]
        out = _sdpa(q, ck, cv, mask, cfg)
        new_cache = {"k": ck, "v": cv}
        return (out @ p["wo"]), new_cache

    T = src.shape[1]
    if kv_from is not None:
        mask = jnp.ones((1, 1, 1, S, T), dtype=bool)       # cross: dense
    else:
        qpos = positions[..., :, None] if positions.ndim == 2 else \
            jnp.arange(S)[:, None]
        kpos = jnp.arange(T)[None, :]
        if causal:
            mask = kpos <= qpos
            if kind == "local":
                mask = mask & (kpos > qpos - cfg.window)
        else:
            mask = jnp.ones((S, T), dtype=bool)
            if kind == "local":
                mask = jnp.abs(kpos - qpos) < cfg.window
        mask = mask[..., None, None, :, :] if mask.ndim == 3 else \
            mask[None, None, None, :, :]
    out = _sdpa(q, k, v, mask, cfg)
    return (out @ p["wo"]), {"k": k, "v": v}


# ---------------------------------------------------------------- MLP


def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype) -> dict:
    ks = jax.random.split(key, 3)
    si, so = 1.0 / math.sqrt(d_model), 1.0 / math.sqrt(d_ff)
    p = {"w_up": (jax.random.normal(ks[0], (d_model, d_ff)) * si).astype(dtype),
         "w_down": (jax.random.normal(ks[1], (d_ff, d_model)) * so).astype(dtype)}
    if gated:
        p["w_gate"] = (jax.random.normal(ks[2], (d_model, d_ff)) * si).astype(dtype)
    return p


def mlp_block(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    f = jax.nn.silu if act == "silu" else jax.nn.gelu
    if "w_gate" in p:
        return (f(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return f(x @ p["w_up"]) @ p["w_down"]
