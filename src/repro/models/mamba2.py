"""Mamba2 / SSD block (arXiv:2405.21060), TPU-shaped.

State-space recurrence per head h with scalar decay:

    S_t = a_t * S_{t-1} + (dt_t x_t) (x) B_t          S in R^{hd x state}
    y_t = C_t . S_t + D * x_t,   a_t = exp(-exp(A) dt_t)

Training/prefill uses the chunked (SSD) form: within a chunk of length L the
recurrence unrolls into causal matmuls via cumulative log-decays; the state is
carried across chunks with a lax.scan — everything is MXU-shaped, avoiding an
O(T) elementwise dependence chain and the O(T x hd x state) associative-scan
intermediates.  Decode is the single-step recurrence.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import rms_norm

__all__ = ["init_mamba2", "mamba2_block", "mamba2_decode", "init_mamba2_state"]

# Chunk length trades intra-chunk [B, H, L, L] decay-matrix traffic against
# per-chunk *fixed* costs (the [B, H, hd, state] carry is read+written every
# chunk).  Measured on the zamba2 train cell (§Perf it.4): L=64 -> 370 s,
# L=128 -> 226 s, L=256 -> 170 s of HBM time — the state carry dominates, so
# larger chunks win on traffic, but L=256 blows the per-chip temp memory
# (146 GB).  L=128 is the feasible optimum; the real fix is a Pallas SSD
# kernel that keeps the decay matrices in VMEM.
CHUNK = 128


def init_mamba2(key, cfg, dtype) -> dict:
    d, din = cfg.d_model, cfg.d_inner
    H = cfg.ssm_heads
    st = cfg.ssm_state
    ks = jax.random.split(key, 6)
    si = 1.0 / math.sqrt(d)
    return {
        # projections: z (gate), x, B, C, dt
        "in_proj": (jax.random.normal(ks[0], (d, 2 * din + 2 * st + H)) * si
                    ).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, din)) /
                   math.sqrt(cfg.ssm_conv)).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, float(max(2, H)), H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.zeros((din,), dtype),
        "out_proj": (jax.random.normal(ks[2], (din, d)) / math.sqrt(din)
                     ).astype(dtype),
    }


def _split_proj(p, u, cfg):
    din, st, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, x, Bm, Cm, dt = jnp.split(u @ p["in_proj"],
                                 [din, 2 * din, 2 * din + st, 2 * din + 2 * st],
                                 axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    return z, x, Bm, Cm, dt


def _causal_conv(x, w, state=None):
    """Depthwise causal conv; x [B, T, din], w [K, din].
    With ``state`` [B, K-1, din] performs the incremental step."""
    K = w.shape[0]
    if state is not None:
        xa = jnp.concatenate([state, x], axis=1)          # [B, K-1+T, din]
        new_state = xa[:, -(K - 1):, :] if K > 1 else state
    else:
        xa = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        new_state = xa[:, -(K - 1):, :] if K > 1 else None
    out = sum(xa[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out), new_state


def _ssd_chunked(xh, Bm, Cm, dt, A_log, S0):
    """Chunked SSD scan.

    xh [B, T, H, hd]; Bm/Cm [B, T, st]; dt [B, T, H]; S0 [B, H, hd, st].
    Returns (y [B, T, H, hd], S_final)."""
    Bsz, T, H, hd = xh.shape
    st = Bm.shape[-1]
    L = min(CHUNK, T)
    assert T % L == 0, (T, L)
    nC = T // L

    loga = (-jnp.exp(A_log)[None, :, None] *
            dt.transpose(0, 2, 1).astype(jnp.float32))     # [B, H, T]
    u = xh * dt[..., None].astype(xh.dtype)                # dt-weighted input

    # the [B, H, L, L] transition matrix is the HBM hog; in bf16 production
    # mode it is formed and consumed in bf16 (f32 accumulation in the dot),
    # halving the dominant memory-roofline term (§Perf it.4)
    m_dtype = xh.dtype if xh.dtype == jnp.bfloat16 else jnp.float32

    def chunk_step(S, args):
        u_c, B_c, C_c, la_c = args                         # [B,L,H,hd] etc
        l = jnp.cumsum(la_c, axis=-1)                      # [B, H, L] inclusive
        # intra-chunk: M[t, j] = (C_t . B_j) exp(l_t - l_j), j <= t
        cb = jnp.einsum("bts,bjs->btj", C_c.astype(jnp.float32),
                        B_c.astype(jnp.float32))           # [B, L, L]
        dec = jnp.exp(l[..., :, None] - l[..., None, :])   # [B, H, L, L]
        causal = jnp.tril(jnp.ones((L, L), bool))
        M = jnp.where(causal, cb[:, None] * dec, 0.0).astype(m_dtype)
        y = jnp.einsum("bhtj,bjhp->bthp", M, u_c.astype(m_dtype),
                       preferred_element_type=jnp.float32)
        # inter-chunk: y_t += exp(l_t) * (S0 @ C_t)
        y = y + jnp.einsum("bht,bhps,bts->bthp", jnp.exp(l),
                           S, C_c.astype(jnp.float32))
        # state update: S' = exp(l_L) S + sum_j exp(l_L - l_j) u_j (x) B_j
        w = jnp.exp(l[..., -1:] - l)                       # [B, H, L]
        S = (S * jnp.exp(l[..., -1])[..., None, None] +
             jnp.einsum("bhj,bjhp,bjs->bhps", w, u_c.astype(jnp.float32),
                        B_c.astype(jnp.float32)))
        return S, y

    def resh(a):
        return a.reshape(Bsz, nC, L, *a.shape[2:]).swapaxes(0, 1)

    la = loga.reshape(Bsz, H, nC, L).transpose(2, 0, 1, 3)  # [nC, B, H, L]
    from .partitioning import scan_unroll

    S_fin, ys = jax.lax.scan(chunk_step, S0.astype(jnp.float32),
                             (resh(u), resh(Bm), resh(Cm), la),
                             unroll=True if scan_unroll() else 1)
    y = ys.swapaxes(0, 1).reshape(Bsz, T, H, hd)
    return y.astype(xh.dtype), S_fin


def mamba2_block(p: dict, u: jax.Array, cfg, state=None, conv_state=None):
    """Full-sequence Mamba2 block. u [B, T, d] -> (y, (S, conv_state))."""
    B, T, d = u.shape
    H, st = cfg.ssm_heads, cfg.ssm_state
    hd = cfg.d_inner // H
    z, x, Bm, Cm, dt = _split_proj(p, u, cfg)
    x, conv_state = _causal_conv(x, p["conv_w"], conv_state)
    xh = x.reshape(B, T, H, hd)
    S0 = (jnp.zeros((B, H, hd, st), jnp.float32) if state is None else state)
    y, S = _ssd_chunked(xh, Bm, Cm, dt, p["A_log"], S0)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, T, cfg.d_inner).astype(u.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["out_proj"], (S, conv_state)


def init_mamba2_state(cfg, batch: int):
    H, st = cfg.ssm_heads, cfg.ssm_state
    hd = cfg.d_inner // H
    return (jnp.zeros((batch, H, hd, st), jnp.float32),
            jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), jnp.float32))


def mamba2_decode(p: dict, u: jax.Array, cfg, state, conv_state):
    """Single-step recurrence. u [B, 1, d]."""
    B, _, d = u.shape
    H, st = cfg.ssm_heads, cfg.ssm_state
    hd = cfg.d_inner // H
    z, x, Bm, Cm, dt = _split_proj(p, u, cfg)
    x, conv_state = _causal_conv(x, p["conv_w"],
                                 conv_state.astype(x.dtype))
    xh = x.reshape(B, H, hd)
    dt1 = dt[:, 0]                                          # [B, H]
    a = jnp.exp(-jnp.exp(p["A_log"])[None] * dt1)           # [B, H]
    upd = jnp.einsum("bhp,bs->bhps", xh.astype(jnp.float32) * dt1[..., None],
                     Bm[:, 0].astype(jnp.float32))
    S = state * a[..., None, None] + upd
    y = jnp.einsum("bhps,bs->bhp", S, Cm[:, 0].astype(jnp.float32))
    y = y + xh.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, 1, cfg.d_inner).astype(u.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["out_proj"], (S, conv_state)
