"""Unified LM assembly for the assigned architecture pool.

A model is a stack of layer *cycles* (cfg.attn_pattern) executed as
``lax.scan`` over stacked per-cycle parameters, plus an unrolled tail when
``n_layers % len(cycle) != 0``.  Layer kinds:

    "global" / "local"            GQA attention (full / sliding window) + MLP
    "global+moe" / "local+moe"    attention + MoE FFN
    "mamba2"                      Mamba2/SSD block
    "mamba2+shared"               Mamba2 + the weight-tied shared attention
                                  block (zamba2)
    "rwkv6"                       RWKV-6 time mix + channel mix

Steps: ``train`` (loss + grads + optimizer update), ``prefill`` (forward; can
also fill KV caches / recurrent states), ``decode`` (one token against the
cache; local layers use a ring buffer bounded by the window).  Encoder-decoder
(whisper) runs a bidirectional encoder over stub frame embeddings and a causal
decoder with cross attention (cross K/V cached for decode).  Modality
frontends are stubs per the assignment: frames / patch embeddings arrive
precomputed.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import (apply_rope, init_attention, init_mlp, mlp_block,
                     rms_norm, sincos_positions, sdpa_chunked, _sdpa)
from .partitioning import constrain, scan_unroll


def _scan(f, init, xs, length=None):
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=True if scan_unroll() else 1)
from .mamba2 import (init_mamba2, init_mamba2_state, mamba2_block,
                     mamba2_decode)
from .moe import init_moe, moe_block
from .rwkv6 import init_rwkv6, init_rwkv6_state, rwkv6_block, rwkv6_decode

__all__ = ["init_params", "init_cache", "forward", "loss_fn",
           "train_step_fn", "prefill_fn", "decode_fn"]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _ffn_is_moe(kind: str) -> bool:
    return kind.endswith("+moe")


# ------------------------------------------------------------------ params


def _init_layer(key, cfg: ModelConfig, kind: str, cross: bool = False) -> dict:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if kind == "rwkv6":
        return {"ln": jnp.zeros((d,), dt), "rwkv": init_rwkv6(ks[0], cfg, dt)}
    if kind.startswith("mamba2"):
        return {"ln": jnp.zeros((d,), dt), "mamba": init_mamba2(ks[0], cfg, dt)}
    p = {
        "ln1": jnp.zeros((d,), dt),
        "attn": init_attention(ks[0], cfg, dt),
        "ln2": jnp.zeros((d,), dt),
    }
    if _ffn_is_moe(kind):
        p["moe"] = init_moe(ks[1], cfg, dt)
    else:
        ff = cfg.moe_dense_ff if cfg.moe_dense_ff else cfg.d_ff
        p["mlp"] = init_mlp(ks[2], d, ff, cfg.mlp_gated, dt)
    if cfg.post_block_norm:
        p["post_ln1"] = jnp.zeros((d,), dt)
        p["post_ln2"] = jnp.zeros((d,), dt)
    if cross:
        p["ln_cross"] = jnp.zeros((d,), dt)
        p["cross"] = init_attention(ks[3], cfg, dt)
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key=None) -> dict:
    key = jax.random.PRNGKey(0) if key is None else key
    dt = _dtype(cfg)
    cyc, n_groups, tail = cfg.layer_plan()
    keys = jax.random.split(key, 16)

    params: dict = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02
                  ).astype(dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(keys[1], (cfg.d_model, cfg.vocab))
                             / math.sqrt(cfg.d_model)).astype(dt)

    cross = cfg.enc_dec
    params["scan"] = tuple(
        _stack([_init_layer(jax.random.fold_in(keys[2], g * 64 + ci),
                            cfg, kind, cross) for g in range(n_groups)])
        for ci, kind in enumerate(cyc)) if n_groups else tuple()
    params["tail"] = tuple(
        _init_layer(jax.random.fold_in(keys[3], i), cfg, kind, cross)
        for i, kind in enumerate(tail))

    if cfg.shared_block_period:
        params["shared_block"] = {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "attn": init_attention(keys[4], cfg, dt),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "mlp": init_mlp(keys[5], cfg.d_model, cfg.d_ff, cfg.mlp_gated, dt),
        }

    if cfg.enc_dec:
        params["enc"] = {
            "scan": _stack([_init_layer(jax.random.fold_in(keys[6], g),
                                        cfg, "global")
                            for g in range(cfg.n_enc_layers)]),
            "final_norm": jnp.zeros((cfg.d_model,), dt),
        }
    return params


# ------------------------------------------------------------------ caches


def init_cache(cfg: ModelConfig, batch: int, cap: int) -> dict:
    """Decode-state pytree for a KV capacity of ``cap`` tokens.  Local
    (sliding-window) layers allocate only ``min(cap, window)`` slots."""
    dt = _dtype(cfg)
    cyc, n_groups, tail = cfg.layer_plan()

    def layer_cache(kind: str, stack_n: int | None):
        def z(*s, dtype=dt):
            shape = (stack_n, *s) if stack_n is not None else s
            return jnp.zeros(shape, dtype)

        if kind == "rwkv6":
            st = init_rwkv6_state(cfg, batch)
            return {"rwkv_state": jax.tree.map(
                lambda a: (jnp.zeros((stack_n, *a.shape), a.dtype)
                           if stack_n is not None else a), st)}
        if kind.startswith("mamba2"):
            st = init_mamba2_state(cfg, batch)
            c = {"mamba_state": jax.tree.map(
                lambda a: (jnp.zeros((stack_n, *a.shape), a.dtype)
                           if stack_n is not None else a), st)}
            if kind == "mamba2+shared":
                c["k"] = z(batch, cap, cfg.n_kv_heads, cfg.head_dim)
                c["v"] = z(batch, cap, cfg.n_kv_heads, cfg.head_dim)
            return c
        span = min(cap, cfg.window) if kind.startswith("local") else cap
        c = {"k": z(batch, span, cfg.n_kv_heads, cfg.head_dim),
             "v": z(batch, span, cfg.n_kv_heads, cfg.head_dim)}
        if kind.startswith("local"):
            c["pos"] = jnp.full((stack_n, batch, span) if stack_n is not None
                                else (batch, span), -1, jnp.int32)
        if cfg.enc_dec:
            c["xk"] = z(batch, cap, cfg.n_kv_heads, cfg.head_dim)
            c["xv"] = z(batch, cap, cfg.n_kv_heads, cfg.head_dim)
            c["x_len"] = jnp.zeros((stack_n,) if stack_n is not None else (),
                                   jnp.int32)
        return c

    return {
        "scan": tuple(layer_cache(kind, n_groups) for kind in cyc)
        if n_groups else tuple(),
        "tail": tuple(layer_cache(kind, None) for kind in tail),
    }


def _cache_cap(cache) -> int:
    caps = [l.shape[-3] for l in jax.tree.leaves(cache)
            if hasattr(l, "ndim") and l.ndim >= 4]
    return max(caps) if caps else 0


# ------------------------------------------------------------------ layers


def _project_kv(ap, h, cfg, kind, positions):
    B, S, _ = h.shape
    k = (h @ ap["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ ap["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        k = rms_norm(k, ap["k_norm"], cfg.norm_eps)
    if not cfg.enc_dec:
        theta = (cfg.rope_local_theta if (kind == "local" and
                                          cfg.rope_local_theta) else
                 cfg.rope_theta)
        if cfg.mrope_sections is not None:
            from .layers import apply_mrope

            k = apply_mrope(k, positions, theta, cfg.mrope_sections)
        else:
            k = apply_rope(k, positions, theta)
    return k, v


def _project_q(ap, h, cfg, kind, positions):
    B, S, _ = h.shape
    q = (h @ ap["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, ap["q_norm"], cfg.norm_eps)
    if not cfg.enc_dec:
        theta = (cfg.rope_local_theta if (kind == "local" and
                                          cfg.rope_local_theta) else
                 cfg.rope_theta)
        if cfg.mrope_sections is not None:
            from .layers import apply_mrope

            q = apply_mrope(q, positions, theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, theta)
    return q


def _self_attention(ap, h, cfg, kind, positions, cache, pos, decode, causal):
    """Self attention in three modes: full-sequence, prefill-fill, decode."""
    akind = kind.split("+")[0]
    new_cache = {}
    if decode:
        q = _project_q(ap, h, cfg, akind, pos[:, None]
                       if positions is None else positions)
        k, v = _project_kv(ap, h, cfg, akind,
                           pos[:, None] if positions is None else positions)
        bidx = jnp.arange(h.shape[0])
        if "pos" in cache:                      # local ring buffer
            span = cache["k"].shape[-3]
            slot = pos % span
            ck = cache["k"].at[bidx, slot].set(k[:, 0])
            cv = cache["v"].at[bidx, slot].set(v[:, 0])
            cp = cache["pos"].at[bidx, slot].set(pos)
            mask = ((cp <= pos[:, None]) & (cp >= 0) &
                    (cp > (pos - cfg.window)[:, None]))
            new_cache.update({"k": ck, "v": cv, "pos": cp})
        else:
            ck = cache["k"].at[bidx, pos].set(k[:, 0])
            cv = cache["v"].at[bidx, pos].set(v[:, 0])
            tpos = jnp.arange(ck.shape[-3])[None, :]
            mask = tpos <= pos[:, None]
            new_cache.update({"k": ck, "v": cv})
        out = _sdpa(q, ck, cv, mask[:, None, None, None, :], cfg)
        return out @ ap["wo"], new_cache

    B, S, _ = h.shape
    q = _project_q(ap, h, cfg, akind, positions)
    k, v = _project_kv(ap, h, cfg, akind, positions)
    # sequence-parallel attention: when the head count does not divide the
    # model axis, GSPMD would otherwise shard the contraction over head_dim
    # and all-reduce every [chunk, T] logits slab; sharding the query
    # *sequence* instead keeps softmax rows local (k/v replicate over model,
    # which is cheap for GQA's small KV heads).
    q = constrain(q, "attn_q")
    k = constrain(k, "attn_kv")
    v = constrain(v, "attn_kv")

    def mask_fn(qpos, kpos):
        qp, kp = qpos[:, None], kpos[None, :]
        m = (kp <= qp) if causal else jnp.ones((qpos.shape[0],
                                                kpos.shape[0]), bool)
        m = m & (kpos >= 0)[None, :]            # banded path left-pads K/V
        if akind == "local":
            m = m & (jnp.abs(kp - qp) < cfg.window)
        return m

    out = sdpa_chunked(q, k, v, cfg, mask_fn,
                       local_window=cfg.window if (akind == "local" and
                                                   causal) else None)
    out = constrain(out, "attn_out")
    if cache is not None:                       # prefill: fill the cache
        if "pos" in cache:
            span = cache["k"].shape[-3]
            take = min(S, span)
            idx = (jnp.arange(S - take, S) % span)
            ck = cache["k"].at[:, idx].set(k[:, S - take:])
            cv = cache["v"].at[:, idx].set(v[:, S - take:])
            cp = cache["pos"].at[:, idx].set(
                jnp.arange(S - take, S, dtype=jnp.int32)[None, :])
            new_cache.update({"k": ck, "v": cv, "pos": cp})
        else:
            ck = cache["k"].at[:, :S].set(k)
            cv = cache["v"].at[:, :S].set(v)
            new_cache.update({"k": ck, "v": cv})
    return out @ ap["wo"], new_cache


def _cross_attention(p, x, cfg, enc_out, cache, decode):
    """Whisper cross attention; caches encoder K/V at prefill."""
    new_cache = {}
    h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
    B, S, _ = h.shape
    q = (h @ p["cross"]["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    if decode:
        xk, xv = cache["xk"], cache["xv"]
        mask = (jnp.arange(xk.shape[1])[None, :] < cache["x_len"]
                )[:, None, None, None, :] if cache["x_len"].ndim else \
            (jnp.arange(xk.shape[1]) < cache["x_len"])[None, None, None, None, :]
    else:
        T = enc_out.shape[1]
        xk = (enc_out @ p["cross"]["wk"]).reshape(B, T, cfg.n_kv_heads,
                                                  cfg.head_dim)
        xv = (enc_out @ p["cross"]["wv"]).reshape(B, T, cfg.n_kv_heads,
                                                  cfg.head_dim)
        if cache is not None:
            cap = cache["xk"].shape[-3]
            new_cache["xk"] = cache["xk"].at[:, :T].set(xk[:, :cap])
            new_cache["xv"] = cache["xv"].at[:, :T].set(xv[:, :cap])
            new_cache["x_len"] = jnp.asarray(min(T, cap), jnp.int32)
        mask = jnp.ones((1, 1, 1, S, xk.shape[1]), bool)
    out = _sdpa(q, xk, xv, mask, cfg)
    return x + out @ p["cross"]["wo"], new_cache


def _attn_layer(p, x, cfg, kind, positions, cache, pos, enc_out, decode,
                causal):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    a, new_cache = _self_attention(p["attn"], h, cfg, kind, positions,
                                   cache, pos, decode, causal)
    if cfg.post_block_norm:
        a = rms_norm(a, p["post_ln1"], cfg.norm_eps)
    x = x + a

    if "cross" in p and (enc_out is not None or
                         (cache is not None and "xk" in cache)):
        x, nc = _cross_attention(p, x, cfg, enc_out, cache, decode)
        new_cache.update(nc)

    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        f, aux = moe_block(p["moe"], h, cfg)
    else:
        f = mlp_block(p["mlp"], h, cfg.act)
    if cfg.post_block_norm:
        f = rms_norm(f, p["post_ln2"], cfg.norm_eps)
    return x + f, aux, new_cache


def _layer_apply(p, x, cfg, kind, positions, shared_p, cache, pos, enc_out,
                 decode, causal):
    if kind == "rwkv6":
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        st = cache["rwkv_state"] if cache is not None else None
        if decode:
            delta, st = rwkv6_decode(p["rwkv"], h, cfg, st)
        else:
            delta, st = rwkv6_block(p["rwkv"], h, cfg, st if cache is not None
                                    else None)
        nc = {"rwkv_state": st} if cache is not None else {}
        return x + delta, jnp.zeros((), jnp.float32), nc
    if kind.startswith("mamba2"):
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        nc = {}
        if decode:
            S, conv = cache["mamba_state"]
            delta, (S, conv) = mamba2_decode(p["mamba"], h, cfg, S, conv)
            nc["mamba_state"] = (S, conv)
        else:
            st = cache["mamba_state"] if cache is not None else None
            delta, st2 = mamba2_block(
                p["mamba"], h, cfg,
                state=None if st is None else st[0],
                conv_state=None if st is None else st[1])
            if cache is not None:
                nc["mamba_state"] = st2
        x = x + delta
        if kind == "mamba2+shared":
            sub = None
            if cache is not None and "k" in cache:
                sub = {"k": cache["k"], "v": cache["v"]}
            x, aux, snc = _attn_layer(shared_p, x, cfg, "global", positions,
                                      sub, pos, None, decode, causal)
            nc.update(snc)
            return x, aux, nc
        return x, jnp.zeros((), jnp.float32), nc
    return _attn_layer(p, x, cfg, kind, positions, cache, pos, enc_out,
                       decode, causal)


# ------------------------------------------------------------------ stacks


def _run_stack(params, x, cfg, positions, *, cache=None, pos=None,
               enc_out=None, decode=False, causal=True):
    cyc, n_groups, tail = cfg.layer_plan()
    shared_p = params.get("shared_block")
    aux_total = jnp.zeros((), jnp.float32)

    if n_groups:
        def group_body(carry, scanned):
            x, aux = carry
            x = constrain(x, "act")
            layer_ps, layer_cs = scanned
            new_cs = []
            for ci, kind in enumerate(cyc):
                c = None if layer_cs is None else layer_cs[ci]
                x, a, nc = _layer_apply(layer_ps[ci], x, cfg, kind, positions,
                                        shared_p, c, pos, enc_out, decode,
                                        causal)
                aux = aux + a
                new_cs.append(nc)
            x = constrain(x, "act")
            return (x, aux), tuple(new_cs)

        if not decode:
            group_body = jax.checkpoint(group_body)   # remat per layer group
        scan_caches = cache["scan"] if cache is not None else None
        (x, aux_total), new_scan = _scan(
            group_body, (x, aux_total), (params["scan"], scan_caches))
    else:
        new_scan = tuple()

    new_tail = []
    for i, kind in enumerate(tail):
        c = None if cache is None else cache["tail"][i]
        x, a, nc = _layer_apply(params["tail"][i], x, cfg, kind, positions,
                                shared_p, c, pos, enc_out, decode, causal)
        aux_total = aux_total + a
        new_tail.append(nc)

    new_cache = None
    if cache is not None:
        new_cache = {"scan": new_scan, "tail": tuple(new_tail)}
    return x, aux_total, new_cache


# ------------------------------------------------------------------ forward


def _embed_inputs(params, cfg, batch: dict):
    dt = _dtype(cfg)
    if cfg.enc_dec:
        tok = batch["tokens"]
        x = params["embed"][tok].astype(dt)
        x = x + sincos_positions(tok.shape[1], cfg.d_model).astype(dt)[None]
        positions = jnp.broadcast_to(jnp.arange(tok.shape[1]),
                                     tok.shape).astype(jnp.int32)
        return x, positions
    if cfg.frontend == "patches" and "patch_embeds" in batch:
        te = params["embed"][batch["tokens"]].astype(dt)
        x = jnp.concatenate([batch["patch_embeds"].astype(dt), te], axis=1)
    else:
        x = params["embed"][batch["tokens"]].astype(dt)
    S = x.shape[1]
    if cfg.mrope_sections is not None and "positions" in batch:
        positions = batch["positions"]
    else:
        positions = jnp.broadcast_to(jnp.arange(S), (x.shape[0], S)
                                     ).astype(jnp.int32)
    return x, positions


def _encode(params, cfg, frames):
    dt = _dtype(cfg)
    x = frames.astype(dt) + sincos_positions(frames.shape[1],
                                             cfg.d_model).astype(dt)[None]
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1]),
                                 frames.shape[:2]).astype(jnp.int32)

    def body(carry, layer_ps):
        y, _, _ = _layer_apply(layer_ps, carry, cfg, "global", positions,
                               None, None, None, None, False, False)
        return y, None

    x, _ = _scan(body, x, params["enc"]["scan"])
    return rms_norm(x, params["enc"]["final_norm"], cfg.norm_eps)


def _logits(params, cfg, x):
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = (x @ head).astype(jnp.float32)
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


def forward(params, cfg: ModelConfig, batch: dict, *, cache=None,
            decode=False, last_only=False, return_hidden=False):
    """Returns (logits | hidden, aux_loss, new_cache).

    ``last_only``: project only the final position to logits (prefill).
    ``return_hidden``: skip the LM head entirely (the chunked-CE loss
    projects per sequence chunk to bound logits memory)."""
    enc_out = None
    if cfg.enc_dec and "frames" in batch:
        enc_out = _encode(params, cfg, batch["frames"])

    if decode:
        tok = batch["token"]
        pos = batch["pos"]
        dt = _dtype(cfg)
        x = params["embed"][tok].astype(dt)
        if cfg.enc_dec:
            table = sincos_positions(_cache_cap(cache), cfg.d_model).astype(dt)
            x = x + table[pos][:, None, :]
            positions = None
        elif cfg.mrope_sections is not None:
            positions = batch["positions"]
        else:
            positions = None
        x, aux, new_cache = _run_stack(params, x, cfg, positions, cache=cache,
                                       pos=pos, enc_out=enc_out, decode=True)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return _logits(params, cfg, x), aux, new_cache

    x, positions = _embed_inputs(params, cfg, batch)
    x, aux, new_cache = _run_stack(params, x, cfg, positions, cache=cache,
                                   enc_out=enc_out, decode=False)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, aux, new_cache
    if last_only:
        return _logits(params, cfg, x[:, -1:, :]), aux, new_cache
    return _logits(params, cfg, x), aux, new_cache


# ------------------------------------------------------------------ steps


CE_CHUNK = 512


def _chunked_ce(params, cfg, x, labels, chunk: int = CE_CHUNK):
    """Cross entropy with per-chunk LM-head projection: the [B, S, vocab]
    logits tensor never materialises (live set: one [B, chunk, vocab] slab,
    vocab-sharded via the "logits" constraint)."""
    B, S, _ = x.shape
    if S % chunk:
        chunk = S
    n = S // chunk
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T

    @jax.checkpoint
    def one(xs, ls):
        lg = constrain((xs @ head).astype(jnp.float32), "logits")
        if cfg.final_logit_softcap:
            c = cfg.final_logit_softcap
            lg = jnp.tanh(lg / c) * c
        lse = jax.nn.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(lg, ls[..., None], axis=-1)[..., 0]
        return (lse - ll).sum()

    def body(acc, i):
        xs = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, 1)
        ls = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, 1)
        return acc + one(xs, ls), None

    total, _ = _scan(body, jnp.zeros((), jnp.float32), jnp.arange(n))
    return total / (B * S)


def loss_fn(params, cfg: ModelConfig, batch: dict):
    hidden, aux, _ = forward(params, cfg, batch, return_hidden=True)
    labels = batch["labels"]
    S = min(hidden.shape[1], labels.shape[1])
    ce = _chunked_ce(params, cfg, hidden[:, -S:, :], labels[:, -S:])
    return ce + 0.01 * aux


def train_step_fn(cfg: ModelConfig, optimizer):
    """(params, opt_state, batch) -> (params, opt_state, loss)."""

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch))(params)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, loss

    return step


def prefill_fn(cfg: ModelConfig, with_cache: bool = False):
    """Forward over the prompt.  ``with_cache``: also fill a decode cache."""

    if not with_cache:
        def prefill(params, batch):
            logits, _, _ = forward(params, cfg, batch, last_only=True)
            return logits[:, -1, :]
        return prefill

    def prefill_cache(params, cache, batch):
        logits, _, new_cache = forward(params, cfg, batch, cache=cache)
        return logits[:, -1, :], new_cache

    return prefill_cache


def decode_fn(cfg: ModelConfig):
    def decode(params, cache, batch):
        logits, _, new_cache = forward(params, cfg, batch, cache=cache,
                                       decode=True)
        return logits[:, -1, :], new_cache

    return decode
