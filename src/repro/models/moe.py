"""Mixture-of-Experts block: top-k routing with capacity-based dispatch
(GShard-style), expert-parallel over the ``model`` mesh axis.

Dispatch is computed per sequence (token groups of size S) so the position
cumsum stays shard-local under batch sharding; decode (S = 1) dispatches over
the batch axis instead.  Expert compute is a dense [E, C, d] x [E, d, ff]
einsum — FLOPs proportional to *active* parameters (capacity-bounded), unlike
a compute-all-experts dense dispatch.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import init_mlp, mlp_block

__all__ = ["init_moe", "moe_block"]


def init_moe(key, cfg, dtype) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    si, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(ff)
    p = {
        "router": (jax.random.normal(ks[0], (d, E)) * si).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, ff)) * si).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, ff)) * si).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, ff, d)) * so).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, ff * cfg.n_shared_experts, True, dtype)
    return p


def _dispatch_group(p, x, cfg):
    """x [N, d] one dispatch group; returns (y [N, d], aux_loss scalar)."""
    N, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    ff = cfg.d_ff
    cap = max(1, int(math.ceil(N * k * cfg.capacity_factor / E)))

    logits = (x.astype(jnp.float32) @ p["router"])            # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)                          # [N, k]
    w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)        # [N, k, E]
    flat = onehot.reshape(N * k, E)                           # slot-major
    pos = jnp.cumsum(flat, axis=0) - flat                     # position per expert
    pos = (pos * flat).sum(-1).astype(jnp.int32)              # [N*k]
    e_flat = idx.reshape(N * k)
    keep = (pos < cap) & (w.reshape(N * k) > 0)

    slot = jnp.where(keep, e_flat * cap + pos, E * cap)       # overflow -> dropped
    buf = jnp.zeros((E * cap + 1, d), dtype=x.dtype)
    tok = jnp.repeat(jnp.arange(N), k)
    buf = buf.at[slot].add(x[tok])
    buf = buf[:-1].reshape(E, cap, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])          # [E, cap, d]

    gathered = out.reshape(E * cap, d)
    y_slots = jnp.where(keep[:, None], gathered[jnp.clip(slot, 0, E * cap - 1)],
                        0.0)
    y = jnp.zeros((N, d), dtype=x.dtype)
    y = y.at[tok].add(y_slots * w.reshape(N * k, 1).astype(x.dtype))

    # load-balancing auxiliary loss (Switch): E * sum_e f_e * P_e
    f = onehot.sum(axis=(0, 1)) / max(1, N)                   # fraction routed
    P = probs.mean(axis=0)
    aux = E * jnp.sum(f * P)
    return y, aux


def moe_block(p: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x [B, S, d] -> (y [B, S, d], aux loss scalar)."""
    B, S, d = x.shape
    if S == 1:
        y, aux = _dispatch_group(p, x[:, 0, :], cfg)
        y = y[:, None, :]
    else:
        y, aux = jax.vmap(lambda xb: _dispatch_group(p, xb, cfg))(x)
        aux = aux.mean()
    if cfg.n_shared_experts:
        y = y + mlp_block(p["shared"], x, cfg.act)
    return y, aux
