"""Model zoo: the 10 assigned architectures as one composable JAX stack
(scan-over-layer-groups, GQA/SWA attention, MoE, Mamba2, RWKV6, enc-dec)."""

from .lm import init_params, train_step_fn, prefill_fn, decode_fn, init_cache  # noqa: F401
