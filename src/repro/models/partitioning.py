"""Activation-partitioning hooks.

Launchers configure global PartitionSpecs for the residual stream and the
logits; the model applies them via ``constrain`` at layer-group boundaries.
When unset (unit tests, single CPU), everything is a no-op.

The residual-stream spec realises Megatron-style sequence parallelism: with
``P(("pod","data"), "model", None)`` the scan-boundary activations shard
their sequence axis over the model axis, cutting the per-device live
activation set by the TP degree; GSPMD inserts the all-gather/reduce-scatter
pairs around attention/MLP automatically.
"""

from __future__ import annotations

from contextlib import contextmanager

import jax

_KEYS = ("act", "logits", "attn_q", "attn_kv", "attn_out", "attn_chunk",
         "attn_chunks")
_SPECS: dict[str, object] = {k: None for k in _KEYS}
_SPECS["unroll"] = False


def set_specs(**kw) -> None:
    for k in _KEYS:
        _SPECS[k] = kw.get(k)


@contextmanager
def activation_specs(**kw):
    old = dict(_SPECS)
    set_specs(**kw)
    try:
        yield
    finally:
        _SPECS.update(old)


@contextmanager
def unrolled_scans(on: bool = True):
    """Unroll every lax.scan in the model stack.  XLA's HloCostAnalysis counts
    a while body once regardless of trip count, so the roofline cost pass
    lowers with scans unrolled (exact FLOP/byte counts); production lowering
    keeps scans (compact HLO, fast compiles)."""
    old = _SPECS["unroll"]
    _SPECS["unroll"] = on
    try:
        yield
    finally:
        _SPECS["unroll"] = old


def scan_unroll() -> bool:
    return bool(_SPECS["unroll"])


def constrain(x, which: str):
    spec = _SPECS.get(which)
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
