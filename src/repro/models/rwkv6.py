"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time mix with
data-dependent per-channel decay, plus channel mix.

Per head (key/value dim = hd):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T            S in R^{hd x hd}
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    w_t = exp(-exp(w0 + lora(x_t)))                (data-dependent decay)

Training/prefill uses a chunked parallel form (cumulative log-decay products
inside a chunk, state carried across chunks by lax.scan) so the hot loop is
matmuls; decode is the single-step recurrence.  Token-shift mixes x_{t-1}
into the projections with learned per-channel coefficients (the static-mu
simplification of the paper's dynamic mixing; noted in DESIGN.md).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import rms_norm

__all__ = ["init_rwkv6", "rwkv6_block", "rwkv6_decode", "init_rwkv6_state"]

CHUNK = 64
LORA = 64


def init_rwkv6(key, cfg, dtype) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_size
    H = d // hd
    ks = jax.random.split(key, 12)
    si = 1.0 / math.sqrt(d)
    return {
        "mu": jnp.full((5, d), 0.5, dtype),            # shift mix for r,k,v,g,w
        "wr": (jax.random.normal(ks[0], (d, d)) * si).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, d)) * si).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, d)) * si).astype(dtype),
        "wg": (jax.random.normal(ks[3], (d, d)) * si).astype(dtype),
        "wo": (jax.random.normal(ks[4], (d, d)) * si).astype(dtype),
        "w0": jnp.full((d,), -4.0, jnp.float32),       # decay bias: slow decay
        "w1": (jax.random.normal(ks[5], (d, LORA)) * si).astype(dtype),
        "w2": (jax.random.normal(ks[6], (LORA, d)) /
               math.sqrt(LORA)).astype(dtype),
        "u": (jax.random.normal(ks[7], (H, hd)) * 0.1).astype(jnp.float32),
        "ln_x": jnp.zeros((d,), dtype),
        # channel mix
        "cmu": jnp.full((2, d), 0.5, dtype),
        "ck": (jax.random.normal(ks[8], (d, cfg.d_ff)) * si).astype(dtype),
        "cv": (jax.random.normal(ks[9], (cfg.d_ff, d)) /
               math.sqrt(cfg.d_ff)).astype(dtype),
        "cr": (jax.random.normal(ks[10], (d, d)) * si).astype(dtype),
    }


def _shift(x, mu, last):
    """Token shift: mix x_{t-1} (or carry ``last`` for t=0) into x_t."""
    prev = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return x * mu + prev * (1.0 - mu)


def _wkv_chunked(r, k, v, logw, u, H, hd):
    """r/k/v [B, T, H, hd] (f32); logw [B, T, H, hd] (negative); u [H, hd]."""
    B, T, _, _ = r.shape
    L = min(CHUNK, T)
    assert T % L == 0
    nC = T // L

    def chunk(S, args):
        rc, kc, vc, lw = args                              # [B, L, H, hd]
        l = jnp.cumsum(lw, axis=1)                         # inclusive logdecay
        lprev = l - lw                                     # exclusive
        rt = rc * jnp.exp(lprev)                           # r~_t = r_t P_{t-1}
        kt = kc * jnp.exp(-l)                              # k~_j = k_j / P_j
        A = jnp.einsum("bthc,bjhc->bhtj", rt, kt)          # [B, H, L, L]
        strict = jnp.tril(jnp.ones((L, L), bool), k=-1)
        A = jnp.where(strict, A, 0.0)
        diag = jnp.einsum("bthc,hc,bthc->bth", rc, u, kc)  # bonus u term
        y = jnp.einsum("bhtj,bjhd->bthd", A, vc)
        y = y + diag[..., None] * vc
        y = y + jnp.einsum("bthc,bhcd->bthd", rt, S)       # inter-chunk
        # S' = diag(P_L) S + sum_j (P_L / P_j) k_j v_j^T
        S = (S * jnp.exp(l[:, -1])[..., None] +
             jnp.einsum("bjhc,bjhd->bhcd",
                        kc * jnp.exp(l[:, -1:] - l), vc))
        return S, y

    def resh(a):
        return a.reshape(B, nC, L, H, hd).swapaxes(0, 1)

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    from .partitioning import scan_unroll

    S_fin, ys = jax.lax.scan(chunk, S0, (resh(r), resh(k), resh(v), resh(logw)),
                             unroll=True if scan_unroll() else 1)
    return ys.swapaxes(0, 1).reshape(B, T, H, hd), S_fin


def _projections(p, x, last, cfg):
    d = cfg.d_model
    hd = cfg.rwkv_head_size
    H = d // hd
    B, T, _ = x.shape
    xr = _shift(x, p["mu"][0], last)
    xk = _shift(x, p["mu"][1], last)
    xv = _shift(x, p["mu"][2], last)
    xg = _shift(x, p["mu"][3], last)
    xw = _shift(x, p["mu"][4], last)
    r = (xr @ p["wr"]).reshape(B, T, H, hd).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, T, H, hd).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, T, H, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    logw = -jnp.exp(p["w0"] +
                    (jnp.tanh(xw @ p["w1"]) @ p["w2"]).astype(jnp.float32))
    logw = logw.reshape(B, T, H, hd)
    return r, k, v, g, logw


def rwkv6_block(p: dict, x: jax.Array, cfg, state=None):
    """Time mix + channel mix over a full sequence. x [B, T, d]."""
    B, T, d = x.shape
    hd = cfg.rwkv_head_size
    H = d // hd
    last = jnp.zeros((B, d), x.dtype) if state is None else state[0]
    r, k, v, g, logw = _projections(p, x, last, cfg)
    y, S = _wkv_chunked(r, k, v, logw, p["u"], H, hd)
    y = y.reshape(B, T, d).astype(x.dtype)
    y = rms_norm(y, p["ln_x"], cfg.norm_eps) * g
    out = y @ p["wo"]

    # channel mix
    h = x + out
    clast = jnp.zeros((B, d), x.dtype) if state is None else state[2]
    hk = _shift(h, p["cmu"][0], clast)
    hr = _shift(h, p["cmu"][1], clast)
    cm = (jnp.square(jax.nn.relu(hk @ p["ck"])) @ p["cv"])
    cm = jax.nn.sigmoid(hr @ p["cr"]) * cm
    new_state = (x[:, -1, :], S, h[:, -1, :])
    return out + cm, new_state


def init_rwkv6_state(cfg, batch: int):
    d = cfg.d_model
    hd = cfg.rwkv_head_size
    H = d // hd
    return (jnp.zeros((batch, d), jnp.bfloat16 if cfg.dtype == "bfloat16"
                      else jnp.float32),
            jnp.zeros((batch, H, hd, hd), jnp.float32),
            jnp.zeros((batch, d), jnp.bfloat16 if cfg.dtype == "bfloat16"
                      else jnp.float32))


def rwkv6_decode(p: dict, x: jax.Array, cfg, state):
    """Single-token step. x [B, 1, d]; state (last_x, S, last_h)."""
    B, _, d = x.shape
    hd = cfg.rwkv_head_size
    H = d // hd
    last_x, S, last_h = state
    r, k, v, g, logw = _projections(p, x, last_x, cfg)
    r1, k1, v1 = r[:, 0], k[:, 0], v[:, 0]                 # [B, H, hd]
    w1 = jnp.exp(logw[:, 0])                               # decay in (0, 1)
    kv = jnp.einsum("bhc,bhd->bhcd", k1, v1)
    y = jnp.einsum("bhc,bhcd->bhd", r1, S + p["u"][..., None] * kv)
    S = S * w1[..., None] + kv
    y = y.reshape(B, 1, d).astype(x.dtype)
    y = rms_norm(y, p["ln_x"], cfg.norm_eps) * g
    out = y @ p["wo"]

    h = x + out
    hk = _shift(h, p["cmu"][0], last_h)
    hr = _shift(h, p["cmu"][1], last_h)
    cm = (jnp.square(jax.nn.relu(hk @ p["ck"])) @ p["cv"])
    cm = jax.nn.sigmoid(hr @ p["cr"]) * cm
    return out + cm, (x[:, -1, :], S, h[:, -1, :])
