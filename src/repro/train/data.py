"""Synthetic LM data pipeline with host-side prefetch and straggler backup.

A deterministic per-step token stream (seeded by step id, so restarts are
bitwise reproducible), prefetched on a background thread.  If the producer
stalls past ``timeout_s`` (a host-side straggler), the consumer synthesises
the batch inline from the same seed — the step never blocks on a sick host.
"""

from __future__ import annotations

import queue
import threading
import time as _time

import numpy as np

__all__ = ["SyntheticLM", "PrefetchIterator"]


class SyntheticLM:
    """Markov-bigram synthetic corpus: learnable structure, zero deps."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def batch_for_step(self, step: int) -> dict:
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        base = rng.integers(0, self.vocab, (self.batch, self.seq + 1))
        # inject bigram structure: even tokens are followed by token+1
        nxt = np.where(base[:, :-1] % 2 == 0,
                       (base[:, :-1] + 1) % self.vocab, base[:, 1:])
        tokens = base[:, :-1].astype(np.int32)
        labels = nxt.astype(np.int32)
        return {"tokens": tokens, "labels": labels}


class PrefetchIterator:
    """Prefetch ``depth`` batches ahead; fall back to inline synthesis on a
    producer stall (straggler mitigation)."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2, timeout_s: float = 5.0):
        self.source = source
        self.step = start_step
        self.timeout_s = timeout_s
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next_produce = start_step
        self._stop = False
        self.stall_fallbacks = 0
        # daemon=True is a last-resort backstop for callers that drop the
        # iterator without close(); the supported lifecycle is close()
        # (or a with-block), which joins the thread deterministically
        self._t = threading.Thread(target=self._producer, daemon=True)
        self._t.start()

    def _producer(self):
        while not self._stop:
            b = self.source.batch_for_step(self._next_produce)
            try:
                self._q.put((self._next_produce, b), timeout=0.1)
                self._next_produce += 1
            except queue.Full:
                continue

    def __next__(self) -> dict:
        want = self.step
        try:
            while True:
                got_step, b = self._q.get(timeout=self.timeout_s)
                if got_step == want:
                    break
                if got_step > want:           # queue ran ahead of a restart
                    b = self.source.batch_for_step(want)
                    break
        except queue.Empty:
            # producer straggling: synthesise inline (deterministic)
            self.stall_fallbacks += 1
            b = self.source.batch_for_step(want)
        self.step += 1
        return b

    def close(self, timeout_s: float = 5.0):
        """Stop and join the producer thread (idempotent).

        The producer may be blocked in a bounded ``put``; draining the
        queue while joining guarantees it observes ``_stop`` within one
        put timeout instead of leaking past interpreter teardown.
        """
        self._stop = True
        t = self._t
        if t is None or not t.is_alive():
            return
        deadline = _time.monotonic() + timeout_s
        while t.is_alive() and _time.monotonic() < deadline:
            try:                                   # unblock a full put
                self._q.get_nowait()
            except queue.Empty:
                pass
            t.join(timeout=0.05)
        t.join(timeout=max(0.0, deadline - _time.monotonic()))

    def __enter__(self) -> "PrefetchIterator":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
