"""Hand-rolled AdamW with optional low-precision moment states and optional
error-feedback int8 gradient compression across the pod axis (see
repro.distributed.compression)."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["AdamW"]


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    state_dtype: str | None = None     # None: grads' dtype; "bfloat16" to halve
    grad_transform: object = None      # e.g. compression.PodCompressor

    def _sdt(self, g):
        if self.state_dtype == "bfloat16":
            return jnp.bfloat16
        return jnp.float32

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, self._sdt(p))
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(self, params, grads, state):
        if self.grad_transform is not None:
            grads, state = self.grad_transform.apply(grads, state)
        step = state["step"] + 1
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
            v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
            mh = m32 / c1
            vh = v32 / c2
            d = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay:
                d = d + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - self.lr * d
            return (newp.astype(p.dtype), m32.astype(m.dtype),
                    v32.astype(v.dtype))

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        new_state = dict(state)
        new_state.update({"step": step, "m": new_m, "v": new_v})
        return new_p, new_state
