"""Fault-tolerant training loop.

* jitted train step (loss + grads + AdamW) with donated state,
* periodic asynchronous checkpoints (CheckpointManager),
* crash/preemption recovery: on start, restore the latest committed
  checkpoint and resume from its step — bitwise identical to an uninterrupted
  run (the data pipeline is step-seeded),
* optional failure injection for tests (``fail_at_step``),
* host-side straggler mitigation via the prefetching data iterator.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from ..distributed.checkpoint import CheckpointManager
from ..models.lm import init_params, train_step_fn
from ..train.data import PrefetchIterator, SyntheticLM
from ..train.optimizer import AdamW

__all__ = ["TrainLoopConfig", "run_training"]


class InjectedFailure(RuntimeError):
    pass


@dataclass
class TrainLoopConfig:
    steps: int = 50
    batch: int = 8
    seq: int = 64
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_interval: int = 10
    lr: float = 1e-3
    fail_at_step: int | None = None
    seed: int = 0


def run_training(cfg_model, loop: TrainLoopConfig, shardings=None):
    """Returns (params, losses list, resumed_from_step)."""
    opt = AdamW(lr=loop.lr)
    step_fn = jax.jit(train_step_fn(cfg_model, opt), donate_argnums=(0, 1))

    params = init_params(cfg_model, jax.random.PRNGKey(loop.seed))
    opt_state = opt.init(params)

    mgr = CheckpointManager(loop.ckpt_dir, interval=loop.ckpt_interval)
    start = 0
    restored = mgr.restore_latest({"params": params, "opt": opt_state})
    if restored[0] is not None:
        start = restored[0]
        params = restored[1]["params"]
        opt_state = restored[1]["opt"]

    src = SyntheticLM(cfg_model.vocab, loop.batch, loop.seq, seed=loop.seed)
    it = PrefetchIterator(src, start_step=start)
    losses = []
    try:
        for step in range(start, loop.steps):
            if loop.fail_at_step is not None and step == loop.fail_at_step:
                raise InjectedFailure(f"injected failure at step {step}")
            batch = next(it)
            params, opt_state, loss = step_fn(params, opt_state,
                                              jax.tree.map(jax.numpy.asarray,
                                                           batch))
            losses.append(float(loss))
            mgr.maybe_save(step + 1, {"params": params, "opt": opt_state})
    finally:
        # join the in-flight async write even when crashing out: an
        # immediate restart must discover the highest committed step, not
        # race the background thread for it
        mgr.wait()
        it.close()
    return params, losses, start
