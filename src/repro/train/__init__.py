"""Training substrate: hand-rolled AdamW, synthetic LM data pipeline, and the
fault-tolerant training loop."""

from .optimizer import AdamW  # noqa: F401
