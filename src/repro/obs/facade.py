"""The ``Observability`` facade the engine threads everywhere.

One handle bundling the three obs primitives — span :class:`Tracer`,
:class:`MetricsRegistry`, :class:`SharingAuditLog` — behind the hooks the
runtime calls.  Every hook is safe to call with tracing disabled (the
tracer degenerates to guarded no-ops) and every engine call site guards
on ``obs is not None`` first, so a runtime constructed without
observability pays nothing.

``collect()`` is the single read-side facade over the previously
disconnected stat silos: it folds ``RunStats``, ``OverloadMetrics``,
``EventTimeMetrics`` and the executor counters into one dict next to the
registry series and the audit summary.
"""

from __future__ import annotations

from .audit import SharingAuditLog
from .metrics import (DEPTH_BUCKETS, LAG_BUCKETS, LATENCY_MS_BUCKETS,
                      OCCUPANCY_BUCKETS, MetricsRegistry)
from .trace import Tracer

PHASES = ("plan", "execute", "finalize", "fold")


class Observability:
    """Span tracer + metrics registry + sharing-decision audit log."""

    def __init__(self, *, trace: bool = True, audit: bool = True,
                 capacity: int = 1 << 18, sample: int = 1,
                 audit_capacity: int = 1 << 16):
        self.tracer = Tracer(capacity=capacity if trace else 0,
                             sample=sample)
        self.registry = MetricsRegistry()
        self.audit = SharingAuditLog(capacity=audit_capacity) if audit \
            else None
        self.pane_ticks: int | None = None  # set by the owning runtime
        # hot-path instrument handles, cached by name: registry lookups
        # re-validate histogram edges per call, too costly per pane
        self._phase_hist = {}
        self._counters = {}
        self._gauges = {}
        self._hists = {}

    @classmethod
    def disabled(cls) -> "Observability":
        """Tracing and audit off; the registry still collects series."""
        return cls(trace=False, audit=False)

    @property
    def tracing(self) -> bool:
        return self.tracer.enabled

    # ------------------------------------------------------------ pane keys

    def pane_key(self, pane):
        """(group, pane_t0) trace key for an event batch's pane.

        ``pane_ticks`` (set by the owning runtime) snaps the first event
        time to the pane grid so plan/execute/fold spans and event-time
        lifecycle marks land on the same track.
        """
        if pane is None or len(pane) == 0:
            return (-1, -1)
        t = int(pane.time[0])
        if self.pane_ticks:
            t -= t % self.pane_ticks
        return (int(pane.group[0]), t)

    # ----------------------------------------------------------- span hooks

    def pane_phase(self, phase, t_start, dur_s, key=None) -> None:
        """Record one pipeline-phase span (and its latency histogram)."""
        h = self._phase_hist.get(phase)
        if h is None:
            h = self._phase_hist[phase] = self.registry.histogram(
                f"engine.phase.{phase}_ms", LATENCY_MS_BUCKETS)
        h.observe(dur_s * 1e3)
        if self.tracer.enabled:
            self.tracer.complete(phase, t_start, dur_s, key=key,
                                 cat="phase")

    def pane_phase_n(self, phase, dur_s, n: int) -> None:
        """``n`` panes' worth of the same amortized phase duration, one
        call — the tracing-off twin of ``n`` ``pane_phase`` calls."""
        h = self._phase_hist.get(phase)
        if h is None:
            h = self._phase_hist[phase] = self.registry.histogram(
                f"engine.phase.{phase}_ms", LATENCY_MS_BUCKETS)
        h.observe_n(dur_s * 1e3, n)

    def lifecycle(self, stage, key=None, args=None) -> None:
        if self.tracer.enabled:
            self.tracer.instant(stage, key=key, cat="lifecycle", args=args)

    def cache_event(self, hit: bool, key=None) -> None:
        if self.tracer.enabled:
            self.tracer.instant("plan_cache_hit" if hit
                                else "plan_cache_miss", key=key, cat="cache")

    def span(self, name, cat="span", args=None):
        return self.tracer.span(name, cat, args)

    # -------------------------------------------------------- metrics hooks

    def count(self, name, n: int = 1) -> None:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = self.registry.counter(name)
        c.value += n

    def set_gauge(self, name, v) -> None:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = self.registry.gauge(name)
        g.value = v

    def observe(self, name, value, edges=LATENCY_MS_BUCKETS) -> None:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = self.registry.histogram(name, edges)
        h.observe(value)

    # ------------------------------------------------------------ audit hook

    def audit_decision(self, **kw) -> None:
        if self.audit is not None:
            self.audit.record(**kw)

    # ---------------------------------------------------------------- merge

    def merge_from(self, other: "Observability") -> None:
        """Fold another instance's metric series into this one.

        Cross-instance merge for fleets (one ``Observability`` per shard):
        counters and histogram buckets sum, gauges take the other's last
        write, matching histogram names must share bucket layouts.  Traces
        and audit logs are deliberately not merged — they are per-instance
        diagnostic streams, and interleaving them would destroy the
        per-shard timelines."""
        self.registry.merge(other.registry)

    # --------------------------------------------------------------- export

    def export_trace(self, path) -> int:
        return self.tracer.export_jsonl(path)

    def phase_totals(self) -> dict:
        return self.tracer.phase_totals()

    def collect(self, stats=None, overload=None, eventtime=None,
                runtime=None, serving=None) -> dict:
        """One unified read-side view over every stat silo.

        ``stats`` is a ``RunStats``, ``overload`` an ``OverloadMetrics``,
        ``eventtime`` an ``EventTimeMetrics``, ``runtime`` a
        ``HamletRuntime`` (for executor / fold-executor counters, which
        are also mirrored into registry gauges here), ``serving`` a
        :class:`~repro.serve.frontend.ServingFrontend` (per-session /
        per-tenant delivery-latency percentiles land under ``"serving"``).
        """
        out = {"metrics": self.registry.collect(),
               "trace": {"events": len(self.tracer),
                         "dropped": self.tracer.dropped,
                         "sample": self.tracer.sample}}
        if self.audit is not None:
            out["audit"] = self.audit.summary()
        if stats is not None:
            eng = {k: v for k, v in vars(stats).items()
                   if isinstance(v, (int, float))}
            eng["phase_split"] = stats.phase_split()
            out["engine"] = eng
        if overload is not None:
            out["overload"] = overload.summary()
        if serving is not None:
            out["serving"] = (serving if isinstance(serving, dict)
                              else serving.summary())
        if eventtime is not None:
            out["eventtime"] = eventtime.summary()
        if runtime is not None:
            ex = runtime.executor
            out["executors"] = {
                "batch": {"jobs": ex.jobs, "launches": ex.launches,
                          "flushes": ex.flushes}}
            fe = getattr(runtime, "fold_exec", None)
            if fe is not None:
                out["executors"]["fold"] = {
                    "flushes": fe.flushes, "launches": fe.launches,
                    "window_folds": fe.window_folds,
                    "flush_plan_hits": fe.plan_hits,
                    "flush_plan_misses": fe.plan_misses,
                    "flush_plan_evictions": fe.plan_evictions}
                for k in ("hits", "misses", "evictions"):
                    # sync the live series to the executor's lifetime total
                    # (they can lag when obs was attached mid-stream)
                    c = self.registry.counter(f"fold_exec.flush_plan.{k}")
                    c.value = getattr(fe, f"plan_{k}")
            out["plan_cache"] = runtime.plan_cache_stats()
        return out


__all__ = ["Observability", "PHASES", "LATENCY_MS_BUCKETS",
           "OCCUPANCY_BUCKETS", "LAG_BUCKETS", "DEPTH_BUCKETS"]
