"""Chrome-trace span tracer with per-pane tracks and a bounded ring buffer.

Spans are recorded as tuples into a ``collections.deque(maxlen=capacity)``
ring (oldest events drop first, counted in :attr:`Tracer.dropped`) and
formatted lazily at export.  The layout follows the Chrome trace event
format so the output loads directly in Perfetto / ``chrome://tracing``:

* ``tid 0`` is the *engine* track: nested ``B``/``E`` duration spans
  (micro-batch flush, fold flush, service epochs) plus engine-wide
  ``X`` phase events that have no pane attribution.
* ``tid >= 1`` is one track per sampled pane, keyed by
  ``(group, pane_t0)``: ``X`` complete events for the four pipeline
  phases (plan / execute / finalize / fold) and ``i`` instant events for
  lifecycle marks (ingest -> seal -> plan -> execute -> emit ->
  revise / evict) and plan-cache lookups.

Timestamps are microseconds relative to tracer construction, taken from
the *same* ``perf_counter`` readings the engine already uses for
``RunStats`` — so per-pane phase spans sum to the ``RunStats`` phase
totals by construction.

The export is strict JSONL (one event object per line).  Perfetto loads
the JSONL directly; for viewers that require the enveloped form, run::

    python -m repro.obs.trace trace.jsonl trace.json

to wrap the events as ``{"traceEvents": [...]}``.
"""

from __future__ import annotations

import json
import os
from collections import deque
from time import perf_counter

_PHASES = ("plan", "execute", "finalize", "fold")
_MISSING = object()


class _NullSpan:
    """No-op context manager returned when tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tr", "_name", "_cat", "_args")

    def __init__(self, tr, name, cat, args):
        self._tr = tr
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self):
        self._tr._begin(self._name, self._cat, self._args)
        return self

    def __exit__(self, *exc):
        self._tr._end(self._name)
        return False


class Tracer:
    """Bounded ring-buffer span recorder in Chrome trace event layout.

    ``capacity`` bounds the in-memory event ring (``capacity <= 0``
    disables the tracer entirely: every record call is a cheap guarded
    no-op and :meth:`span` returns a shared null context manager).
    ``sample`` records every N-th pane track; engine-track spans and
    unsampled-pane phase events are unaffected by sampling only in the
    sense that unsampled panes simply do not get a track (their events
    are skipped, keeping the ring for the panes that were kept).
    """

    def __init__(self, capacity: int = 1 << 18, sample: int = 1):
        self.capacity = int(capacity)
        self.sample = max(1, int(sample))
        self.enabled = self.capacity > 0
        self._events = deque(maxlen=max(1, self.capacity))
        self._t0 = perf_counter()
        self._stack: list[str] = []
        self._tids: dict = {}
        self._next_tid = 1
        self._panes_seen = 0
        self.dropped = 0
        self._pid = os.getpid()

    # ------------------------------------------------------------- internals

    def _ts(self, t: float | None = None) -> float:
        return ((perf_counter() if t is None else t) - self._t0) * 1e6

    def _emit(self, ev: tuple) -> None:
        if len(self._events) == self._events.maxlen:
            self.dropped += 1
        self._events.append(ev)

    # ----------------------------------------------------------- pane tracks

    def pane_tid(self, key):
        """Track id for pane ``key``; ``None`` when the pane is sampled out."""
        tid = self._tids.get(key, _MISSING)
        if tid is not _MISSING:
            return tid
        self._panes_seen += 1
        if (self._panes_seen - 1) % self.sample:
            self._tids[key] = None
            return None
        tid = self._next_tid
        self._next_tid += 1
        self._tids[key] = tid
        self._emit(("M", "thread_name", "__metadata", 0.0, 0.0, tid,
                    {"name": f"pane g{key[0]} t{key[1]}"}))
        return tid

    # ------------------------------------------------------------- recording

    def complete(self, name, t_start, dur_s, key=None, cat="phase",
                 args=None) -> None:
        """Record a retrospective ``X`` event ``dur_s`` seconds long."""
        if not self.enabled:
            return
        tid = 0
        if key is not None:
            tid = self.pane_tid(key)
            if tid is None:
                return
        self._emit(("X", name, cat, self._ts(t_start), dur_s * 1e6, tid,
                    args))

    def instant(self, name, key=None, cat="lifecycle", args=None) -> None:
        if not self.enabled:
            return
        tid = 0
        if key is not None:
            tid = self.pane_tid(key)
            if tid is None:
                return
        self._emit(("i", name, cat, self._ts(), 0.0, tid, args))

    def span(self, name, cat="span", args=None):
        """Nestable ``B``/``E`` duration span on the engine track."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, cat, args)

    def _begin(self, name, cat, args) -> None:
        self._stack.append(name)
        self._emit(("B", name, cat, self._ts(), 0.0, 0, args))

    def _end(self, name) -> None:
        if self._stack and self._stack[-1] == name:
            self._stack.pop()
        self._emit(("E", name, "span", self._ts(), 0.0, 0, None))

    # --------------------------------------------------------------- export

    def __len__(self) -> int:
        return len(self._events)

    def events(self) -> list[dict]:
        """Materialise the ring as Chrome trace event dicts."""
        out = []
        for ph, name, cat, ts, dur, tid, args in self._events:
            ev = {"ph": ph, "name": name, "cat": cat,
                  "ts": round(ts, 3), "pid": self._pid, "tid": tid}
            if ph == "X":
                ev["dur"] = round(dur, 3)
            elif ph == "i":
                ev["s"] = "t"
            if args:
                ev["args"] = args
            out.append(ev)
        return out

    def export_jsonl(self, path) -> int:
        """Write strict JSONL (one event per line); returns event count."""
        evs = self.events()
        with open(path, "w") as f:
            for ev in evs:
                f.write(json.dumps(ev, sort_keys=True))
                f.write("\n")
        return len(evs)

    def phase_totals(self) -> dict:
        """Seconds of recorded ``X`` phase-span time, keyed by phase name."""
        tot = {}
        for ph, name, cat, _ts, dur, _tid, _args in self._events:
            if ph == "X" and cat == "phase":
                tot[name] = tot.get(name, 0.0) + dur / 1e6
        return tot


def jsonl_to_chrome(src, dst) -> int:
    """Wrap a JSONL trace as ``{"traceEvents": [...]}`` for strict viewers."""
    events = []
    with open(src) as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    with open(dst, "w") as f:
        json.dump({"traceEvents": events,
                   "displayTimeUnit": "ms"}, f)
    return len(events)


def _main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="wrap a JSONL trace as a Chrome trace JSON envelope")
    ap.add_argument("src")
    ap.add_argument("dst")
    args = ap.parse_args(argv)
    n = jsonl_to_chrome(args.src, args.dst)
    print(f"wrote {n} events -> {args.dst}")
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
