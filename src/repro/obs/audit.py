"""Sharing-decision audit log.

Records every optimizer share / no-share decision the engine makes while
planning a pane: the candidate queries, the decided group partition
(*verbatim* the ``groups_sig`` tuple that enters the pane-plan cache
key), the benefit delta the cost model computed, the coverage pattern the
decision was based on, and whether the decision *flipped* the cached plan
key relative to the previous pane at the same (component, Kleene-type)
site — the paper's Fig. 12 adaptivity story, inspectable on any run.

Alongside the per-decision entries, :meth:`SharingAuditLog.note_pane`
captures the full decided-groups portion of each pane's plan-cache key so
a run's audit log can be replayed against the exact key objects the plan
cache saw (see ``tests/test_obs.py``).
"""

from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass, field


@dataclass(frozen=True)
class SharingDecision:
    """One optimizer share/no-share decision at a Kleene-type site."""

    seq: int                 # global decision ordinal
    pane: tuple              # (group, pane_t0) of the pane being planned
    comp: int                # component ordinal within the runtime
    el: int                  # local Kleene event-type index
    candidates: tuple        # query positions eligible to share
    decided: tuple           # decided groups — the plan-cache key object
    shared: bool             # any group of >= 2 queries?
    flipped: bool            # differs from previous decision at this site?
    benefit: float | None = None   # cost-model benefit delta (None: static)
    patterns: tuple | None = None  # coverage pattern histogram (value, count)
    b: int = 0               # burst size the decision was made on
    n: int = 0               # running event count at decision time

    def to_dict(self) -> dict:
        return {"seq": self.seq, "pane": list(self.pane), "comp": self.comp,
                "el": self.el, "candidates": list(self.candidates),
                "decided": [list(g) for g in self.decided],
                "shared": self.shared, "flipped": self.flipped,
                "benefit": self.benefit,
                "patterns": ([list(p) for p in self.patterns]
                             if self.patterns is not None else None),
                "b": self.b, "n": self.n}


@dataclass
class SharingAuditLog:
    """Bounded ring of :class:`SharingDecision` entries plus per-pane keys."""

    capacity: int = 1 << 16
    recorded: int = 0
    dropped: int = 0
    flips: int = 0
    shared_decisions: int = 0
    split_decisions: int = 0
    _entries: deque = field(init=False, repr=False)
    _last: dict = field(default_factory=dict, repr=False)
    _pane_groups: OrderedDict = field(default_factory=OrderedDict,
                                      repr=False)

    def __post_init__(self):
        self._entries = deque(maxlen=max(1, int(self.capacity)))

    def record(self, *, pane, comp, el, candidates, decided,
               benefit=None, patterns=None, b=0, n=0) -> None:
        decided = tuple(tuple(g) for g in decided)
        site = (comp, el)
        prev = self._last.get(site)
        flipped = prev is not None and prev != decided
        self._last[site] = decided
        shared = any(len(g) >= 2 for g in decided)
        self.recorded += 1
        self.flips += flipped
        if shared:
            self.shared_decisions += 1
        else:
            self.split_decisions += 1
        if len(self._entries) == self._entries.maxlen:
            self.dropped += 1
        self._entries.append(SharingDecision(
            seq=self.recorded, pane=tuple(pane) if pane else (-1, -1),
            comp=comp, el=el, candidates=tuple(candidates), decided=decided,
            shared=shared, flipped=flipped, benefit=benefit,
            patterns=(tuple(tuple(p) for p in patterns)
                      if patterns is not None else None),
            b=int(b), n=int(n)))

    def note_pane(self, pane, groups: tuple, comp: int = 0) -> None:
        """Record the decided-groups portion of a pane's plan-cache key,
        keyed ``(comp, group, pane_t0)`` (components plan independently)."""
        if pane is None:
            return
        key = (comp,) + tuple(pane)
        if key in self._pane_groups:
            self._pane_groups.move_to_end(key)
        elif len(self._pane_groups) >= self._entries.maxlen:
            self._pane_groups.popitem(last=False)
        self._pane_groups[key] = groups

    # --------------------------------------------------------------- access

    def entries(self) -> list:
        return list(self._entries)

    def by_pane(self) -> dict:
        out: dict = {}
        for e in self._entries:
            out.setdefault(e.pane, []).append(e)
        return out

    def pane_key_groups(self) -> dict:
        """(comp, group, pane_t0) -> decided-groups tuple as assembled
        into the pane's plan-cache key."""
        return dict(self._pane_groups)

    def summary(self) -> dict:
        return {"decisions": self.recorded, "dropped": self.dropped,
                "shared": self.shared_decisions,
                "split": self.split_decisions, "flips": self.flips,
                "sites": len(self._last)}

    def export_jsonl(self, path) -> int:
        import json

        with open(path, "w") as f:
            for e in self._entries:
                f.write(json.dumps(e.to_dict(), sort_keys=True))
                f.write("\n")
        return len(self._entries)
