"""Metrics registry: counters, gauges, histograms with fixed bucket layouts.

Every series lives in a :class:`MetricsRegistry` keyed by name.  Histogram
bucket edges are *fixed at creation* and must match on every subsequent
lookup and on :meth:`MetricsRegistry.merge` — merging two histograms with
different edge layouts raises instead of silently resampling, so bucket
edges are stable across merges by construction.

The module ships the canonical edge layouts the engine uses:

* ``LATENCY_MS_BUCKETS`` — phase / pane latency in milliseconds.
* ``SERVE_LATENCY_MS_BUCKETS`` — serving delivery / blocked-time latency
  (finer sub-100ms edges so paced-session quantiles do not snap to the
  coarse engine-phase edges).
* ``OCCUPANCY_BUCKETS``  — bucket occupancy and launches-per-flush.
* ``LAG_BUCKETS``        — watermark lag in stream ticks.
* ``DEPTH_BUCKETS``      — revision-storm depth (panes per storm).
"""

from __future__ import annotations

from bisect import bisect_right
from math import inf, isfinite

LATENCY_MS_BUCKETS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0,
                      50.0, 100.0, 250.0, 500.0, 1000.0)

# Serving delivery latency needs finer resolution than the engine-phase
# layout: a paced session study operates in the 10–500 ms regime, and with
# the coarse edges above every quantile snaps to 25.0/50.0/500.0 ms exactly
# (the committed BENCH_serving.json artifact showed p50 == 25.0 because the
# histogram had no edge between 25 and 50).  These edges keep sub-100 ms
# resolution at ~±15% per bucket.  Every serving-latency series must use
# this layout — histogram merges raise on a layout mismatch, so mixing the
# coarse layout in is caught loudly instead of silently resampled.
SERVE_LATENCY_MS_BUCKETS = (
    0.05, 0.1, 0.25, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 5.0, 6.5, 8.0, 10.0,
    12.5, 15.0, 20.0, 25.0, 30.0, 35.0, 40.0, 50.0, 60.0, 70.0, 85.0,
    100.0, 125.0, 150.0, 200.0, 250.0, 300.0, 400.0, 500.0, 700.0,
    1000.0, 1500.0, 2000.0)


def serve_latency_series(kind: str, key) -> str:
    """Canonical name of a keyed serving-latency histogram series.

    ``kind`` is ``"session"`` or ``"tenant"``; the serving front-end keeps
    one ``SERVE_LATENCY_MS_BUCKETS`` histogram per key under this name
    (delivery latency: pane sealed by the scheduler watermark -> record in
    inbox).
    """
    if kind not in ("session", "tenant"):
        raise ValueError(f"unknown serving latency kind {kind!r}")
    return f"serve.latency_ms.{kind}.{key}"


def serve_blocked_series(sid) -> str:
    """Canonical name of the per-session credit-blocked-time histogram.

    The transport's credit gate observes, per session, how long the
    session sat at zero credits before the next grant (the producer-side
    backpressure stall); layout is ``SERVE_LATENCY_MS_BUCKETS``."""
    return f"serve.blocked_ms.session.{sid}"


OCCUPANCY_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0,
                     512.0, 1024.0)
LAG_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)
DEPTH_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)


class Counter:
    """Monotonic counter."""

    kind = "counter"
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def merge(self, other: "Counter") -> None:
        self.value += other.value

    def collect(self):
        return self.value


class Gauge:
    """Last-write-wins scalar."""

    kind = "gauge"
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v

    def merge(self, other: "Gauge") -> None:
        self.value = other.value

    def collect(self):
        return self.value


class Histogram:
    """Fixed-bucket histogram: ``len(edges) + 1`` counts, last is overflow.

    Non-finite observations (NaN, ±inf) never enter the buckets or ``sum``
    — they land in the ``invalid`` counter, so one poisoned sample cannot
    turn ``mean`` (and every latency report downstream) into NaN forever.
    ``max`` tracks the largest *finite* observation, which lets
    :meth:`quantile` report a real value even when the quantile lands in
    the open overflow bucket instead of silently capping at the last
    finite edge (the classic under-reported-SLO-breach bug).
    """

    kind = "histogram"
    __slots__ = ("name", "edges", "counts", "count", "sum", "invalid", "max")

    def __init__(self, name: str, edges=LATENCY_MS_BUCKETS):
        edges = tuple(float(e) for e in edges)
        if not edges or list(edges) != sorted(set(edges)):
            raise ValueError(f"histogram {name!r}: edges must be a "
                             f"non-empty strictly increasing sequence")
        self.name = name
        self.edges = edges
        self.counts = [0] * (len(edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.invalid = 0          # NaN / ±inf observations, kept out of sum
        self.max = None           # largest finite observation, or None

    def observe(self, v) -> None:
        if not isfinite(v):
            self.invalid += 1
            return
        self.counts[bisect_right(self.edges, v)] += 1
        self.count += 1
        self.sum += v
        if self.max is None or v > self.max:
            self.max = v

    def observe_n(self, v, n: int) -> None:
        """Record ``n`` observations of the same value in one call."""
        if not isfinite(v):
            self.invalid += n
            return
        self.counts[bisect_right(self.edges, v)] += n
        self.count += n
        self.sum += v * n
        if self.max is None or v > self.max:
            self.max = v

    def merge(self, other: "Histogram") -> None:
        if other.edges != self.edges:
            raise ValueError(
                f"histogram {self.name!r}: bucket layouts differ "
                f"({self.edges} vs {other.edges}); edges are fixed at "
                f"creation and must be stable across merges")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        self.invalid += other.invalid
        if other.max is not None and (self.max is None
                                      or other.max > self.max):
            self.max = other.max

    def quantile(self, q: float) -> float:
        """Upper bucket edge containing quantile ``q`` (0..1).

        ``q == 0`` reports the first *populated* bucket's edge (not a
        populated-looking edge from empty leading buckets); a quantile in
        the overflow bucket reports the tracked finite ``max`` rather
        than capping at the last edge.
        """
        if self.count == 0:
            return 0.0
        target = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target and (c > 0 or target > 0):
                if i >= len(self.edges):
                    return self.max if self.max is not None else inf
                return self.edges[i]
        return self.max if self.max is not None else self.edges[-1]

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def collect(self):
        return {"count": self.count, "sum": self.sum, "mean": self.mean,
                "invalid": self.invalid, "max": self.max,
                "edges": list(self.edges), "counts": list(self.counts)}


class MetricsRegistry:
    """Name-keyed registry of counters, gauges and histograms."""

    def __init__(self):
        self._m: dict = {}

    def _get(self, name, cls, *args):
        m = self._m.get(name)
        if m is None:
            m = self._m[name] = cls(name, *args)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, requested {cls.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, edges=LATENCY_MS_BUCKETS) -> Histogram:
        h = self._get(name, Histogram, edges)
        if h.edges != tuple(float(e) for e in edges):
            raise ValueError(f"histogram {name!r} already registered with "
                             f"edges {h.edges}")
        return h

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold ``other`` into this registry (types and edges must agree)."""
        for name, m in other._m.items():
            if isinstance(m, Histogram):
                self.histogram(name, m.edges).merge(m)
            else:
                self._get(name, type(m)).merge(m)

    def names(self):
        return sorted(self._m)

    def __len__(self) -> int:
        return len(self._m)

    def __contains__(self, name) -> bool:
        return name in self._m

    def get(self, name):
        return self._m.get(name)

    def collect(self) -> dict:
        return {name: self._m[name].collect() for name in sorted(self._m)}
