"""Unified observability: span tracer, metrics registry, sharing audit log.

The engine threads one :class:`Observability` facade (``obs=None`` by
default — zero cost when absent) through the pane pipeline:

* :mod:`repro.obs.trace` — Chrome-trace/Perfetto span tracer with
  per-pane tracks, a bounded ring buffer, and a sampling knob.
* :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket histograms
  behind a name-keyed registry with merge-stable bucket layouts.
* :mod:`repro.obs.audit` — the sharing-decision audit log recording every
  optimizer share/no-share decision and plan-key flip.
"""

from .audit import SharingAuditLog, SharingDecision
from .facade import PHASES, Observability
from .metrics import (DEPTH_BUCKETS, LAG_BUCKETS, LATENCY_MS_BUCKETS,
                      OCCUPANCY_BUCKETS, SERVE_LATENCY_MS_BUCKETS, Counter,
                      Gauge, Histogram, MetricsRegistry)
from .trace import NULL_SPAN, Tracer, jsonl_to_chrome

__all__ = [
    "Observability", "PHASES", "Tracer", "NULL_SPAN", "jsonl_to_chrome",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "LATENCY_MS_BUCKETS", "SERVE_LATENCY_MS_BUCKETS", "OCCUPANCY_BUCKETS",
    "LAG_BUCKETS", "DEPTH_BUCKETS", "SharingAuditLog", "SharingDecision",
]
