"""Wire transport for the serving front-end: frame codec roundtrips,
loopback bitwise parity with the in-process path (ordered and event-time
disordered), credit-based backpressure bounds, disconnect races, and
socket/thread lifecycle hygiene."""

import os
import struct
import threading
import time

import numpy as np
import pytest

from repro.core.engine import HamletRuntime, vals_equal
from repro.core.events import EventBatch
from repro.core.pattern import EventType, Kleene, Seq
from repro.core.query import Query, Workload
from repro.eventtime.config import EventTimeConfig
from repro.overload.config import OverloadConfig
from repro.overload.runtime import OverloadRuntime
from repro.serve import CreditGate, ServingClient, ServingFrontend, \
    ServingServer
from repro.serve.session import Delivery
from repro.serve.transport import (decode_chunk, decode_deliveries,
                                   encode_chunk, encode_deliveries)
from repro.streams.generator import (NAMED_STREAMS, RIDESHARING_SCHEMA,
                                     SMARTHOME_SCHEMA, STOCK_SCHEMA,
                                     TAXI_SCHEMA, DisorderConfig,
                                     apply_disorder)

DATASETS = {
    "ridesharing": (RIDESHARING_SCHEMA, "Travel", ("Request", "Accept")),
    "stock": (STOCK_SCHEMA, "Quote", ("Buy", "Sell")),
    "smarthome": (SMARTHOME_SCHEMA, "Measure", ("Load", "Work")),
    "taxi": (TAXI_SCHEMA, "Travel", ("Request", "Pickup")),
}

STREAM_KW = {"ridesharing": dict(events_per_minute=250, minutes=1,
                                 n_groups=6),
             "stock": dict(events_per_minute=300, minutes=1, n_groups=6),
             "smarthome": dict(events_per_minute=300, minutes=1,
                               n_groups=6),
             "taxi": dict(events_per_minute=250, minutes=1, n_groups=6)}


def _wl(schema, kleene, heads, within=20, slide=10):
    k = EventType(kleene)
    qs = [Query(f"q{i}", Seq(EventType(h), Kleene(k)),
                within=within, slide=slide)
          for i, h in enumerate(heads)]
    qs.append(Query("qk", Kleene(k), within=within, slide=slide))
    return Workload(schema, qs)


def _dataset(name):
    schema, kleene, heads = DATASETS[name]
    return (_wl(schema, kleene, heads),
            NAMED_STREAMS[name](**STREAM_KW[name]))


def _by_tenant(stream, n_tenants, groups_per_tenant=2):
    parts = []
    for t in range(n_tenants):
        lo, hi = t * groups_per_tenant, (t + 1) * groups_per_tenant
        mask = (stream.group >= lo) & (stream.group < hi)
        parts.append(stream.select(np.flatnonzero(mask)))
    return parts


def _frontend(wl, **kw):
    kw.setdefault("backend", "overload")
    kw.setdefault("overload",
                  OverloadConfig(shed_policy="none", micro_batch=4))
    kw.setdefault("groups_per_tenant", 2)
    return ServingFrontend(wl, **kw)


def _wait_sessions_closed(fe, n, timeout=30.0):
    """CLOSE frames are processed by the server loop asynchronously; the
    owner must not drain before every session's close has landed."""
    deadline = time.perf_counter() + timeout
    while True:
        sess = fe.summary()["sessions"]
        if len(sess) >= n and all(s["closed"] for s in sess.values()):
            return
        assert time.perf_counter() < deadline, "sessions never closed"
        time.sleep(0.005)


def _assert_same(a, b, ctx=""):
    assert set(a) == set(b), ctx
    for k in a:
        assert vals_equal(a[k], b[k]), (ctx, k)


# ----------------------------------------------------------------- codec


def test_chunk_codec_roundtrip_is_zero_copy():
    wl, stream = _dataset("stock")
    payload = encode_chunk(stream)
    back = decode_chunk(wl.schema, payload)
    for col in ("type_id", "time", "attrs", "group", "seq"):
        a, b = getattr(stream, col), getattr(back, col)
        if a is None:
            assert b is None
            continue
        assert np.array_equal(a, b), col
        assert not b.flags.owndata, f"{col} was copied, not viewed"
    empty = stream.select(np.arange(0))
    assert len(decode_chunk(wl.schema, encode_chunk(empty))) == 0


def test_delivery_codec_roundtrip_values_and_interning():
    ds = [
        Delivery("emit", "q0", 3, 40, {"count": 7.0, "sum": float("nan")},
                 0, 1.25),
        Delivery("retract", "q0", 3, 40, None, 1, 0.5),
        Delivery("amend", "q1", -2, 50,
                 {"count": 9, "arr": np.arange(3.0)}, 2, 2000.0),
    ]
    t_enc, back = decode_deliveries(encode_deliveries(ds, 123.5))
    assert t_enc == 123.5
    assert len(back) == len(ds)
    for a, b in zip(ds, back):
        assert (a.kind, a.query, a.group, a.w0, a.revision) == \
            (b.kind, b.query, b.group, b.w0, b.revision)
        assert b.latency_ms == pytest.approx(a.latency_ms)
    assert back[0].vals["count"] == 7.0
    assert type(back[0].vals["count"]) is float
    assert np.isnan(back[0].vals["sum"])
    assert back[1].vals is None
    assert back[2].vals["count"] == 9 and type(back[2].vals["count"]) is int
    assert np.array_equal(back[2].vals["arr"], np.arange(3.0))
    # one intern table per frame: "q0" appears once in the payload
    assert encode_deliveries(ds, 0.0).count(b"q0") == 1


# ------------------------------------------------------- loopback parity


@pytest.mark.parametrize("name", sorted(DATASETS))
def test_loopback_parity_sweep(name):
    """Three socket clients trickling tenant splits through the server are
    bitwise equal to the single-threaded batch run, and each END frame
    carries exactly the subscribed subset."""
    wl, stream = _dataset(name)
    ref = OverloadRuntime(
        wl, OverloadConfig(shed_policy="none", micro_batch=4)).run(stream)
    parts = _by_tenant(stream, 3)
    fe = _frontend(wl)
    srv = ServingServer(fe)
    host, port = srv.start()
    out = {}
    # sessions must all exist before anyone submits, else an early
    # closer lets the seal pass a late opener's first events — the same
    # open-before-trickle contract the in-process tests follow
    opened = threading.Barrier(3)

    def run_client(t):
        c = ServingClient(host, port, tenant=t)
        opened.wait(timeout=30.0)
        for c0 in range(0, len(parts[t]), 40):
            c.submit(parts[t].select(
                np.arange(c0, min(c0 + 40, len(parts[t])))))
        c.close()
        got = list(c.deliveries())
        out[t] = (c.results, got)
        c.shutdown()

    threads = [threading.Thread(target=run_client, args=(t,))
               for t in range(3)]
    try:
        for th in threads:
            th.start()
        _wait_sessions_closed(fe, 3)
        res = srv.drain()
        for th in threads:
            th.join(timeout=30.0)
            assert not th.is_alive()
    finally:
        srv.stop()
    _assert_same(res, ref, name)
    n_deliver = 0
    for t in range(3):
        end_res, got = out[t]
        _assert_same(end_res,
                     {k: v for k, v in ref.items() if k[1] // 2 == t},
                     (name, t))
        assert all(d.group // 2 == t for d in got), "cross-tenant delivery"
        n_deliver += len(got)
    assert n_deliver == len(ref)
    summ = srv.summary()
    assert summ["frames_in"] > 0 and summ["bytes_out"] > 0
    assert summ["disconnects"] == 0


def test_loopback_eventtime_disorder_parity():
    """Disordered arrivals over the socket (chunk-local sort, producer seq
    riding the wire) repair to the in-order batch run bitwise."""
    wl, stream = _dataset("taxi")
    t_end = ((int(stream.time.max()) // 10) + 1) * 10
    ref = HamletRuntime(wl).run(stream, t_end=t_end)
    ds = apply_disorder(stream, DisorderConfig(fraction=0.3, max_skew=6,
                                               seed=5))
    base = ds.base
    fe = _frontend(wl, backend="eventtime",
                   eventtime=EventTimeConfig(skew=8), micro_batch=2,
                   skew=8, overload=None)
    srv = ServingServer(fe)
    host, port = srv.start()
    clients = [ServingClient(host, port, tenant=t) for t in range(3)]
    try:
        rng = np.random.default_rng(7)
        cur = 0
        while cur < len(base):
            n = int(rng.integers(20, 60))
            idx = ds.order[cur:min(cur + n, len(base))]
            sub = EventBatch.from_unsorted(
                base.schema, base.type_id[idx], base.time[idx],
                base.attrs[idx], base.group[idx], seq=base.seq[idx])
            clients[int(rng.integers(0, 3))].submit(sub)
            cur += n
        for c in clients:
            c.advance_to(t_end)
            c.close()
        _wait_sessions_closed(fe, 3)
        srv.drain()
        got = {k: v for k, v in fe.results().items() if k in ref}
        _assert_same(got, ref)
        for c in clients:
            c.wait_end()
    finally:
        for c in clients:
            c.shutdown()
        srv.stop()


# ----------------------------------------------------------- backpressure


class _FakeFE:
    def __init__(self):
        self.sealed = 0
        self.staged = 0

    def sealed_to(self):
        return self.sealed

    def staged_events(self):
        return self.staged


class _Rec:
    def __init__(self):
        self.counts = {}
        self.blocked = []

    def count(self, name, n=1):
        self.counts[name] = self.counts.get(name, 0) + n

    def observe_blocked(self, sid, ms):
        self.blocked.append((sid, ms))


def test_credit_gate_withholds_and_regrant_is_lossless():
    fe, rec = _FakeFE(), _Rec()
    gate = CreditGate(fe, window=10, staging_high=5, obs=rec)
    assert gate.register(1) == 10
    gate.on_submit(1, 4, t_max=10, now=0.0)
    gate.on_submit(1, 6, t_max=20, now=0.0)     # balance 0 -> blocked
    fe.sealed, fe.staged = 15, 9                # first submit consumed,
    assert gate.poll(1, now=1.0) == 0           # but gate is shut
    assert gate.withheld == 4
    assert rec.counts["serve.credits_withheld"] == 4
    fe.sealed, fe.staged = 25, 2                # gate open, all freed
    assert gate.poll(1, now=2.0) == 10          # withheld credits regrant
    assert gate.granted == 10
    assert rec.counts["serve.credits_granted"] == 10
    assert rec.blocked and rec.blocked[0][0] == 1
    assert rec.blocked[0][1] == pytest.approx(2000.0)   # blocked 0.0->2.0s
    gate.forget(1)
    assert gate.poll(1, now=3.0) == 0           # unknown session: no-op
    gate.on_submit(1, 5, t_max=30, now=3.0)     # post-forget: dropped
    assert gate.summary()["inflight"] == {}


def test_backpressure_bounds_staging_and_never_sheds():
    """A producer much faster than the seal: the credit window caps what
    it can hold in flight, so staging stays bounded and nothing is shed —
    overload surfaces as client blocked time, not loss."""
    from repro.obs import Observability

    wl, stream = _dataset("ridesharing")
    window, chunk, high = 48, 16, 1 << 10
    obs = Observability()
    fe = _frontend(wl, session_admission=True, obs=obs)
    srv = ServingServer(fe, credit_window=window, staging_high=high)
    host, port = srv.start()
    try:
        c = ServingClient(host, port, tenant=0, groups="all")
        for c0 in range(0, len(stream), chunk):
            c.submit(stream.select(
                np.arange(c0, min(c0 + chunk, len(stream)))))
        c.close()
        _wait_sessions_closed(fe, 1)
        res = srv.drain()
        c.wait_end()
        c.shutdown()
    finally:
        srv.stop()
    summ = fe.summary()
    assert summ["session_shed"] == 0, "compliant client was shed"
    assert summ["sessions"][c.sid]["submitted"] == len(stream)
    # hard bound: staged events never exceed the gate plus the session's
    # window (plus one in-transit chunk), however fast the producer pushes
    assert summ["staging"]["hwm"] <= high + window + chunk
    gate = srv.summary()["credit"]
    # credit conservation: everything submitted beyond the initial window
    # had to be granted back first
    assert gate["granted"] >= len(stream) - window
    assert c.blocked_s > 0.0, "producer never hit the credit wall"
    assert res, "no results through the backpressured session"
    metrics = obs.collect(serving=fe)["metrics"]
    assert metrics["serve.credits_granted"] >= len(stream) - window
    assert metrics["serve.staging_hwm"] == summ["staging"]["hwm"]
    blocked = [k for k in metrics if k.startswith("serve.blocked_ms.")]
    assert blocked, "blocked-time histogram series missing"


# ------------------------------------------------------ disconnect races


def test_client_disconnect_mid_stream_frees_session_and_credits():
    """A hard socket drop (no CLOSE, no BYE) must close the session, free
    its credit state, and leave the surviving session's results bitwise
    intact — and drain() must not hang on the dead connection."""
    wl, stream = _dataset("ridesharing")
    ref = OverloadRuntime(
        wl, OverloadConfig(shed_policy="none", micro_batch=4)).run(stream)
    parts = _by_tenant(stream, 2)
    fe = _frontend(wl)
    srv = ServingServer(fe)
    host, port = srv.start()
    try:
        victim = ServingClient(host, port, tenant=0)
        survivor = ServingClient(host, port, tenant=1)
        victim.submit(parts[0].select(np.arange(min(40, len(parts[0])))))
        victim.kill()                          # mid-stream, no CLOSE
        survivor.submit(parts[1])
        survivor.close()
        deadline = time.perf_counter() + 30.0
        while True:
            sess = fe.summary()["sessions"]
            if (srv.disconnects == 1
                    and sess[victim.sid]["closed"]
                    and sess[survivor.sid]["closed"]):
                break
            assert time.perf_counter() < deadline, "drop never detected"
            time.sleep(0.005)
        assert victim.sid not in srv.gate.summary()["inflight"]
        srv.drain(timeout=30.0)
        end = survivor.wait_end()
        survivor.shutdown()
    finally:
        srv.stop()
    # group independence: the survivor's subscribed windows are untouched
    # by the victim's partial submission
    _assert_same(end, {k: v for k, v in ref.items() if k[1] // 2 == 1})
    with pytest.raises(ConnectionError):
        list(victim.deliveries())              # cut, not drained


def test_dead_client_blocked_on_credits_unblocks():
    """submit(block=True) waiting for credits must raise, not hang, when
    the connection dies underneath it."""
    wl, stream = _dataset("ridesharing")
    fe = _frontend(wl)
    srv = ServingServer(fe, credit_window=8)
    host, port = srv.start()
    try:
        c = ServingClient(host, port, tenant=0)
        err = []

        def push():
            try:
                # single huge batch can never fit the window of 8
                c.submit(stream, timeout=30.0)
            except (ConnectionError, TimeoutError) as e:
                err.append(e)

        th = threading.Thread(target=push)
        th.start()
        time.sleep(0.05)
        c.kill()
        th.join(timeout=10.0)
        assert not th.is_alive(), "submit hung on a dead connection"
        assert err and isinstance(err[0], ConnectionError)
    finally:
        srv.stop()


# -------------------------------------------------------------- hygiene


def test_no_leaked_threads_or_fds_after_stop():
    fds_before = len(os.listdir("/proc/self/fd"))
    before = set(threading.enumerate())
    wl, stream = _dataset("ridesharing")
    parts = _by_tenant(stream, 2)
    fe = _frontend(wl)
    srv = ServingServer(fe)
    host, port = srv.start()
    clients = [ServingClient(host, port, tenant=t) for t in range(2)]
    for t, c in enumerate(clients):
        c.submit(parts[t])
        c.close()
    _wait_sessions_closed(fe, 2)
    srv.drain()
    for c in clients:
        c.wait_end()
        c.shutdown()
    srv.stop()
    leaked = [t for t in threading.enumerate()
              if t not in before and t.is_alive()
              and "ThreadPoolExecutor" not in repr(t)
              and "asyncio" not in t.name]
    assert not leaked, leaked
    assert len(os.listdir("/proc/self/fd")) <= fds_before, "fd leak"


def test_bad_frame_type_drops_connection_cleanly():
    wl, _ = _dataset("ridesharing")
    fe = _frontend(wl)
    srv = ServingServer(fe)
    host, port = srv.start()
    try:
        c = ServingClient(host, port, tenant=0)
        c._send(99, b"junk")                  # protocol violation
        deadline = time.perf_counter() + 10.0
        while not c._dead:
            assert time.perf_counter() < deadline
            time.sleep(0.005)
        c.kill()
        assert fe.summary()["sessions"][c.sid]["closed"]
    finally:
        srv.stop()
